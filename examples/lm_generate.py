"""Generate from any assigned architecture (reduced config) — exercises the
prefill + KV/state-cache decode path across all six arch families.

    PYTHONPATH=src:. python examples/lm_generate.py --arch mamba2-1.3b
    PYTHONPATH=src:. python examples/lm_generate.py --arch recurrentgemma-2b
"""
import sys
sys.path[:0] = ["src", "."]

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import TokenStream, text_memory, vit_patch_embeds
from repro.launch.serve import generate
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b",
                    choices=list(configs.REGISTRY))
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get(args.arch, "smoke")
    if cfg.task != "lm":
        raise SystemExit(f"{args.arch} is a diffusion model — "
                         "use examples/serve_diffusion.py")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    stream = TokenStream(cfg.vocab_size, args.prompt_len, args.batch,
                         num_codebooks=cfg.num_codebooks)
    prompts, _ = stream.batch_at(0)
    memory = (text_memory(jax.random.PRNGKey(3), args.batch, 8, cfg.cond_dim)
              if cfg.cond_dim else None)
    print(f"[{cfg.name}] families: "
          f"{sorted(set(t for t in cfg.layer_types()))}; prompts {prompts.shape}")
    t0 = time.time()
    toks = generate(cfg, params, prompts, args.gen, memory=memory,
                    key=jax.random.PRNGKey(1))
    print(f"[{cfg.name}] generated {toks.shape} in {time.time()-t0:.1f}s")
    print(f"[{cfg.name}] sample:", jax.device_get(toks[0]).tolist()[:12])


if __name__ == "__main__":
    main()
