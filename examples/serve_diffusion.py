"""End-to-end SERVING driver (the paper's inference kind): a batched
diffusion-generation service with SmoothCache acceleration, built on the
`repro.cache` policy API.

A calibration process runs once and saves a `CacheArtifact` (curves +
resolved schedule + provenance); the serving process *loads* the artifact —
it never recalibrates — and drains a queue of generation requests in
fixed-size batches.  Schedules are input-independent (the paper's core
observation), so one artifact serves every request.  Reports per-request
latency with and without caching.

    PYTHONPATH=src:. python examples/serve_diffusion.py --requests 24 \
        --batch 8 --policy "smoothcache:alpha=0.18"
"""
import sys
sys.path[:0] = ["src", "."]

import argparse
import dataclasses
import os
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import cache, configs
from repro.core import solvers


@dataclasses.dataclass
class Request:
    rid: int
    label: int
    submitted: float
    done: Optional[float] = None


class DiffusionServer:
    """Static-batch serving loop: drain the queue in batches of B."""

    def __init__(self, pipeline: cache.DiffusionPipeline, params, batch: int,
                 cached: bool = True):
        self.pipe = pipeline
        self.params = params
        self.batch = batch
        # resolved schedule, or None for the uncached baseline
        self.schedule = pipeline.schedule if cached else None

    def serve(self, queue: List[Request], key):
        results = {}
        i = 0
        while i < len(queue):
            chunk = queue[i : i + self.batch]
            labels = jnp.array([r.label for r in chunk])
            if len(chunk) < self.batch:           # pad the tail batch
                pad = self.batch - len(chunk)
                labels = jnp.concatenate([labels, jnp.zeros(pad, jnp.int32)])
            x = self.pipe.generate(
                self.params, jax.random.fold_in(key, i), self.batch,
                label=labels, compiled=False, schedule=self.schedule)
            jax.block_until_ready(x)
            now = time.time()
            for j, r in enumerate(chunk):
                r.done = now
                results[r.rid] = np.asarray(x[j])
            i += self.batch
        return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--policy", default="smoothcache:alpha=0.18",
                    help="cache policy spec, e.g. 'smoothcache:alpha=0.18', "
                         "'static:n=2', 'budget:target=0.5', or "
                         "'per_type(attn=smoothcache(alpha=0.1),"
                         "ffn=static(n=2))'")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--artifact", default="",
                    help="path for the calibration artifact "
                         "(default: results/serve_<arch>.cache.json)")
    args = ap.parse_args()

    cache.get(args.policy)                 # fail fast on a bad spec
    cfg = configs.get("dit-xl-256", "smoke")
    print("[serve] training small DiT ...")
    params, _, _ = common.train_small_dit(cfg, jax.random.PRNGKey(0),
                                          steps=120)

    # --- calibration process: calibrate once, save the artifact -------------
    calib = cache.DiffusionPipeline(cfg, solvers.ddim(args.steps),
                                    args.policy, cfg_scale=1.5)
    calib.calibrate(params, jax.random.PRNGKey(1), 8,
                    cond_args={"label": jnp.arange(8) % cfg.num_classes})
    path = args.artifact or os.path.join(common.RESULTS_DIR,
                                         f"serve_{cfg.name}.cache.json")
    calib.save_artifact(path)
    print(f"[serve] saved {path}")
    print("[serve] " + calib.schedule.summary().replace("\n", "\n[serve] "))

    # --- serving process: load the artifact, never recalibrate --------------
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(args.steps),
                                   args.policy, cfg_scale=1.5)
    pipe.load_artifact(path)
    print(f"[serve] loaded artifact (compute fraction "
          f"{pipe.compute_fraction():.2f})")

    rng = np.random.RandomState(0)
    def make_queue():
        t0 = time.time()
        return [Request(i, int(rng.randint(cfg.num_classes)), t0)
                for i in range(args.requests)]

    for name, cached in [("no_cache", False), (args.policy, True)]:
        server = DiffusionServer(pipe, params, args.batch, cached=cached)
        queue = make_queue()
        server.serve(queue, jax.random.PRNGKey(2))     # warmup compile
        queue = make_queue()
        t0 = time.time()
        server.serve(queue, jax.random.PRNGKey(3))
        dt = time.time() - t0
        lat = np.mean([r.done - r.submitted for r in queue])
        print(f"[serve] {name:24s}: {args.requests} requests in {dt:.2f}s "
              f"({dt/args.requests*1e3:.0f} ms/req, mean latency {lat:.2f}s)")


if __name__ == "__main__":
    main()
