"""End-to-end SERVING driver (the paper's inference kind): a batched
diffusion-generation service with SmoothCache acceleration.

A queue of generation requests (class label or text-memory conditioned)
is served in fixed-size batches; the executor reuses one calibrated
schedule across all requests (schedules are input-independent — the
paper's core observation).  Reports per-request latency with and without
caching.

    PYTHONPATH=src:. python examples/serve_diffusion.py --requests 24 \
        --batch 8 --alpha 0.18
"""
import sys
sys.path[:0] = ["src", "."]

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import configs
from repro.core import calibration, schedule as S, solvers
from repro.core.executor import SmoothCacheExecutor


@dataclasses.dataclass
class Request:
    rid: int
    label: int
    submitted: float
    done: Optional[float] = None


class DiffusionServer:
    """Static-batch serving loop: drain the queue in batches of B."""

    def __init__(self, cfg, params, solver, schedule, batch: int,
                 cfg_scale: float = 1.5):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.schedule = schedule
        self.ex = SmoothCacheExecutor(cfg, solver, cfg_scale=cfg_scale)

    def serve(self, queue: List[Request], key):
        results = {}
        i = 0
        while i < len(queue):
            chunk = queue[i : i + self.batch]
            labels = jnp.array([r.label for r in chunk])
            if len(chunk) < self.batch:           # pad the tail batch
                pad = self.batch - len(chunk)
                labels = jnp.concatenate([labels, jnp.zeros(pad, jnp.int32)])
            x = self.ex.sample(self.params, jax.random.fold_in(key, i),
                               self.batch, schedule=self.schedule,
                               label=labels)
            jax.block_until_ready(x)
            now = time.time()
            for j, r in enumerate(chunk):
                r.done = now
                results[r.rid] = np.asarray(x[j])
            i += self.batch
        return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.18)
    ap.add_argument("--steps", type=int, default=50)
    args = ap.parse_args()

    cfg = configs.get("dit-xl-256", "smoke")
    print("[serve] training small DiT ...")
    params, _, _ = common.train_small_dit(cfg, jax.random.PRNGKey(0),
                                          steps=120)
    solver = solvers.ddim(args.steps)

    # one calibration pass → one schedule reused by every request
    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
    curves, _, _ = calibration.calibrate(
        ex, params, jax.random.PRNGKey(1), 8,
        cond_args={"label": jnp.arange(8) % cfg.num_classes})
    sch = S.smoothcache(curves, args.alpha, k_max=3)
    print("[serve] " + sch.summary().replace("\n", "\n[serve] "))

    rng = np.random.RandomState(0)
    def make_queue():
        t0 = time.time()
        return [Request(i, int(rng.randint(cfg.num_classes)), t0)
                for i in range(args.requests)]

    for name, schedule in [("no_cache", None), (f"alpha={args.alpha}", sch)]:
        server = DiffusionServer(cfg, params, solver, schedule, args.batch)
        queue = make_queue()
        server.serve(queue, jax.random.PRNGKey(2))     # warmup compile
        queue = make_queue()
        t0 = time.time()
        server.serve(queue, jax.random.PRNGKey(3))
        dt = time.time() - t0
        lat = np.mean([r.done - r.submitted for r in queue])
        print(f"[serve] {name:14s}: {args.requests} requests in {dt:.2f}s "
              f"({dt/args.requests*1e3:.0f} ms/req, mean latency {lat:.2f}s)")


if __name__ == "__main__":
    main()
