"""End-to-end SERVING driver — a thin CLI over ``repro.serve``.

A calibration process runs once and saves `CacheArtifact`s (curves +
resolved schedule + plan + provenance); the serving process *loads* them
into an `ArtifactStore` — it never recalibrates — and drains an open-loop
queue of generation requests with synthetic Poisson arrivals through the
continuous-batching `ServeEngine`: power-of-two micro-batch buckets per
(artifact, signature) group, step-interleaved scheduling over the
executor's resumable segment runs, and the segment-compiled path by
default (``--eager`` falls back to the reference sampler).

Three scenarios share one arrival trace: every request on ``no_cache``,
every request on the calibrated policy, and a heterogeneous queue mixing
both with an adaptive policy.  The report separates p50/p95 queue wait
from service time (arrivals are real timestamps, not one shared t0).

    PYTHONPATH=src:. python examples/serve_diffusion.py --requests 24 \
        --batch 8 --policy "smoothcache:alpha=0.18" --rate 2.0
"""
import sys
sys.path[:0] = ["src", "."]

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import cache, configs, serve
from repro.core import solvers
from repro.core.executor import SmoothCacheExecutor

CFG_SCALE = 1.5


def build_store(cfg, solver, policy, adaptive_spec, paths):
    """Serving-side store: calibration-free baseline + artifact entries."""
    store = serve.ArtifactStore(cfg, solver, cfg_scale=CFG_SCALE)
    store.add_policy("no_cache", "none")
    store.add_artifact(policy, paths["static"])
    store.add_artifact(adaptive_spec, paths["adaptive"])
    return store


def make_requests(n, policies, rng, cfg, rate):
    """Open-loop trace: Poisson arrivals, random labels/seeds, policies
    assigned round-robin (the heterogeneous case passes several)."""
    arrivals = serve.poisson_arrivals(rate, n, rng)
    return [serve.Request(
        rid=i, seed=int(rng.randint(1 << 30)),
        policy=policies[i % len(policies)],
        label=int(rng.randint(cfg.num_classes)),
        arrival=a) for i, a in enumerate(arrivals)]


def serve_scenario(name, policies, *, executor, params, store, args, cfg):
    """Drain one Poisson trace; returns the engine report."""
    # identical trace across scenarios: reseed the arrival/label RNG
    rng = np.random.RandomState(0)
    eng = serve.ServeEngine(
        executor, params, store, max_batch=args.batch,
        max_wait=args.max_wait, max_inflight=args.max_inflight,
        eager=args.eager)
    t0 = eng.clock.now()
    reqs = make_requests(args.requests, policies, rng, cfg, args.rate)
    for r in reqs:
        r.arrival += t0
    eng.submit(*reqs)
    eng.run_until_drained()
    rep = eng.report()
    qw, sv = rep["queue_wait_s"], rep["service_s"]
    print(f"[serve] {name:16s}: {rep['requests']} req "
          f"{rep['throughput_rps']:6.2f} req/s | "
          f"queue p50/p95 {qw['p50']:.2f}/{qw['p95']:.2f}s | "
          f"service p50/p95 {sv['p50']:.2f}/{sv['p95']:.2f}s | "
          f"compute {rep['compute_fraction']:.2f} | "
          f"programs {rep['compiles']['xla_programs']}"
          f"≤{rep['program_budget']}")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8,
                    help="max micro-batch bucket (power of two)")
    ap.add_argument("--policy", default="smoothcache:alpha=0.18",
                    help="calibrated policy spec for the static artifact")
    ap.add_argument("--tau", type=float, default=0.3,
                    help="adaptive threshold for the mixed-queue scenario")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--max-wait", type=float, default=0.5,
                    help="batching window before a partial bucket forms")
    ap.add_argument("--max-inflight", type=int, default=2)
    ap.add_argument("--train-steps", type=int, default=120)
    ap.add_argument("--eager", action="store_true",
                    help="escape hatch: serve on the eager reference "
                         "sampler instead of the segment-compiled path")
    ap.add_argument("--artifact-dir", default="",
                    help="directory for calibration artifacts "
                         "(default: results/)")
    args = ap.parse_args()

    cache.get(args.policy)                 # fail fast on a bad spec
    adaptive_spec = f"adaptive:base={args.policy.replace(':', '(', 1)}" \
                    + (")" if ":" in args.policy else "") \
                    + f",tau={args.tau:g}"
    cfg = configs.get("dit-xl-256", "smoke")
    print("[serve] training small DiT ...")
    params, _, _ = common.train_small_dit(cfg, jax.random.PRNGKey(0),
                                          steps=args.train_steps)

    # --- calibration process: calibrate once, save artifacts ----------------
    outdir = args.artifact_dir or common.RESULTS_DIR
    paths = {}
    for kind, spec in [("static", args.policy), ("adaptive", adaptive_spec)]:
        calib = cache.DiffusionPipeline(cfg, solvers.ddim(args.steps), spec,
                                        cfg_scale=CFG_SCALE)
        calib.calibrate(params, jax.random.PRNGKey(1), 8,
                        cond_args={"label": jnp.arange(8) % cfg.num_classes})
        paths[kind] = calib.save_artifact(
            os.path.join(outdir, f"serve_{cfg.name}.{kind}.cache.json"))
        print(f"[serve] saved {paths[kind]}")

    # --- serving process: load, validate, never recalibrate -----------------
    solver = solvers.ddim(args.steps)
    executor = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
    store = build_store(cfg, solver, args.policy, adaptive_spec, paths)
    print("[serve] " + store.summary().replace("\n", "\n[serve] "))

    scenarios = [
        ("no_cache", ["no_cache"]),
        (args.policy, [args.policy]),
        ("mixed+adaptive", ["no_cache", args.policy, adaptive_spec]),
    ]
    for name, policies in scenarios:
        serve_scenario(name, policies, executor=executor, params=params,
                       store=store, args=args, cfg=cfg)


if __name__ == "__main__":
    main()
