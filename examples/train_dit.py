"""End-to-end training driver: train a DiT for a few hundred steps on the
synthetic latent pipeline with checkpointing, then sample from it.

    PYTHONPATH=src:. python examples/train_dit.py --steps 300 \
        --ckpt /tmp/dit.ckpt [--arch dit-xl-256]
"""
import sys
sys.path[:0] = ["src", "."]

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import checkpoint, configs
from repro.core import diffusion, solvers
from repro.core.executor import SmoothCacheExecutor
from repro.data import BlobLatents, CondLatents


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-xl-256")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_dit.ckpt")
    args = ap.parse_args()

    cfg = configs.get(args.arch, "smoke")
    kind = "rf" if args.arch.startswith("opensora") else "eps"
    if cfg.num_classes:
        data = BlobLatents(cfg.latent_shape, cfg.num_classes, args.batch)
    else:
        data = CondLatents(cfg.latent_shape, cfg.cond_dim, 8, args.batch)
    print(f"[train_dit] {cfg.name}: {cfg.num_layers} blocks, "
          f"latents {cfg.latent_shape}, {args.steps} steps")
    params, sched, losses = common.train_small_dit(
        cfg, jax.random.PRNGKey(0), steps=args.steps, batch=args.batch,
        lr=args.lr, data=data, loss_kind=kind)
    print(f"[train_dit] loss: {losses[0]:.4f} → "
          f"{np.mean(losses[-20:]):.4f} (last-20 mean)")
    checkpoint.save(args.ckpt, {"params": params},
                    {"arch": args.arch, "steps": args.steps, "kind": kind})
    print(f"[train_dit] saved {args.ckpt}")

    # sample from the trained model to prove the checkpoint round-trips
    tree, meta = checkpoint.restore(args.ckpt)
    solver = (solvers.rectified_flow(30) if kind == "rf" else solvers.ddim(50))
    ex = SmoothCacheExecutor(cfg, solver,
                             cfg_scale=1.5 if cfg.num_classes else None)
    cond = {}
    if cfg.num_classes:
        cond["label"] = jnp.arange(4) % cfg.num_classes
    else:
        cond["memory"] = data.batch_at(0)[1][:4]
    x = ex.sample(tree["params"], jax.random.PRNGKey(1), 4, **cond)
    print(f"[train_dit] sampled {x.shape}, finite={bool(jnp.all(jnp.isfinite(x)))}")


if __name__ == "__main__":
    main()
