"""Quickstart: SmoothCache end to end in ~2 minutes on CPU, via the
`repro.cache` policy API.

1. train a small class-conditional DiT on synthetic latents,
2. build a `DiffusionPipeline` and run one 10-sample calibration pass
   (paper §3.1 uses 10) — this yields a serializable `CacheArtifact`,
3. sweep cache policies by registry spec string (Eq. 4 α-schedules vs
   No-Cache and FORA static intervals),
4. report measured wall-clock speedup + sample-quality proxy.

    PYTHONPATH=src:. python examples/quickstart.py
"""
import sys, os
sys.path[:0] = ["src", "."]

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import cache, configs
from repro.core import solvers
from repro.data import BlobLatents


def main():
    cfg = configs.get("dit-xl-256", "smoke")
    print(f"model: {cfg.name} ({cfg.num_layers} blocks, d={cfg.d_model}, "
          f"latents {cfg.latent_shape}), types={cfg.layer_types()}")

    print("training small DiT on synthetic class-conditional latents ...")
    params, _, losses = common.train_small_dit(
        cfg, jax.random.PRNGKey(0), steps=150)
    print(f"  loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(50),
                                   "smoothcache:alpha=0.18", cfg_scale=1.5)
    label = jnp.arange(10) % cfg.num_classes

    print("calibration pass (10 samples, 50 DDIM steps) ...")
    artifact = pipe.calibrate(params, jax.random.PRNGKey(1), 10,
                              cond_args={"label": label})
    for t, c in artifact.curves.items():
        print(f"  {t:5s} lag-1 err: start={c[1,1]:.3f} "
              f"mid={c[25,1]:.3f} end={c[-1,1]:.3f}")

    data = BlobLatents(cfg.latent_shape, cfg.num_classes, 32, seed=7)
    ref_x0, ref_label = data.batch_at(0)

    def sample(sch):
        return pipe.generate(params, jax.random.PRNGKey(3), 32,
                             schedule=sch, label=ref_label)

    base = sample(None)
    t_base = common.time_call(lambda: sample(None), iters=2)
    fd_base = common.frechet_distance(np.asarray(base), np.asarray(ref_x0))
    print(f"\n{'policy':24s} {'ms/batch':>9s} {'speedup':>8s} "
          f"{'frechet':>9s} {'compute%':>9s}")
    print(f"{'no_cache':24s} {t_base/1e3:9.0f} {1.0:8.2f}x {fd_base:9.4f} "
          f"{100.0:8.0f}%")
    for spec in ("smoothcache:alpha=0.08", "smoothcache:alpha=0.18",
                 "static:n=2", "static:n=3"):
        sch = pipe.schedule_for(spec)     # resolved against the one artifact
        x = sample(sch)
        t = common.time_call(lambda: sample(sch), iters=2)
        fd = common.frechet_distance(np.asarray(x), np.asarray(ref_x0))
        frac = 100 * np.mean([sch.compute_fraction(ty) for ty in sch.skip])
        print(f"{spec:24s} {t/1e3:9.0f} {t_base/t:8.2f}x {fd:9.4f} "
              f"{frac:8.0f}%")


if __name__ == "__main__":
    main()
