"""repro.serve — scheduler/batcher/store unit tests on a virtual clock
with a fake executor (batch formation, bucket selection, signature
grouping, fairness under mixed schedules, artifact hot-swap, metrics),
plus one end-to-end test on the smoke DiT proving served latents are
bit-identical to direct ``DiffusionPipeline.generate`` with the same
seeds (the serving determinism contract)."""
import dataclasses
import json

import numpy as np
import pytest

from repro import serve
from repro.cache.artifact import CacheArtifact
from repro.core import plan as plan_lib
from repro.core import schedule as S
from repro.serve.batcher import bucket_for, bucket_sizes
from repro.serve.metrics import percentile


# ---------------------------------------------------------------------------
# Fakes: deployment (cfg/solver), executor with virtual-clock costs
# ---------------------------------------------------------------------------

class FakeCfg:
    name = "fake-arch"

    def layer_types(self):
        return ("attn", "ffn")


class FakeSolver:
    name = "ddim"

    def __init__(self, num_steps=8):
        self.num_steps = num_steps


@dataclasses.dataclass
class FakeRunState:
    plan: plan_lib.ExecutionPlan
    batch: int
    run_index: int = 0
    x: object = None
    decisions = None

    @property
    def done(self):
        return self.run_index >= len(self.plan.runs)


@dataclasses.dataclass
class FakeAdaptiveState:
    schedule: object
    batch: int
    step: int = 0
    x: object = None
    decisions: tuple = ()

    @property
    def done(self):
        return self.step >= self.schedule.num_steps


class FakeExecutor:
    """Implements the executor's resumable-run surface; each advance
    charges the virtual clock per *computed* layer evaluation, so cheap
    (heavily cached) schedules finish in less virtual time and scheduling
    behavior becomes exact assertions."""

    def __init__(self, clock, step_cost=1.0):
        self.clock = clock
        self.step_cost = step_cost
        self._programs = set()               # (kind, sig-ish, batch shape)

    def _charge(self, skip: dict, length: int):
        computed = sum(1 for sk in skip.values() if not sk)
        self.clock.advance(self.step_cost * length
                           * computed / max(len(skip), 1))

    def start_run(self, params, key, batch, *, plan, schedule=None,
                  label=None, memory=None):
        return FakeRunState(plan=plan, batch=batch)

    def advance_run(self, params, rs, *, check=False):
        run = rs.plan.runs[rs.run_index]
        self._programs.add(("seg", run.sig, rs.batch))
        self._charge(run.sig.skip, run.length)
        rs = dataclasses.replace(rs, run_index=rs.run_index + 1)
        if rs.done:
            # row j encodes its batch position (tests result routing)
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def start_adaptive_run(self, params, key, batch, *, schedule, tau,
                           proxy_map=None, pool=None, k_max=3, label=None,
                           memory=None):
        return FakeAdaptiveState(schedule=schedule, batch=batch)

    def advance_adaptive_run(self, params, rs):
        mask = {t: bool(v[rs.step]) for t, v in rs.schedule.skip.items()}
        skipset = tuple(sorted(t for t, sk in mask.items() if sk))
        self._programs.add(("sigstep", skipset, rs.batch))
        self._charge(mask, 1)
        rs = dataclasses.replace(rs, step=rs.step + 1,
                                 decisions=rs.decisions + (skipset,))
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def sample(self, params, key, batch, *, schedule=None, label=None,
               memory=None):
        self._programs.add(("eager", "all", batch))
        for s in range(schedule.num_steps):
            self._charge({t: bool(v[s])
                          for t, v in schedule.skip.items()}, 1)
        return np.arange(batch, dtype=np.float64)[:, None]

    def compiled_variant_count(self, kind=None):
        if kind is None:
            return len(self._programs)
        return len({p for p in self._programs if p[0] == kind})

    def xla_program_count(self, kind=None):
        return self.compiled_variant_count(kind)


def make_store(num_steps=8, **entries):
    store = serve.ArtifactStore(FakeCfg(), FakeSolver(num_steps))
    for name, spec in entries.items():
        store.add_policy(name, spec)
    return store


def make_engine(num_steps=8, store=None, **kw):
    clock = serve.VirtualClock()
    store = store if store is not None else make_store(
        num_steps, no_cache="none", static2="static:n=2")
    ex = FakeExecutor(clock)
    kw.setdefault("max_batch", 4)
    eng = serve.ServeEngine(ex, params=None, store=store, clock=clock, **kw)
    return eng, clock


def req(rid, policy, arrival=0.0, priority=0, seed=None, label=None):
    return serve.Request(rid=rid, seed=rid if seed is None else seed,
                         policy=policy, label=label, priority=priority,
                         arrival=arrival)


# ---------------------------------------------------------------------------
# Buckets (pure)
# ---------------------------------------------------------------------------

def test_bucket_for_largest_power_of_two():
    assert [bucket_for(n, 8) for n in (1, 2, 3, 4, 5, 7, 8, 9, 100)] \
        == [1, 2, 2, 4, 4, 4, 8, 8, 8]
    with pytest.raises(ValueError):
        bucket_for(0, 8)


def test_bucket_sizes():
    assert bucket_sizes(8) == (1, 2, 4, 8)
    assert bucket_sizes(1) == (1,)


def test_max_batch_must_be_power_of_two():
    with pytest.raises(ValueError, match="power of two"):
        make_engine(max_batch=6)


# ---------------------------------------------------------------------------
# Batch formation / bucket selection
# ---------------------------------------------------------------------------

def test_tail_splits_into_power_of_two_buckets():
    eng, _ = make_engine(max_batch=4)
    eng.submit(*[req(i, "static2") for i in range(7)])
    eng.run_until_drained()
    assert sorted(r.bucket for r in eng.records) == [1, 2, 4]
    # every row is a real request — no padding anywhere
    assert sum(r.bucket for r in eng.records) == 7
    assert sorted(eng.results) == list(range(7))


def test_result_rows_route_to_the_right_request():
    eng, _ = make_engine(max_batch=4)
    eng.submit(*[req(i, "static2") for i in range(6)])
    res = eng.run_until_drained()
    for rec in eng.records:
        for j, rid in enumerate(rec.rids):
            assert res[rid][0] == j        # fake writes row index into row


def test_batching_window_holds_partial_buckets():
    eng, clock = make_engine(max_batch=4, max_wait=5.0)
    eng.submit(req(0, "static2", arrival=0.0),
               req(1, "static2", arrival=1.0),
               req(2, "static2", arrival=2.0))
    eng.run_until_drained()
    # nothing fills the 4-bucket, so one 2-batch + one 1-batch form when
    # the oldest member's wait hits max_wait — not at arrival
    assert [r.bucket for r in eng.records] == [2, 1]
    assert eng.records[0].formed_at == pytest.approx(5.0)
    reqs0 = eng.records[0].rids
    assert reqs0 == (0, 1)


def test_batching_window_expiry_is_roundoff_safe():
    # regression: the engine sleeps the virtual clock to exactly
    # ``arrival + max_wait`` (next_event), but the old expiry test
    # ``now - arrival >= max_wait`` can round the other way
    # ((a+w)-a < w), so the window never expired and the engine
    # livelocked with a frozen clock.  Formation must use the same
    # float expression the event time was computed with.
    a, w = 9.3665445913662, 0.2
    assert (a + w) - a < w          # the roundoff premise of the bug
    eng, _ = make_engine(max_batch=4, max_wait=w)
    eng.submit(req(0, "static2", arrival=a))
    eng.run_until_drained()
    assert sorted(eng.results) == [0]
    assert eng.records[0].formed_at == pytest.approx(a + w)


def test_full_bucket_forms_immediately_despite_window():
    eng, _ = make_engine(max_batch=4, max_wait=100.0)
    eng.submit(*[req(i, "static2", arrival=0.0) for i in range(4)])
    eng.run_until_drained()
    assert [r.bucket for r in eng.records] == [4]
    assert eng.records[0].formed_at == pytest.approx(0.0)


def test_priority_beats_arrival_within_group():
    eng, _ = make_engine(max_batch=2, max_wait=0.0, max_inflight=1)
    eng.submit(req(0, "static2", arrival=0.0),
               req(1, "static2", arrival=0.0),
               req(2, "static2", arrival=0.0, priority=5))
    eng.run_until_drained()
    assert 2 in eng.records[0].rids


def test_arrivals_gate_admission():
    eng, clock = make_engine(max_batch=4)
    eng.submit(req(0, "static2", arrival=0.0),
               req(1, "static2", arrival=50.0))
    eng.run_until_drained()
    # the late request cannot join the first batch
    assert [r.bucket for r in eng.records] == [1, 1]
    assert eng.records[1].formed_at >= 50.0


# ---------------------------------------------------------------------------
# Signature grouping + fairness
# ---------------------------------------------------------------------------

def test_policies_never_share_a_batch():
    eng, _ = make_engine(max_batch=4)
    eng.submit(*[req(i, "static2" if i % 2 else "no_cache")
                 for i in range(8)])
    eng.run_until_drained()
    for rec in eng.records:
        # one entry per batch: every member request targeted rec.group
        assert all(rid % 2 == (rec.group == "static2") for rid in rec.rids)
    by_group = {}
    for rec in eng.records:
        by_group.setdefault(rec.group, 0)
        by_group[rec.group] += rec.bucket
    assert by_group == {"no_cache": 4, "static2": 4}


def test_round_robin_across_groups():
    eng, _ = make_engine(max_batch=2, max_inflight=1)
    eng.submit(*[req(i, "no_cache") for i in range(4)],
               *[req(10 + i, "static2") for i in range(4)])
    eng.run_until_drained()
    # groups alternate instead of one draining fully first
    assert [r.group for r in eng.records] == [
        "no_cache", "static2", "no_cache", "static2"]


def test_interleave_avoids_convoy_fcfs_does_not():
    """A short heavily-cached batch admitted behind a long many-segment
    one must not convoy under the interleaving scheduler.  The long job
    (``static:n=2`` over 16 steps) has 16 plan segments ≈ 8 virtual
    seconds of compute; the short job (``static:n=8``) has 4 segments ≈
    2 seconds and arrives just after the long one starts."""
    done_times = {}
    for sched_name in ("interleave", "fcfs"):
        store = make_store(16, longjob="static:n=2", cached="static:n=8")
        eng, clock = make_engine(num_steps=16, store=store, max_batch=2,
                                 max_inflight=2, scheduler=sched_name)
        eng.submit(req(0, "longjob", arrival=0.0),
                   req(1, "cached", arrival=0.5))
        eng.run_until_drained()
        done = {rec.group: rec.finished_at for rec in eng.records}
        done_times[sched_name] = done
    # fcfs: the cached run convoys behind the long run
    assert done_times["fcfs"]["cached"] > done_times["fcfs"]["longjob"]
    # interleave: the cheap run timeslices in and finishes first
    assert (done_times["interleave"]["cached"]
            < done_times["interleave"]["longjob"])
    assert (done_times["interleave"]["cached"]
            < done_times["fcfs"]["cached"])


def test_adaptive_entries_route_through_adaptive_runs():
    store = make_store(8, static2="static:n=2")
    art = _adaptive_artifact(num_steps=8)
    store.add_artifact("adaptive", art)
    eng, _ = make_engine(store=store, max_batch=2)
    eng.submit(req(0, "adaptive"), req(1, "adaptive"), req(2, "static2"))
    eng.run_until_drained()
    rec = {r.group: r for r in eng.records}
    assert rec["adaptive"].decisions is not None
    assert len(rec["adaptive"].decisions) == 8
    assert rec["static2"].decisions is None
    # realized fraction comes from decisions and matches the fake's rule
    sch = store.get("adaptive").schedule
    skipped = sum(int(v[s]) for v in sch.skip.values()
                  for s in range(sch.num_steps))
    assert rec["adaptive"].compute_fraction == pytest.approx(
        1.0 - skipped / (8 * 2))


# ---------------------------------------------------------------------------
# Fused adaptive servables
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FakeFusedState:
    schedule: object
    batch: int
    step: int = 0
    x: object = None
    chunks: int = 0                          # program dispatches so far

    @property
    def done(self):
        return self.step >= self.schedule.num_steps

    @property
    def decisions(self):
        return tuple(
            tuple(sorted(t for t, v in self.schedule.skip.items()
                         if v[s]))
            for s in range(self.step))


class FakeFusedExecutor(FakeExecutor):
    """Fused-capable fake: one "fused" program per entry pool regardless
    of chunking, a whole n_steps chunk per advance."""

    supports_fused_adaptive = True
    fused_advances = 0

    def start_adaptive_fused_run(self, params, key, batch, *, schedule,
                                 tau, proxy_map=None, pool=None, k_max=3,
                                 label=None, memory=None):
        self._programs.add(("fused", tuple(sorted(
            tuple(s.live_in) for s in pool)), batch))
        return FakeFusedState(schedule=schedule, batch=batch)

    def advance_adaptive_fused(self, params, rs, n_steps=None):
        self.fused_advances += 1
        remaining = rs.schedule.num_steps - rs.step
        length = remaining if n_steps is None else min(n_steps, remaining)
        for s in range(rs.step, rs.step + length):
            self._charge({t: bool(v[s])
                          for t, v in rs.schedule.skip.items()}, 1)
        rs = dataclasses.replace(rs, step=rs.step + length,
                                 chunks=rs.chunks + 1)
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs


def test_fused_adaptive_servables_route_and_count_one_program():
    store = make_store(8, static2="static:n=2")
    store.add_artifact("adaptive", _adaptive_artifact(num_steps=8))
    clock = serve.VirtualClock()
    ex = FakeFusedExecutor(clock)
    eng = serve.ServeEngine(ex, params=None, store=store, clock=clock,
                            max_batch=2, adaptive_chunk=3)
    eng.submit(req(0, "adaptive"), req(1, "adaptive"), req(2, "static2"))
    eng.run_until_drained()
    rec = {r.group: r for r in eng.records}
    # decisions survive through the fused trace; the run advanced in
    # ceil(8/3) = 3 chunk dispatches, not 8 per-step ones
    assert len(rec["adaptive"].decisions) == 8
    assert ex.fused_advances == 3
    # exactly ONE fused program for the entry's whole pool; no per-
    # signature "sigstep" dispatch programs
    assert ex.compiled_variant_count("fused") == 1
    assert ex.compiled_variant_count("sigstep") == 0


def test_program_budget_counts_fused_adaptive_as_one():
    store = make_store(8, static2="static:n=2")
    store.add_artifact("adaptive", _adaptive_artifact(num_steps=8))
    clock = serve.VirtualClock()
    static_sigs = store.get("static2").plan.num_unique_signatures
    ever = [t for t, v in store.get("adaptive").schedule.skip.items()
            if v.any()]
    buckets = len(bucket_sizes(4))
    # host-dispatched executor: the adaptive entry costs its whole pool
    eng_host = serve.ServeEngine(FakeExecutor(clock), params=None,
                                 store=store, clock=clock, max_batch=4)
    assert eng_host.program_budget() == buckets * (static_sigs
                                                   + 2 ** len(ever))
    # fused executor: the adaptive entry costs ONE program per bucket
    eng_fused = serve.ServeEngine(FakeFusedExecutor(clock), params=None,
                                  store=store, clock=clock, max_batch=4)
    assert eng_fused.program_budget() == buckets * (static_sigs + 1)
    assert eng_fused.program_budget() < eng_host.program_budget()


def test_eager_escape_hatch():
    eng, _ = make_engine(max_batch=2, eager=True)
    eng.submit(req(0, "static2"), req(1, "static2"))
    eng.run_until_drained()
    assert eng.executor.compiled_variant_count("eager") == 1
    assert eng.executor.compiled_variant_count("seg") == 0
    assert sorted(eng.results) == [0, 1]


def test_unknown_policy_rejected_at_submit():
    # a reasoned outcome, not an engine-killing KeyError mid-stream
    eng, _ = make_engine()
    eng.submit(req(0, "typo"))
    assert eng.outcome(0) == ("shed", "no_entry")
    assert eng.metrics.rejects == {"no_entry": 1}
    assert eng.metrics.shed_reasons.get("no_entry") == 1
    # the queue never saw it; the engine drains cleanly
    assert len(eng.queue) == 0
    eng.run_until_drained()


def test_duplicate_rid_rejected_even_while_pending():
    # duplicates are dropped and counted — the original's outcome is
    # untouched, and the serving loop survives
    eng, _ = make_engine()
    eng.submit(req(0, "static2", arrival=100.0))     # queued, not served
    eng.submit(req(0, "static2"))                    # cross-call dup
    eng.submit(req(1, "static2"), req(1, "static2"))  # same-call dup
    assert eng.metrics.rejects == {"duplicate_rid": 2}
    assert eng.outcome(0) == ("pending", None)
    assert len(eng.queue) == 2                       # one rid 0, one rid 1
    eng.run_until_drained()
    assert sorted(eng.results) == [0, 1]


def test_batch_key_distinguishes_high_bit_seeds():
    a = np.asarray(serve.batch_key([5]))
    b = np.asarray(serve.batch_key([2 ** 31 + 5]))
    assert not np.array_equal(a, b)
    # and is order-sensitive (row order is part of the batch identity)
    c = np.asarray(serve.batch_key([1, 2]))
    d = np.asarray(serve.batch_key([2, 1]))
    assert not np.array_equal(c, d)


# ---------------------------------------------------------------------------
# Store: validation + hot swap
# ---------------------------------------------------------------------------

def _static_artifact(num_steps=8, n=2, arch="fake-arch", solver="ddim",
                     name=None):
    types = ("attn", "ffn")
    sch = S.fora(types, num_steps, n)
    return CacheArtifact(
        arch=arch, solver=solver, num_steps=num_steps,
        policy={"name": "static", "n": n}, curves={}, schedule=sch,
        plan=plan_lib.analyze(sch).to_jsonable(), meta={})


def _adaptive_artifact(num_steps=8, tau=0.1, k_max=1):
    types = ("attn", "ffn")
    sch = S.fora(types, num_steps, 2)
    pool = [list(sig.live_in) for sig in plan_lib.mask_lattice(sch)]
    return CacheArtifact(
        arch="fake-arch", solver="ddim", num_steps=num_steps,
        policy={"name": "adaptive", "base": {"name": "static", "n": 2},
                "tau": tau},
        curves={}, schedule=sch,
        plan=plan_lib.analyze(sch).to_jsonable(),
        adaptive={"tau": tau, "k_max": k_max,
                  "proxy_map": {"coeffs": {"attn": [0.0, 0.01],
                                           "ffn": [0.0, 0.01]},
                                "mean_proxy": None},
                  "pool": pool},
        meta={})


def test_store_rejects_calibration_needing_policy():
    store = make_store()
    with pytest.raises(ValueError, match="never calibrates"):
        store.add_policy("smooth", "smoothcache:alpha=0.18")


def test_store_validates_artifact_against_deployment():
    store = make_store()
    with pytest.raises(ValueError, match="calibrated on"):
        store.add_artifact("bad", _static_artifact(arch="other-arch"))
    with pytest.raises(ValueError, match="solver"):
        store.add_artifact("bad", _static_artifact(num_steps=99))
    # non-strict loads anyway (explicit override)
    store.add_artifact("forced", _static_artifact(arch="other-arch"),
                       strict=False)


def test_store_adaptive_tau_without_proxy_map_rejected():
    art = _adaptive_artifact()
    art.adaptive.pop("proxy_map")
    store = make_store()
    with pytest.raises(ValueError, match="proxy_map"):
        store.add_artifact("adaptive", art)


def test_hot_swap_bumps_version_and_serves_new_schedule(tmp_path):
    path = str(tmp_path / "entry.cache.json")
    art1 = _static_artifact(n=2)
    with open(path, "w") as f:
        f.write(art1.to_json())
    store = make_store()
    e1 = store.add_artifact("entry", path)
    assert e1.version == 1

    eng, _ = make_engine(store=store, max_batch=2)
    eng.submit(req(0, "entry"), req(1, "entry"))
    eng.run_until_drained()
    assert eng.records[-1].version == 1

    # overwrite on disk with a different schedule, then hot-swap
    art2 = _static_artifact(n=4)
    with open(path, "w") as f:
        f.write(art2.to_json())
    e2 = store.reload("entry")
    assert e2.version == 2
    assert e2.schedule.fingerprint() != e1.schedule.fingerprint()

    eng.submit(req(2, "entry"), req(3, "entry"))
    eng.run_until_drained()
    assert eng.records[-1].version == 2
    assert len(eng.results) == 4


def test_hot_swap_of_invalid_artifact_keeps_old_entry(tmp_path):
    path = str(tmp_path / "entry.cache.json")
    with open(path, "w") as f:
        f.write(_static_artifact(n=2).to_json())
    store = make_store()
    store.add_artifact("entry", path)

    # replacement calibrated for a different deployment must be refused
    with open(path, "w") as f:
        f.write(_static_artifact(num_steps=13).to_json())
    with pytest.raises(ValueError, match="solver"):
        store.reload("entry")
    assert store.get("entry").version == 1          # old entry still serves
    assert store.get("entry").schedule.num_steps == 8


def test_reload_keeps_policy_override(tmp_path):
    """An entry added with a policy override (e.g. serving an adaptive
    artifact's static base schedule) must keep that override across a
    hot swap — not silently flip back to the artifact's stored policy."""
    path = str(tmp_path / "entry.cache.json")
    with open(path, "w") as f:
        f.write(_adaptive_artifact().to_json())
    store = make_store()
    e1 = store.add_artifact("entry", path, policy="static:n=2")
    assert not e1.adaptive
    e2 = store.reload("entry")
    assert e2.version == 2
    assert not e2.adaptive                     # override survived the swap
    assert e2.policy.spec() == e1.policy.spec()


def test_reload_of_policy_entry_needs_explicit_source():
    store = make_store(static2="static:n=2")
    with pytest.raises(ValueError, match="path"):
        store.reload("static2")


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_percentile_interpolates():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == pytest.approx(2.5)
    assert percentile([7.0], 95) == 7.0
    with pytest.raises(ValueError):
        percentile([], 50)


def test_queue_wait_and_service_reported_separately():
    eng, clock = make_engine(max_batch=1, max_inflight=1)
    eng.submit(req(0, "no_cache", arrival=0.0),
               req(1, "no_cache", arrival=0.0))
    eng.run_until_drained()
    rep = eng.report()
    assert rep["requests"] == 2
    # the fake charges 1.0 virtual second per full-compute step (8 steps):
    # both service times are 8s; the second request queues behind the first
    assert rep["service_s"]["p50"] == pytest.approx(8.0)
    assert rep["queue_wait_s"]["max"] == pytest.approx(8.0)
    assert rep["queue_wait_s"]["p50"] == pytest.approx(4.0)  # mean of 0, 8
    assert rep["makespan_s"] == pytest.approx(16.0)
    assert rep["throughput_rps"] == pytest.approx(2 / 16.0)
    json.dumps(rep)                                  # JSON-safe


def test_report_includes_compile_counts_and_budget():
    eng, _ = make_engine(max_batch=4)
    eng.submit(*[req(i, "static2") for i in range(6)])
    eng.run_until_drained()
    rep = eng.report()
    assert rep["compiles"]["xla_programs"] > 0
    assert rep["compiles"]["xla_programs"] <= rep["program_budget"]
    assert rep["buckets"] == {"2": 1, "4": 1}


def test_realized_compute_fraction_static():
    eng, _ = make_engine(max_batch=2)
    eng.submit(req(0, "static2"), req(1, "static2"))
    eng.run_until_drained()
    sch = eng.store.get("static2").schedule
    expect = float(np.mean([1.0 - np.mean(v)
                            for v in sch.skip.values()]))
    assert eng.report()["compute_fraction"] == pytest.approx(expect)


# ---------------------------------------------------------------------------
# Poisson arrivals (pure)
# ---------------------------------------------------------------------------

def test_poisson_arrivals_reproducible_and_increasing():
    rng1 = np.random.RandomState(3)
    rng2 = np.random.RandomState(3)
    a = serve.poisson_arrivals(2.0, 50, rng1, start=1.0)
    b = serve.poisson_arrivals(2.0, 50, rng2, start=1.0)
    assert a == b
    assert all(x < y for x, y in zip(a, a[1:]))
    assert a[0] > 1.0
    # mean gap ≈ 1/rate
    gaps = np.diff([1.0] + a)
    assert 0.2 < float(np.mean(gaps)) < 1.0
    with pytest.raises(ValueError):
        serve.poisson_arrivals(0.0, 5, rng1)


# ---------------------------------------------------------------------------
# End-to-end: served latents ≡ direct pipeline.generate (smoke DiT)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dit():
    import jax
    from repro import configs
    from repro.core import diffusion
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape),
        params)
    return cfg, params


def test_served_latents_bit_identical_to_generate(small_dit, tmp_path):
    """Acceptance: a heterogeneous queue mixing a static and an adaptive
    policy drains through the engine within the compile budget, and every
    served latent equals a direct ``DiffusionPipeline.generate`` replay of
    its micro-batch, bitwise."""
    import jax
    import jax.numpy as jnp
    from repro import cache
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    steps = 6

    # offline calibration process → artifact on disk
    calib = cache.DiffusionPipeline(
        cfg, solvers.ddim(steps),
        "adaptive:base=smoothcache(alpha=0.5),tau=0.3", cfg_scale=1.5)
    calib.calibrate(params, jax.random.PRNGKey(1), 2,
                    cond_args={"label": jnp.zeros((2,), jnp.int32)})
    path = str(tmp_path / "adaptive.cache.json")
    calib.save_artifact(path)

    # serving process: store + engine, never recalibrates
    solver = solvers.ddim(steps)
    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
    store = serve.ArtifactStore(cfg, solver, cfg_scale=1.5)
    store.add_policy("static2", "static:n=2")
    store.add_artifact("adaptive", path)
    eng = serve.ServeEngine(ex, params, store, max_batch=2, max_inflight=2,
                            clock=serve.VirtualClock(), check=True)
    eng.submit(*[serve.Request(
        rid=i, seed=100 + i,
        policy="adaptive" if i % 2 else "static2",
        label=i % cfg.num_classes, arrival=0.0) for i in range(5)])
    res = eng.run_until_drained()
    assert sorted(res) == list(range(5))
    assert {r.group for r in eng.records} == {"static2", "adaptive"}

    # compile budget: ≤ |buckets used| × signature pool size
    rep = eng.report()
    assert rep["compiles"]["xla_programs"] <= rep["program_budget"]

    # adaptive batches were served through the fused on-device path:
    # one switch program, no per-signature dispatch programs, zero
    # per-step decision syncs
    assert ex.compiled_variant_count("fused") >= 1
    assert ex.compiled_variant_count("sigstep") == 0
    assert ex.host_sync_count == 0

    # replay every micro-batch through the pipeline facade
    static_pipe = cache.DiffusionPipeline(cfg, solvers.ddim(steps),
                                          "static:n=2", cfg_scale=1.5)
    static_pipe.prepare()
    adaptive_pipe = cache.DiffusionPipeline(
        cfg, solvers.ddim(steps),
        "adaptive:base=smoothcache(alpha=0.5),tau=0.3", cfg_scale=1.5)
    adaptive_pipe.load_artifact(path)
    for rec in eng.records:
        key = serve.batch_key(rec.seeds)
        lab = jnp.asarray(rec.labels, jnp.int32)
        if rec.group == "adaptive":
            x, dec = adaptive_pipe.generate(params, key, rec.bucket,
                                            label=lab,
                                            return_decisions=True)
            assert dec == rec.decisions
        else:
            x = static_pipe.generate(params, key, rec.bucket, label=lab)
        for j, rid in enumerate(rec.rids):
            np.testing.assert_array_equal(np.asarray(x[j]), res[rid])
