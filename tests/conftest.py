import os

# Tests run on the single local CPU device (the dry-run, and ONLY the
# dry-run, forces 512 placeholder devices — see src/repro/launch/dryrun.py).
os.environ.setdefault("REPRO_KERNEL_INTERPRET", "1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
