import os

# Tests run on the single local CPU device (the dry-run, and ONLY the
# dry-run, forces 512 placeholder devices — see src/repro/launch/dryrun.py).
os.environ.setdefault("REPRO_KERNEL_INTERPRET", "1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")

# Modules dominated by end-to-end model runs (sampling loops, kernels,
# sharded programs).  Together with every test that instantiates the smoke
# DiT (the `small_dit` fixture) they form the `slow` set that `--fast`
# skips — the CI lane for doc-only changes keeps the pure-logic tests
# (schedule math, plan analysis, registry/spec grammar, serialization).
SLOW_MODULES = {
    "test_system", "test_smoke_archs", "test_sharding", "test_kernels",
    "test_smoothcache", "test_models",
}


def pytest_addoption(parser):
    parser.addoption(
        "--fast", action="store_true", default=False,
        help="skip slow (model-running) tests — the doc-only CI lane")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: end-to-end model tests skipped under --fast")
    config.addinivalue_line(
        "markers", "chaos: seeded fault-injection tests (the CI chaos "
        "lane runs `-m chaos` over the fixed seed matrix)")
    config.addinivalue_line(
        "markers", "obs: observability tests — tracer/registry/cache-"
        "report units plus the zero-sync telemetry regression (the CI "
        "obs lane runs `-m obs`)")
    config.addinivalue_line(
        "markers", "durability: seeded kill–restart durability tests "
        "(the CI durability lane runs `-m durability` over the "
        "kill-seed matrix)")


def pytest_collection_modifyitems(config, items):
    for item in items:
        mod = item.module.__name__.rsplit(".", 1)[-1]
        if mod in SLOW_MODULES or "small_dit" in getattr(
                item, "fixturenames", ()):
            item.add_marker(pytest.mark.slow)
    if config.getoption("--fast"):
        skip = pytest.mark.skip(reason="--fast: slow test skipped")
        for item in items:
            if item.get_closest_marker("slow") is not None:
                item.add_marker(skip)
