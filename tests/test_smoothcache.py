"""SmoothCache core: schedule generation properties (hypothesis), executor
equivalence, calibration error-curve invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.core import calibration, diffusion, schedule as S, solvers
from repro.core.executor import SmoothCacheExecutor


# ---------------------------------------------------------------------------
# Schedule properties
# ---------------------------------------------------------------------------

def _curves(err_rows, k_max=3):
    """Build an (S, K+1) curve array from per-step base errors, err at lag k
    = base * k (monotone in k)."""
    s = len(err_rows)
    out = np.full((s, k_max + 1), np.nan)
    out[:, 0] = 0.0
    for i in range(s):
        for k in range(1, min(k_max, i) + 1):
            out[i, k] = err_rows[i] * k
    return {"attn": out}


@given(st.lists(st.floats(0.0, 1.0), min_size=2, max_size=64),
       st.floats(0.01, 2.0), st.integers(1, 5))
@settings(max_examples=200, deadline=None)
def test_schedule_invariants(rows, alpha, k_max):
    sch = S.smoothcache(_curves(rows, k_max), alpha, k_max)
    v = sch.skip["attn"]
    assert not v[0], "step 0 must always compute"
    # no skip-run longer than k_max
    run = 0
    for b in v:
        run = run + 1 if b else 0
        assert run <= k_max
    assert sch.num_steps == len(rows)


@given(st.lists(st.floats(0.0, 1.0), min_size=2, max_size=48),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0))
@settings(max_examples=100, deadline=None)
def test_schedule_monotone_in_alpha(rows, a1, a2):
    """With lag-monotone error curves, a larger α never computes more."""
    lo, hi = min(a1, a2), max(a1, a2)
    c = _curves(rows)
    s_lo = S.smoothcache(c, lo)
    s_hi = S.smoothcache(c, hi)
    assert s_hi.skip["attn"].sum() >= s_lo.skip["attn"].sum()


def test_alpha_zero_never_skips():
    rows = [0.5] * 20
    sch = S.smoothcache(_curves(rows), 0.0)
    assert sch.skip["attn"].sum() == 0


def test_alpha_huge_skips_max():
    rows = [0.1] * 21
    sch = S.smoothcache(_curves(rows), 1e9, k_max=3)
    # compute every 4th step: steps 0,4,8,... → 16 skips of 21 steps
    assert sch.skip["attn"].sum() == 15 or sch.skip["attn"].sum() == 16


def test_fora_uniform():
    sch = S.fora(["attn", "ffn"], 50, 2)
    for t in ("attn", "ffn"):
        assert not sch.skip[t][0]
        assert sch.skip[t][1::2].all()
        assert not sch.skip[t][2::2].any()


def test_alpha_for_budget_search():
    rng = np.random.RandomState(0)
    rows = list(rng.uniform(0.05, 0.5, size=50))
    curves = _curves(rows)
    alpha = S.alpha_for_budget(curves, target_compute_fraction=0.6)
    sch = S.smoothcache(curves, alpha)
    assert abs(sch.compute_fraction("attn") - 0.6) < 0.15


def test_schedule_json_roundtrip():
    sch = S.fora(["attn"], 10, 3)
    sch2 = S.Schedule.from_json(sch.to_json())
    assert (sch2.skip["attn"] == sch.skip["attn"]).all()
    assert sch2.num_steps == 10


# ---------------------------------------------------------------------------
# Executor equivalence + calibration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dit():
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    # perturb zero-inits so branches matter
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7), a.shape),
        params)
    return cfg, params


def test_noskip_schedule_equals_plain(small_dit):
    cfg, params = small_dit
    ex = SmoothCacheExecutor(cfg, solvers.ddim(6), cfg_scale=1.5)
    label = jnp.zeros((2,), jnp.int32)
    sch = S.no_cache(cfg.layer_types(), 6)
    x1 = ex.sample(params, jax.random.PRNGKey(1), 2, schedule=sch, label=label)
    x2 = ex.sample(params, jax.random.PRNGKey(1), 2, schedule=None, label=label)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


def test_cached_sampling_close_but_cheaper(small_dit):
    cfg, params = small_dit
    ex = SmoothCacheExecutor(cfg, solvers.ddim(8), cfg_scale=1.5)
    label = jnp.zeros((2,), jnp.int32)
    curves, _, _ = calibration.calibrate(
        ex, params, jax.random.PRNGKey(1), 2, cond_args={"label": label})
    sch = S.smoothcache(curves, alpha=0.5, k_max=3)
    assert any(v.any() for v in sch.skip.values()), "expect some skips"
    xc = ex.sample(params, jax.random.PRNGKey(2), 2, schedule=sch, label=label)
    xp = ex.sample(params, jax.random.PRNGKey(2), 2, schedule=None, label=label)
    assert bool(jnp.all(jnp.isfinite(xc)))
    rel = float(jnp.linalg.norm(xc - xp) / (jnp.linalg.norm(xp) + 1e-9))
    assert rel < 0.5, f"cached output diverged wildly: {rel}"


def test_calibration_curve_invariants(small_dit):
    cfg, params = small_dit
    ex = SmoothCacheExecutor(cfg, solvers.ddim(6))
    curves, per_sample, _ = calibration.calibrate(
        ex, params, jax.random.PRNGKey(3), 3,
        cond_args={"label": jnp.zeros((3,), jnp.int32)})
    for t, c in curves.items():
        assert c.shape == (6, 4)
        assert np.allclose(c[:, 0], 0.0)          # lag 0 → zero error
        assert np.isnan(c[0, 1])                  # no lag-1 at step 0
        valid = c[1:, 1]
        assert np.all(valid[np.isfinite(valid)] >= 0)
        assert per_sample[t].shape == (3, 6, 4)


def test_solver_step_counts(small_dit):
    cfg, params = small_dit
    for mk in (solvers.ddim(5), solvers.rectified_flow(5),
               solvers.dpmpp_3m_sde(5)):
        ex = SmoothCacheExecutor(cfg, mk)
        x = ex.sample(params, jax.random.PRNGKey(0), 1,
                      label=jnp.zeros((1,), jnp.int32))
        assert x.shape == (1,) + tuple(cfg.latent_shape)
        assert bool(jnp.all(jnp.isfinite(x)))


def test_distinct_masks_bounded(small_dit):
    """Compiled-variant count is bounded by 2^|types| (graph-compilation
    compatibility claim of the paper §2.2)."""
    cfg, params = small_dit
    types = cfg.layer_types()
    rng = np.random.RandomState(0)
    sch = S.Schedule(
        {t: np.r_[False, rng.rand(9) < 0.5] for t in types}, 10)
    assert len(sch.distinct_masks()) <= 2 ** len(types)
