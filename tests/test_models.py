"""Unit tests for the model substrate: attention variants, MoE dispatch,
SSM/RG-LRU recurrences, norms, RoPE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.config import (AttentionSpec, BlockSpec, MLPSpec, MoESpec,
                          RGLRUSpec, SSMSpec)
from repro.kernels.ref import flash_attention_ref
from repro.models import attention, layers as L, moe, rglru, ssm


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

def test_rmsnorm_unit_scale():
    p = L.rmsnorm_init(16)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 10
    y = L.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_layernorm_standardizes():
    p = L.layernorm_init(32)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 3 + 5
    y = L.layernorm(p, x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 32))
    pos = jnp.arange(8)[None]
    y = L.apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    def dot_at(p1, p2):
        qr = L.apply_rope(q, jnp.array([[p1]]))
        kr = L.apply_rope(k, jnp.array([[p2]]))
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(3, 5) - dot_at(10, 12)) < 1e-3


def test_softcap_bounded():
    x = jnp.linspace(-1000, 1000, 101)
    y = L.softcap(x, 50.0)
    assert float(jnp.max(jnp.abs(y))) <= 50.0


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def test_gqa_matches_ref():
    spec = AttentionSpec(num_heads=8, num_kv_heads=2, head_dim=16,
                         causal=True, pos_emb="none")
    p = attention.init(jax.random.PRNGKey(0), spec, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64))
    out, (k, v) = attention.apply(spec, p, x)
    q = (x @ p["wq"]).reshape(2, 12, 8, 16)
    ref = flash_attention_ref(q, k, v, causal=True)
    ref = ref.reshape(2, 12, -1) @ p["wo"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_chunked_attention_matches_unchunked():
    """The long-sequence query-chunked path must equal plain SDPA."""
    spec = AttentionSpec(num_heads=4, num_kv_heads=2, head_dim=16,
                         causal=True, window=50)
    p = attention.init(jax.random.PRNGKey(0), spec, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 130, 64))
    out_plain, _ = attention.apply(spec, p, x)
    old_thr, old_cq = attention.CHUNK_THRESHOLD, attention.CHUNK_Q
    try:
        attention.CHUNK_THRESHOLD, attention.CHUNK_Q = 64, 32
        out_chunk, _ = attention.apply(spec, p, x)
    finally:
        attention.CHUNK_THRESHOLD, attention.CHUNK_Q = old_thr, old_cq
    np.testing.assert_allclose(np.asarray(out_plain), np.asarray(out_chunk),
                               atol=2e-4, rtol=1e-3)


def test_mla_decode_absorption_matches_full():
    """Absorbed-matmul decode == expanded full attention at the last token."""
    spec = AttentionSpec(kind="mla", num_heads=4, causal=True,
                         q_lora_rank=32, kv_lora_rank=32, rope_head_dim=8,
                         nope_head_dim=16, v_head_dim=16)
    p = attention.init(jax.random.PRNGKey(0), spec, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, 64))
    full, _ = attention.apply(spec, p, x)
    # prefill 8, decode token 8
    _, (ckv, krope) = attention.apply(spec, p, x[:, :8])
    cache = {
        "ckv": jnp.pad(ckv, ((0, 0), (0, 1), (0, 0))),
        "krope": jnp.pad(krope, ((0, 0), (0, 1), (0, 0))),
    }
    slots = jnp.r_[np.arange(8), -1].astype(jnp.int32)
    out, newc = attention.apply(spec, p, x[:, 8:9], mode="decode", pos=8,
                                cache=cache, slot_pos=slots)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, 8]),
                               atol=1e-4, rtol=1e-3)
    assert int(newc["slots"][8]) == 8


def test_sliding_window_blocks_old_tokens():
    spec = AttentionSpec(num_heads=2, num_kv_heads=2, head_dim=8, causal=True,
                         window=4, pos_emb="none")
    p = attention.init(jax.random.PRNGKey(0), spec, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 20, 16))
    out_full, _ = attention.apply(spec, p, x)
    # perturbing tokens outside the window must not change the last output
    x2 = x.at[:, :10].set(jax.random.normal(jax.random.PRNGKey(2), (1, 10, 16)))
    out2, _ = attention.apply(spec, p, x2)
    np.testing.assert_allclose(np.asarray(out_full[:, -1]),
                               np.asarray(out2[:, -1]), atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@given(st.integers(2, 8), st.integers(1, 3), st.sampled_from(["softmax", "sigmoid"]))
@settings(max_examples=20, deadline=None)
def test_moe_gshard_matches_dense(e, k, router):
    k = min(k, e)
    spec = MoESpec(num_experts=e, top_k=k, d_ff=32, capacity_factor=8.0,
                   router=router)
    p = moe.init(jax.random.PRNGKey(e * 7 + k), spec, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    yd, auxd = moe.apply_dense(spec, p, x)
    yg, auxg = moe.apply_gshard(spec, p, x, group_size=16)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yg), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(float(auxd), float(auxg), rtol=1e-4)


def test_moe_capacity_drops_tokens():
    """With capacity 8 rounded minimum, tiny capacity factor must drop."""
    spec = MoESpec(num_experts=2, top_k=1, d_ff=16, capacity_factor=0.01)
    p = moe.init(jax.random.PRNGKey(0), spec, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))
    yg, _ = moe.apply_gshard(spec, p, x, group_size=64)
    yd, _ = moe.apply_dense(spec, p, x)
    # some tokens got zero output (dropped)
    norms = jnp.linalg.norm(yg, axis=-1)
    assert float(jnp.min(norms)) < 1e-6


def test_moe_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives aux ≈ E · E·(1/E·1/E) = 1."""
    e = 4
    spec = MoESpec(num_experts=e, top_k=1, d_ff=8)
    probs = jnp.full((1, 64, e), 1.0 / e)
    idx = jnp.arange(64).reshape(1, 64, 1) % e
    aux = moe.load_balance_loss(spec, probs, idx)
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-5)


def test_moe_shared_expert_always_applied():
    spec = MoESpec(num_experts=2, top_k=1, d_ff=16, num_shared=1,
                   d_ff_shared=16, capacity_factor=8.0)
    p = moe.init(jax.random.PRNGKey(0), spec, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 8))
    y_with, _ = moe.apply_gshard(spec, p, x, group_size=4)
    p2 = dict(p)
    p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    y_without, _ = moe.apply_gshard(spec, p2, x, group_size=4)
    assert float(jnp.max(jnp.abs(y_with - y_without))) > 1e-6


# ---------------------------------------------------------------------------
# recurrences
# ---------------------------------------------------------------------------

def test_rglru_scan_matches_stepwise():
    spec = RGLRUSpec(num_heads=2, conv_width=4)
    d = 16
    p = rglru.init(jax.random.PRNGKey(0), spec, d)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d))
    y_full, cache_full = rglru.apply_full(spec, p, x, d)
    cache = rglru.init_cache(spec, d, 2)
    outs = []
    for t in range(12):
        yt, cache = rglru.apply_decode(spec, p, x[:, t:t+1], cache, d)
        outs.append(yt)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(cache_full["h"]),
                               np.asarray(cache["h"]), atol=1e-4)


def test_ssm_full_matches_stepwise():
    spec = SSMSpec(d_state=8, d_conv=4, expand=2, head_dim=8, chunk=4)
    d = 16
    p = ssm.init(jax.random.PRNGKey(0), spec, d)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    y_full, cache_full = ssm.apply_full(spec, p, x, d)
    cache = ssm.init_cache(spec, d, 2)
    outs = []
    for t in range(8):
        yt, cache = ssm.apply_decode(spec, p, x[:, t:t+1], cache, d)
        outs.append(yt)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               atol=1e-3, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(cache_full["ssm"]),
                               np.asarray(cache["ssm"]), atol=1e-3, rtol=1e-2)
