"""Hypothesis compatibility shim.

The seed environment does not ship ``hypothesis`` and tier-1 must run
without installing anything.  When hypothesis is available we re-export it
unchanged; otherwise we fall back to a minimal deterministic property
runner covering exactly the strategy surface these tests use
(``floats`` / ``integers`` / ``booleans`` / ``lists`` / ``sampled_from``):
each ``@given``
test is executed on a fixed-seed sample of inputs plus the interval
endpoints.  No shrinking, no database — just enough to keep the property
tests meaningful on a bare environment.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import functools
    import inspect

    import numpy as np

    _FALLBACK_MAX_EXAMPLES = 25

    class _Strategy:
        def __init__(self, sample, endpoints=()):
            self.sample = sample            # rng -> value
            self.endpoints = tuple(endpoints)

    class st:  # noqa: N801 - mimics `strategies as st`
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                endpoints=(float(min_value), float(max_value)))

        @staticmethod
        def booleans(**_):
            return _Strategy(lambda rng: bool(rng.randint(2)),
                             endpoints=(False, True))

        @staticmethod
        def integers(min_value=0, max_value=10, **_):
            return _Strategy(
                lambda rng: int(rng.randint(min_value, max_value + 1)),
                endpoints=(int(min_value), int(max_value)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_):
            def sample(rng):
                n = int(rng.randint(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(n)]
            return _Strategy(sample)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randint(len(seq))],
                             endpoints=(seq[0], seq[-1]))

    def settings(max_examples=_FALLBACK_MAX_EXAMPLES, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            n = min(getattr(fn, "_max_examples", _FALLBACK_MAX_EXAMPLES),
                    _FALLBACK_MAX_EXAMPLES)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.RandomState(0)
                # endpoint combos first (diagonal, not the full product)
                for i in range(max(len(s.endpoints) for s in strats)):
                    vals = [s.endpoints[min(i, len(s.endpoints) - 1)]
                            if s.endpoints else s.sample(rng)
                            for s in strats]
                    fn(*args, *vals, **kwargs)
                for _ in range(n):
                    fn(*args, *[s.sample(rng) for s in strats], **kwargs)
            # hide the strategy parameters from pytest's fixture resolution
            wrapper.__signature__ = inspect.Signature()
            wrapper.__wrapped__ = None
            del wrapper.__wrapped__
            return wrapper
        return deco
