"""Per-kernel allclose sweeps vs. the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref, ssd_ref, ssd_sequential_ref
from repro.kernels.ssd import ssd


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("b,l,h,kv,d", [
    (2, 64, 4, 4, 32),
    (2, 64, 4, 1, 32),      # MQA
    (1, 96, 8, 2, 64),      # GQA 4:1
    (1, 128, 16, 8, 64),
    (2, 40, 4, 2, 16),      # non-multiple length → padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, l, h, kv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(l * h + d), 3)
    q = jax.random.normal(ks[0], (b, l, h, d), dtype)
    k = jax.random.normal(ks[1], (b, l, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, l, kv, d), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None),
    (True, 16, None),
    (True, None, 50.0),
    (False, None, None),
    (True, 8, 30.0),
])
def test_flash_attention_masks(causal, window, softcap):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=16, block_k=16,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=5e-5)


@pytest.mark.parametrize("b,l,h,p,g,n,chunk", [
    (2, 64, 4, 16, 1, 16, 16),
    (1, 96, 8, 32, 2, 32, 32),
    (2, 33, 2, 16, 1, 8, 16),   # padding path
    (1, 16, 2, 8, 2, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel(b, l, h, p, g, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(l + h), 5)
    x = jax.random.normal(ks[0], (b, l, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)) - 1.0)
    a = jnp.exp(jax.random.uniform(ks[2], (h,), minval=0.0, maxval=1.0))
    bb = jax.random.normal(ks[3], (b, l, g, n), dtype)
    cc = jax.random.normal(ks[4], (b, l, g, n), dtype)
    y, hT = ssd(x, dt, a, bb, cc, chunk=chunk, interpret=True)
    ys, hTs = ssd_sequential_ref(x, dt, a, bb, cc)
    tol = dict(atol=1e-1, rtol=1e-1) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ys, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTs),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=1e-2)


def test_ssd_chunked_oracle_matches_sequential():
    """The model's jnp chunked path is itself validated against the O(L)
    recurrence (two independent oracles)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (2, 64, 4, 16))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 64, 4)))
    a = jnp.exp(jax.random.uniform(ks[2], (4,), minval=0.0, maxval=1.5))
    bb = jax.random.normal(ks[3], (2, 64, 1, 16))
    cc = jax.random.normal(ks[4], (2, 64, 1, 16))
    yc, hc = ssd_ref(x, dt, a, bb, cc, chunk=16)
    ys, hs = ssd_sequential_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hs), atol=1e-4,
                               rtol=1e-3)


def test_ssd_initial_state():
    """h0 threading matches splitting a sequence in two."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (1, 32, 2, 8))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 32, 2)))
    a = jnp.exp(jax.random.uniform(ks[2], (2,), minval=0.0, maxval=1.0))
    bb = jax.random.normal(ks[3], (1, 32, 1, 8))
    cc = jax.random.normal(ks[4], (1, 32, 1, 8))
    y_full, h_full = ssd_ref(x, dt, a, bb, cc, chunk=8)
    y1, h1 = ssd_ref(x[:, :16], dt[:, :16], a, bb[:, :16], cc[:, :16], chunk=8)
    y2, h2 = ssd_ref(x[:, 16:], dt[:, 16:], a, bb[:, 16:], cc[:, 16:],
                     chunk=8, h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y2),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2), atol=1e-5,
                               rtol=1e-4)


def test_model_forward_with_flash_kernel_matches():
    """use_flash=True routes attention through the Pallas kernel (interpret
    mode on CPU) — must match the jnp path through a whole model."""
    import os
    os.environ["REPRO_KERNEL_INTERPRET"] = "1"
    from repro import configs
    from repro.models import transformer as T

    cfg = configs.get("qwen3-14b", "smoke")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    ref, _ = T.forward(cfg, params, toks, use_flash=False)
    out, _ = T.forward(cfg, params, toks, use_flash=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_ssm_model_with_kernel_matches():
    import os
    os.environ["REPRO_KERNEL_INTERPRET"] = "1"
    from repro import configs
    from repro.models import transformer as T

    cfg = configs.get("mamba2-1.3b", "smoke")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    ref, _ = T.forward(cfg, params, toks, use_flash=False)
    out, _ = T.forward(cfg, params, toks, use_flash=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)
