"""repro.durable — crash-safe serving: write-ahead request journal,
boundary run-state snapshots, and kill–restart recovery.

Units cover the hardened checkpoint IO (refusals, never garbage), the
journal's torn-tail sealing and replay fold, and the seeded KillPlan.
Engine tests run a virtual-clock fake with the export/import seam
(restore-from-snapshot, quarantine of tampered/torn snapshots with a
reasoned health entry, journal-backed ``outcome`` across restarts, the
seeded kill matrix under ``-m durability``), then the smoke DiT proves
the real contract: a run exported at a boundary, saved, restored, and
advanced to completion is bit-identical to never having crashed — for
all three run kinds, through the engine, including a mid-join restore
and the replay-from-start fallback."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro import serve
from repro.cache.artifact import CacheArtifact
from repro.core import plan as plan_lib
from repro.core import schedule as S
from repro.durable import (FORMAT, JournalState, KillPlan, RequestJournal,
                           SnapshotError, SnapshotStore, crash,
                           drain_with_kills, replay)

try:
    import msgpack  # noqa: F401
    _HAVE_MSGPACK = True
except ImportError:                            # pragma: no cover
    _HAVE_MSGPACK = False

needs_msgpack = pytest.mark.skipif(
    not _HAVE_MSGPACK, reason="checkpoint IO needs msgpack")


# ---------------------------------------------------------------------------
# Checkpoint IO: refusals, not garbage
# ---------------------------------------------------------------------------

@needs_msgpack
def test_checkpoint_roundtrip_with_nones_and_meta(tmp_path):
    from repro.checkpoint import io as ckpt_io
    tree = {"x": np.arange(6, dtype=np.float32).reshape(2, 3),
            "nested": {"a": np.ones((1,), np.int32), "gap": None},
            "pair": (np.zeros((2,), np.float64), None)}
    path = str(tmp_path / "t.ckpt")
    ckpt_io.save(path, tree, {"kind": "unit", "step": 3})
    out, meta = ckpt_io.restore(path)
    assert meta["kind"] == "unit" and meta["step"] == 3
    np.testing.assert_array_equal(out["x"], tree["x"])
    assert out["nested"]["gap"] is None
    assert isinstance(out["pair"], tuple) and out["pair"][1] is None
    # header-only read never touches the body
    assert ckpt_io.read_meta(path)["kind"] == "unit"


@needs_msgpack
@pytest.mark.parametrize("mutilate,match", [
    (lambda b: b"NOTACKPT!!" + b[10:], "magic"),
    (lambda b: b[:12], "truncated"),
    (lambda b: b[:-5], "torn|truncated|declares"),
    (lambda b: b[:-1] + bytes([b[-1] ^ 0xFF]), "sha256|checksum"),
])
def test_checkpoint_refuses_torn_and_tampered(tmp_path, mutilate, match):
    """Bad magic, truncated header, torn body, and flipped body bits all
    raise CheckpointError — never a silently-short array."""
    from repro.checkpoint import CheckpointError, io as ckpt_io
    path = str(tmp_path / "t.ckpt")
    ckpt_io.save(path, {"x": np.arange(32, dtype=np.float32)}, {})
    raw = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(mutilate(raw))
    with pytest.raises(CheckpointError, match=match):
        ckpt_io.restore(path)


@needs_msgpack
def test_checkpoint_atomic_publish_leaves_no_tmp(tmp_path):
    from repro.checkpoint import io as ckpt_io
    path = str(tmp_path / "t.ckpt")
    ckpt_io.save(path, {"x": np.ones((4,), np.float32)}, {})
    assert os.listdir(tmp_path) == ["t.ckpt"]


# ---------------------------------------------------------------------------
# Write-ahead journal
# ---------------------------------------------------------------------------

def test_journal_roundtrip_preserves_rid_types(tmp_path):
    path = str(tmp_path / "j.jsonl")
    j = RequestJournal(path)
    j.append("submit", rid=7, seed=7, policy="p", arrival=0.0)
    j.append("finish", sync=True, rids=[7], t=1.5)
    j.close()
    events, skipped = replay(path)
    assert skipped == 0
    assert [e["ev"] for e in events] == ["submit", "finish"]
    assert events[0]["rid"] == 7               # int in, int out
    st = JournalState.replay(path)
    assert st.pending() == {} and st.done == {7: 1.5}


def test_journal_seals_torn_tail(tmp_path):
    """A crash mid-write leaves a half line; reopening seals it so it
    fails its checksum at replay instead of merging with the next
    append."""
    path = str(tmp_path / "j.jsonl")
    j = RequestJournal(path)
    j.append("submit", rid=1, seed=1, policy="p", arrival=0.0)
    j.close()
    with open(path, "ab") as f:                # the torn write
        f.write(b'deadbeef0000 {"ev": "fini')
    j2 = RequestJournal(path)
    assert j2.sealed_tail
    j2.append("shed", rid=1, reason="late", t=2.0)
    j2.close()
    events, skipped = replay(path)
    assert skipped == 1                        # the torn line, counted
    assert [e["ev"] for e in events] == ["submit", "shed"]
    st = JournalState.replay(path)
    assert st.skipped == 1 and st.shed[1] == ("late", 2.0)


def test_journal_fold_retry_and_pending(tmp_path):
    path = str(tmp_path / "j.jsonl")
    j = RequestJournal(path)
    j.append_many([
        {"ev": "submit", "rid": 1, "seed": 1, "policy": "a", "arrival": 0.0},
        {"ev": "submit", "rid": 2, "seed": 2, "policy": "a", "arrival": 0.5},
    ])
    j.append("launch", sync=False, serial=0, rids=[1, 2], t=1.0)
    j.append("retry", sync=False, rid=2, attempt=1, policy="fallback",
             level=1, t=2.0)
    j.append("finish", rids=[1], t=3.0)
    j.close()
    st = JournalState.replay(path)
    assert st.started == {1: 1.0, 2: 1.0}
    assert st.attempts == {2: 1} and st.levels == {2: 1}
    # retry rewrote the pending record's policy — replay resubmits the
    # degraded policy, not the one that faulted
    assert st.pending() == {2: dict(st.submitted[2])}
    assert st.submitted[2]["policy"] == "fallback"


def test_journal_append_many_validates_ev(tmp_path):
    j = RequestJournal(str(tmp_path / "j.jsonl"))
    with pytest.raises(ValueError, match="'ev'"):
        j.append_many([{"rid": 1}])
    j.close()


def test_journal_fsync_on_ack_only(tmp_path):
    j = RequestJournal(str(tmp_path / "j.jsonl"))
    j.append("submit", rid=1, seed=1, policy="p", arrival=0.0)  # ack
    j.append("launch", sync=False, serial=0, rids=[1], t=0.0)   # progress
    assert j.appended == 2 and j.synced == 1
    j.close()


# ---------------------------------------------------------------------------
# KillPlan: seeded, memoized, bounded
# ---------------------------------------------------------------------------

def test_kill_plan_seeded_and_deterministic():
    a = KillPlan(seed=3, kill_rate=0.3)
    b = KillPlan(seed=3, kill_rate=0.3)
    assert [a.should_kill(t) for t in range(50)] \
        == [b.should_kill(t) for t in range(50)]
    assert any(a._memo.values())               # the rate actually fires


def test_kill_plan_overrides_and_bounds():
    p = KillPlan(seed=0, kill_rate=0.0, kills={4}, max_kills=1)
    assert not p.should_kill(3)
    assert p.should_kill(4)                    # explicit strike
    assert not p.should_kill(4)                # max_kills exhausted
    with pytest.raises(ValueError, match="kill_rate"):
        KillPlan(kill_rate=1.5)


# ---------------------------------------------------------------------------
# Virtual serving stack with the export/import seam (house fake + snapshot
# protocol, the shape the real SmoothCacheExecutor implements)
# ---------------------------------------------------------------------------

class _Cfg:
    name = "fake-arch"

    def layer_types(self):
        return ("attn", "ffn")


class _Solver:
    name = "ddim"

    def __init__(self, num_steps=8):
        self.num_steps = num_steps


@dataclasses.dataclass
class _RunState:
    plan: plan_lib.ExecutionPlan
    batch: int
    run_index: int = 0
    x: object = None
    decisions = None

    @property
    def done(self):
        return self.run_index >= len(self.plan.runs)


@dataclasses.dataclass
class _AdaptiveState:
    schedule: object
    batch: int
    step: int = 0
    x: object = None
    decisions: tuple = ()

    @property
    def done(self):
        return self.step >= self.schedule.num_steps


class DurableFakeExecutor:
    """test_serve's virtual-clock fake plus the run-state snapshot seam
    (``supports_export`` / ``export_run`` / ``import_run``)."""

    supports_export = True

    def __init__(self, clock, step_cost=1.0):
        self.clock = clock
        self.step_cost = step_cost
        self._programs = set()

    def _charge(self, skip, length):
        computed = sum(1 for sk in skip.values() if not sk)
        self.clock.advance(self.step_cost * length
                           * computed / max(len(skip), 1))

    def start_run(self, params, key, batch, *, plan, schedule=None,
                  label=None, memory=None):
        return _RunState(plan=plan, batch=batch)

    def advance_run(self, params, rs, *, check=False):
        run = rs.plan.runs[rs.run_index]
        self._charge(run.sig.skip, run.length)
        rs = dataclasses.replace(rs, run_index=rs.run_index + 1)
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def start_adaptive_run(self, params, key, batch, *, schedule, tau,
                           proxy_map=None, pool=None, k_max=3, label=None,
                           memory=None):
        return _AdaptiveState(schedule=schedule, batch=batch)

    def advance_adaptive_run(self, params, rs):
        mask = {t: bool(v[rs.step]) for t, v in rs.schedule.skip.items()}
        skipset = tuple(sorted(t for t, sk in mask.items() if sk))
        self._charge(mask, 1)
        rs = dataclasses.replace(rs, step=rs.step + 1,
                                 decisions=rs.decisions + (skipset,))
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def compiled_variant_count(self, kind=None):
        return len(self._programs)

    def xla_program_count(self, kind=None):
        return len(self._programs)

    # -- the snapshot seam ---------------------------------------------------

    def export_run(self, rs):
        if isinstance(rs, _RunState):
            return "plan", {}, {"batch": rs.batch,
                                "run_index": rs.run_index}
        if isinstance(rs, _AdaptiveState):
            return "adaptive", {}, {
                "batch": rs.batch, "step": rs.step,
                "decisions": [list(d) for d in rs.decisions]}
        raise ValueError(f"not exportable: {type(rs).__name__}")

    def import_run(self, params, kind, arrays, static, *, plan=None,
                   schedule=None, tau=0.0, proxy_map=None, pool=None,
                   k_max=3):
        if kind == "plan":
            return _RunState(plan=plan, batch=int(static["batch"]),
                             run_index=int(static["run_index"]))
        if kind == "adaptive":
            return _AdaptiveState(
                schedule=schedule, batch=int(static["batch"]),
                step=int(static["step"]),
                decisions=tuple(tuple(d)
                                for d in static.get("decisions", ())))
        raise ValueError(f"unknown run kind {kind!r}")


def _fake_artifact(num_steps):
    types = ("attn", "ffn")
    sch = S.fora(types, num_steps, 2)
    pool = [list(sig.live_in) for sig in plan_lib.mask_lattice(sch)]
    return CacheArtifact(
        arch="fake-arch", solver="ddim", num_steps=num_steps,
        policy={"name": "adaptive", "base": {"name": "static", "n": 2},
                "tau": 0.1},
        curves={}, schedule=sch,
        plan=plan_lib.analyze(sch).to_jsonable(),
        adaptive={"tau": 0.1, "k_max": 1,
                  "proxy_map": {"coeffs": {"attn": [0.0, 0.01],
                                           "ffn": [0.0, 0.01]},
                                "mean_proxy": None},
                  "pool": pool},
        meta={})


def make_store(num_steps=8):
    store = serve.ArtifactStore(_Cfg(), _Solver(num_steps))
    store.add_policy("static2", "static:n=2")
    store.add_policy("no_cache", "none")
    store.add_artifact("adaptive", _fake_artifact(num_steps))
    return store


def durable_factory(tmp_path, *, num_steps=8, **kw):
    """Fresh-engine factory over one shared journal path + snapshot dir —
    the contract :func:`drain_with_kills` needs."""
    jpath = str(tmp_path / "journal.jsonl")
    sdir = str(tmp_path / "snapshots")

    def make():
        clock = serve.VirtualClock()
        ex = DurableFakeExecutor(clock)
        kw.setdefault("max_batch", 4)
        return serve.ServeEngine(ex, params=None, store=make_store(
            num_steps), clock=clock, journal=jpath, snapshot_dir=sdir,
            **kw)
    return make, jpath, sdir


def vreq(rid, policy, arrival=0.0, seed=None):
    return serve.Request(rid=rid, seed=rid if seed is None else seed,
                         policy=policy, arrival=arrival)


def _step_until(eng, cond, limit=200):
    for _ in range(limit):
        if cond():
            return
        if not eng.step():
            now = eng.clock.now()
            t = eng.batcher.next_event(now)
            assert t is not None and t > now, "drained before condition"
            eng.clock.sleep_until(t)
    raise AssertionError("condition never reached")


# ---------------------------------------------------------------------------
# Engine: journal WAL + outcome across restarts
# ---------------------------------------------------------------------------

@needs_msgpack
def test_submit_is_write_ahead(tmp_path):
    make, jpath, _ = durable_factory(tmp_path)
    eng = make()
    eng.submit(vreq(0, "static2"), vreq(1, "missing_policy"))
    # on disk (fsynced) before any scheduling happened
    st = JournalState.replay(jpath)
    assert set(st.submitted) == {0, 1}
    assert st.shed[1][0] == "no_entry"         # reasoned, journaled shed
    assert st.pending() == {0: st.submitted[0]}


@needs_msgpack
def test_outcome_answers_from_journal_after_restart(tmp_path):
    make, _, _ = durable_factory(tmp_path)
    eng = make()
    eng.submit(vreq(0, "static2"), vreq(1, "static2"),
               vreq(2, "missing_policy"))
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1]
    crash(eng)

    eng2 = make()
    summary = eng2.recover()
    assert summary["done"] == 2 and summary["shed"] == 1
    assert summary["replayed"] == 0
    # the verdict survives; the payload was the old process's to deliver
    assert eng2.outcome(0) == ("done", None)
    assert eng2.outcome(2) == ("shed", "no_entry")
    with pytest.raises(KeyError):
        eng2.outcome(99)
    # a duplicate of a pre-crash rid is still a duplicate
    eng2.submit(vreq(0, "static2"))
    assert eng2.metrics.rejects.get("duplicate_rid") == 1


# ---------------------------------------------------------------------------
# Engine: restore-from-snapshot (virtual)
# ---------------------------------------------------------------------------

@needs_msgpack
@pytest.mark.parametrize("policy,kind", [("static2", "plan"),
                                         ("adaptive", "adaptive")])
def test_kill_midflight_restores_run(tmp_path, policy, kind):
    """Kill with a batch in flight: the restart restores it from its
    newest boundary snapshot (not from the start) and finishes it; the
    restored record carries a ``restore@`` lineage tag and — for the
    adaptive kind — the pre-crash decision prefix."""
    make, _, sdir = durable_factory(tmp_path)
    eng = make()
    eng.submit(*[vreq(i, policy) for i in range(4)])
    _step_until(eng, lambda: bool(os.listdir(sdir))
                and eng._inflight and not eng._inflight[0].rs.done)
    pre_steps = (eng._inflight[0].rs.run_index if kind == "plan"
                 else eng._inflight[0].rs.step)
    assert pre_steps >= 1
    crash(eng)

    eng2 = make()
    summary = eng2.recover()
    assert summary["restored_runs"] == 1
    assert summary["restored_requests"] == 4
    assert summary["replayed"] == 0 and summary["refused"] == []
    res = eng2.run_until_drained()
    assert sorted(res) == [0, 1, 2, 3]
    rec = eng2.records[0]
    assert any(t.startswith("restore@") for t in rec.lineage)
    assert eng2.metrics.restored_runs == 1
    if kind == "adaptive":
        # decisions = snapshot prefix ++ post-restore steps, identical
        # to an uninterrupted drain of the same entry
        base = make_store().get("adaptive")
        eng3_store_steps = base.schedule.num_steps
        assert len(rec.decisions) == eng3_store_steps
        clean = serve.ServeEngine(
            DurableFakeExecutor(serve.VirtualClock()), params=None,
            store=make_store(), clock=serve.VirtualClock(), max_batch=4)
        clean.submit(*[vreq(i, policy) for i in range(4)])
        clean.run_until_drained()
        assert rec.decisions == clean.records[0].decisions


@needs_msgpack
def test_checkpoint_cadence_and_cleanup(tmp_path):
    """checkpoint_every thins snapshots; a finished batch deletes its
    file — an empty engine leaves an empty snapshot dir."""
    make1, _, sdir1 = durable_factory(tmp_path / "a", checkpoint_every=1)
    make2, _, sdir2 = durable_factory(tmp_path / "b", checkpoint_every=2)
    counts = []
    for make, sdir in ((make1, sdir1), (make2, sdir2)):
        eng = make()
        eng.submit(*[vreq(i, "static2") for i in range(4)])
        eng.run_until_drained()
        assert os.listdir(sdir) == []          # finish dropped the file
        counts.append(eng.metrics.checkpoints)
        crash(eng)
    # cadence thins the checkpoints over the same trace
    assert counts[0] > counts[1] >= 1
    with pytest.raises(ValueError, match="checkpoint_every"):
        durable_factory(tmp_path / "c", checkpoint_every=0)[0]()


@needs_msgpack
def test_eager_runs_are_not_checkpointed(tmp_path):
    make, jpath, sdir = durable_factory(tmp_path)
    eng = make()
    eng.submit(*[vreq(i, "no_cache") for i in range(2)])
    eng.run_until_drained()
    assert sorted(eng.results) == [0, 1]
    assert eng.metrics.checkpoints == 0 and os.listdir(sdir) == []


# ---------------------------------------------------------------------------
# Engine: tampered / torn snapshots → reasoned quarantine → replay
# ---------------------------------------------------------------------------

def _kill_with_snapshot(make, sdir, policy="static2"):
    eng = make()
    eng.submit(*[vreq(i, policy) for i in range(4)])
    _step_until(eng, lambda: bool(os.listdir(sdir))
                and eng._inflight and not eng._inflight[0].rs.done)
    crash(eng)
    return [os.path.join(sdir, n) for n in os.listdir(sdir)]


@needs_msgpack
@pytest.mark.parametrize("mutilate,reason_match", [
    (lambda raw: raw[:-1] + bytes([raw[-1] ^ 0xFF]), "CheckpointError"),
    (lambda raw: raw[: len(raw) // 2], "CheckpointError"),
])
def test_bad_snapshot_quarantined_with_reason_then_replayed(
        tmp_path, mutilate, reason_match):
    """A tampered (flipped body bit) or torn (truncated) snapshot is
    refused: quarantined on disk and in the health ledger with a reason,
    and its requests replay from the start — nothing is lost."""
    make, _, sdir = durable_factory(tmp_path)
    paths = _kill_with_snapshot(make, sdir)
    for p in paths:
        raw = open(p, "rb").read()
        with open(p, "wb") as f:
            f.write(mutilate(raw))

    eng = make()
    summary = eng.recover()
    assert summary["restored_runs"] == 0
    assert summary["replayed"] == 4
    assert len(summary["refused"]) == len(paths)
    qname, reason = summary["refused"][0]
    assert reason_match in reason
    # quarantined, not deleted: a human can inspect the evidence
    assert os.path.exists(os.path.join(sdir, qname + ".quarantined"))
    assert eng.store.health.quarantine_reason(f"snapshot:{qname}") \
        == reason
    assert eng.metrics.snapshots_refused == len(paths)
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1, 2, 3]


@needs_msgpack
def test_provenance_drift_refused(tmp_path):
    """A snapshot taken against one entry version must not restore into
    a store whose entry has since changed — it is refused with the
    drifted field in the reason and replayed instead."""
    make, jpath, sdir = durable_factory(tmp_path)
    _kill_with_snapshot(make, sdir, policy="adaptive")

    clock = serve.VirtualClock()
    store = make_store()
    store.reload("adaptive", _fake_artifact(8))  # hot-swap bumps version
    eng = serve.ServeEngine(DurableFakeExecutor(clock), params=None,
                            store=store, clock=clock, max_batch=4,
                            journal=jpath, snapshot_dir=sdir)
    summary = eng.recover()
    assert summary["restored_runs"] == 0 and summary["replayed"] == 4
    assert any("provenance drift" in r for _, r in summary["refused"])
    assert sorted(eng.run_until_drained()) == [0, 1, 2, 3]


@needs_msgpack
def test_stale_snapshot_discarded_silently(tmp_path):
    """A snapshot whose requests already finished is superseded, not
    suspect: deleted without a quarantine entry."""
    import shutil
    make, _, sdir = durable_factory(tmp_path)
    paths = _kill_with_snapshot(make, sdir)
    keep = str(tmp_path / "keep.ckpt")
    shutil.copy(paths[0], keep)
    eng = make()
    eng.recover()
    eng.run_until_drained()
    crash(eng)
    # resurrect the (now finished) snapshot and recover again
    shutil.copy(keep, paths[0])
    eng2 = make()
    summary = eng2.recover()
    assert summary["stale"] >= 1 and summary["refused"] == []
    assert not os.path.exists(paths[0])
    assert eng2.metrics.report()["durable"]["snapshots_stale"] >= 1


# ---------------------------------------------------------------------------
# SnapshotStore units
# ---------------------------------------------------------------------------

@needs_msgpack
def test_snapshot_store_seq_survives_restart_and_format_guard(tmp_path):
    store = SnapshotStore(str(tmp_path))
    name, nbytes = store.save(0, {"x": np.ones((2,), np.float32)},
                              {"rids": [1, 2]})
    assert name == "run-1.ckpt" and nbytes > 0
    arrays, meta = store.load(os.path.join(str(tmp_path), name))
    assert meta["format"] == FORMAT and meta["rids"] == [1, 2]
    # seq is scanned from disk: a new store continues, never reuses
    store2 = SnapshotStore(str(tmp_path))
    name2, _ = store2.save(0, {}, {})
    assert name2 == "run-2.ckpt"
    # a foreign checkpoint without the format tag is refused
    from repro.checkpoint import io as ckpt_io
    alien = os.path.join(str(tmp_path), "run-9.ckpt")
    ckpt_io.save(alien, {}, {"format": "something/else"})
    with pytest.raises(SnapshotError, match="format"):
        store2.load(alien)


@needs_msgpack
def test_snapshot_meta_checksum_guard(tmp_path):
    """Meta tampering (not just body) is caught: the provenance stamp
    carries its own payload checksum."""
    from repro.checkpoint import io as ckpt_io
    from repro.resilience.integrity import CHECKSUM_KEY
    store = SnapshotStore(str(tmp_path))
    store.save(0, {}, {"rids": [1], "entry": "e"})
    path = store.scan()[0]
    _, meta = ckpt_io.restore(path)
    meta["rids"] = [999]                       # forge the request list
    ckpt_io.save(path, {}, meta)               # checksum now stale
    assert meta[CHECKSUM_KEY]
    with pytest.raises(SnapshotError, match="checksum"):
        store.load(path)


@needs_msgpack
def test_snapshot_one_live_file_per_serial(tmp_path):
    store = SnapshotStore(str(tmp_path))
    store.save(5, {}, {})
    store.save(5, {}, {})                      # supersedes
    assert [os.path.basename(p) for p in store.scan()] == ["run-2.ckpt"]
    store.drop(5)
    assert store.scan() == [] and store.live() == ()


# ---------------------------------------------------------------------------
# The kill matrix (CI durability lane): seeded kill–restart ramps lose
# nothing — every offered request resolves to a result or a reasoned shed
# ---------------------------------------------------------------------------

@needs_msgpack
@pytest.mark.durability
@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_kill_restart_matrix_zero_lost(tmp_path, seed):
    make, jpath, _ = durable_factory(tmp_path)
    n = 18
    policies = ("static2", "adaptive", "no_cache")
    trace = [vreq(i, policies[i % 3], arrival=0.25 * i) for i in range(n)]
    eng0 = make()
    eng0.submit(*trace)
    crash(eng0)

    plan = KillPlan(seed=seed, kill_rate=0.2, kills={2}, max_kills=10)
    report = drain_with_kills(make, plan)
    assert report.restarts >= 1
    resolved = set(report.delivered) | set(report.engine.shed)
    assert resolved == {r.rid for r in trace}, "requests vanished"
    # a fresh incarnation answers every outcome from the journal alone
    probe = make()
    probe.recover()
    for r in trace:
        verdict, _ = probe.outcome(r.rid)
        assert verdict in ("done", "shed")
    st = JournalState.replay(jpath)
    assert st.pending() == {}


# ---------------------------------------------------------------------------
# Real smoke DiT: resume ≡ never-crashed, bitwise
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dit():
    import jax
    from repro import configs
    from repro.core import diffusion
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape),
        params)
    return cfg, params


@needs_msgpack
def test_real_export_import_bitwise_all_three_kinds(small_dit, tmp_path):
    """Export at a boundary → save → restore → import → advance to done
    is bit-identical to an uninterrupted run, for segmented, host-
    adaptive, and fused-adaptive states; tau/k_max drift is refused and
    the fused path never syncs across the round-trip."""
    import jax.numpy as jnp
    from repro.core import calibration
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    steps = 6
    sch = S.fora(cfg.layer_types(), steps, 2)
    pm = calibration.ProxyMap(
        {t: (0.5, 0.01) for t in cfg.layer_types()})
    pool = plan_lib.mask_lattice(sch)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(steps), cfg_scale=1.5)
    assert ex.supports_export
    label = jnp.zeros((2,), jnp.int32)
    key = serve.batch_key([100, 101])
    adaptive_kw = dict(schedule=sch, tau=0.1, proxy_map=pm, pool=pool,
                       k_max=2)

    def seg_start():
        return ex.start_run(params, key, 2, plan=ex.plan_for(sch),
                            schedule=sch, label=label)

    def host_start():
        return ex.start_adaptive_run(params, key, 2, label=label,
                                     **adaptive_kw)

    def fused_start():
        return ex.start_adaptive_fused_run(params, key, 2, label=label,
                                           **adaptive_kw)

    cases = [
        ("plan", seg_start, lambda rs: ex.advance_run(params, rs),
         dict(plan=ex.plan_for(sch))),
        ("adaptive", host_start,
         lambda rs: ex.advance_adaptive_run(params, rs), adaptive_kw),
        ("adaptive_fused", fused_start,
         lambda rs: ex.advance_adaptive_fused(params, rs, n_steps=2),
         adaptive_kw),
    ]
    from repro.checkpoint import io as ckpt_io
    for name, start, advance, import_kw in cases:
        pre_sync = ex.host_sync_count
        ref = start()                          # the uninterrupted twin
        while not ref.done:
            ref = advance(ref)
        rs = advance(start())                  # one boundary in → crash
        kind, arrays, static = ex.export_run(rs)
        assert kind == name
        path = str(tmp_path / f"{name}.ckpt")
        ckpt_io.save(path, arrays, {"static": static})
        del rs, arrays                         # the process died here
        restored_arrays, meta = ckpt_io.restore(path)
        rs2 = ex.import_run(params, kind, restored_arrays,
                            meta["static"], **import_kw)
        while not rs2.done:
            rs2 = advance(rs2)
        np.testing.assert_array_equal(np.asarray(rs2.x),
                                      np.asarray(ref.x))
        if name != "plan":
            assert rs2.decisions == ref.decisions
        if name == "adaptive_fused":
            # the round-trip adds zero host syncs on the fused path
            assert ex.host_sync_count == pre_sync
    # drifted deployment knobs are refused, not silently reinterpreted
    rs = ex.advance_adaptive_run(params, host_start())
    kind, arrays, static = ex.export_run(rs)
    with pytest.raises(ValueError, match="tau"):
        ex.import_run(params, kind, arrays, static,
                      **dict(adaptive_kw, tau=0.3))


def _real_artifact(cfg, steps):
    sch = S.fora(cfg.layer_types(), steps, 2)
    pool = [list(sig.live_in) for sig in plan_lib.mask_lattice(sch)]
    return CacheArtifact(
        arch=cfg.name, solver="ddim", num_steps=steps,
        policy={"name": "adaptive", "base": {"name": "static", "n": 2},
                "tau": 0.1, "k_max": 2},
        curves={}, schedule=sch,
        plan=plan_lib.analyze(sch).to_jsonable(),
        adaptive={"tau": 0.1, "k_max": 2,
                  "proxy_map": {"coeffs": {t: [0.5, 0.01]
                                           for t in cfg.layer_types()},
                                "mean_proxy": None},
                  "pool": pool},
        meta={})


def _real_store(cfg, solver, steps):
    store = serve.ArtifactStore(cfg, solver, cfg_scale=1.5)
    store.add_policy("static2", "static:n=2")
    store.add_artifact("adaptive", _real_artifact(cfg, steps))
    return store


@needs_msgpack
def test_real_engine_restore_bit_identical(small_dit, tmp_path):
    """Kill a real engine with a static and a fused-adaptive batch in
    flight; the restarted engine restores both from snapshots, finishes
    them, and every latent is bit-identical to an uninterrupted engine —
    with the fused path still at zero host syncs."""
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    steps = 6
    reqs = [serve.Request(rid=i, seed=100 + i,
                          policy="adaptive" if i >= 2 else "static2",
                          label=i % cfg.num_classes, arrival=0.0)
            for i in range(4)]

    def build(journal=None, snapshot_dir=None):
        ex = SmoothCacheExecutor(cfg, solvers.ddim(steps), cfg_scale=1.5)
        eng = serve.ServeEngine(
            ex, params, _real_store(cfg, solvers.ddim(steps), steps),
            max_batch=2, max_inflight=2, clock=serve.VirtualClock(),
            check=True, adaptive_chunk=2, journal=journal,
            snapshot_dir=snapshot_dir)
        return eng, ex

    base_eng, _ = build()
    base_eng.submit(*[dataclasses.replace(r) for r in reqs])
    base = base_eng.run_until_drained()

    jpath = str(tmp_path / "journal.jsonl")
    sdir = str(tmp_path / "snapshots")
    eng, _ = build(jpath, sdir)
    eng.submit(*[dataclasses.replace(r) for r in reqs])
    # advance until both batches hold a boundary snapshot mid-flight
    _step_until(eng, lambda: len(eng._snapshots.live()) == 2
                and all(not fl.rs.done for fl in eng._inflight), limit=6)
    crash(eng)

    eng2, ex2 = build(jpath, sdir)
    summary = eng2.recover()
    assert summary["restored_runs"] == 2
    assert summary["restored_requests"] == 4
    assert summary["replayed"] == 0 and summary["refused"] == []
    res = eng2.run_until_drained()
    assert sorted(res) == [0, 1, 2, 3]
    assert ex2.host_sync_count == 0
    for rid in base:
        np.testing.assert_array_equal(res[rid], base[rid])
    assert all(any(t.startswith("restore@") for t in rec.lineage)
               for rec in eng2.records)


@needs_msgpack
def test_real_join_then_restore_bit_identical(small_dit, tmp_path):
    """Continuous mode: late arrivals join an in-flight batch, the
    merged run checkpoints at the next boundary, the process dies, and
    the restart restores the *merged* run (join lineage intact) — every
    latent still equals a solo generate of its own key."""
    import jax.numpy as jnp
    from repro import cache
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    steps = 6
    jpath = str(tmp_path / "journal.jsonl")
    sdir = str(tmp_path / "snapshots")

    def build():
        ex = SmoothCacheExecutor(cfg, solvers.ddim(steps), cfg_scale=1.5)
        store = serve.ArtifactStore(cfg, solvers.ddim(steps),
                                    cfg_scale=1.5)
        store.add_policy("static2", "static:n=2")
        return serve.ServeEngine(
            ex, params, store, max_batch=4, max_inflight=1,
            clock=serve.VirtualClock(), check=True, continuous=True,
            journal=jpath, snapshot_dir=sdir)

    def rq(i):
        return serve.Request(rid=i, seed=100 + i, policy="static2",
                             label=i % cfg.num_classes)

    eng = build()
    eng.submit(rq(0), rq(1))
    assert eng.step()                          # in flight at a boundary
    eng.submit(rq(2), rq(3))
    # run until the chaser merged back in AND the merged 4-row run has
    # checkpointed at a boundary (the journal proves the snapshot covers
    # all four rids, not a leftover pre-merge one), then pull the plug
    def merged_and_snapshotted():
        if eng.metrics.joins != 1 or len(eng._inflight) != 1:
            return False
        fl = eng._inflight[0]
        if fl.rs.done or fl.mb.bucket != 4:
            return False
        ck = JournalState.replay(jpath).checkpoints.get(int(fl.serial))
        return ck is not None and len(ck.get("rids", ())) == 4

    _step_until(eng, merged_and_snapshotted, limit=12)
    crash(eng)

    eng2 = build()
    summary = eng2.recover()
    assert summary["restored_runs"] == 1
    assert summary["restored_requests"] == 4
    res = eng2.run_until_drained()
    assert sorted(res) == [0, 1, 2, 3]
    rec = eng2.records[0]
    assert any("join@" in t for t in rec.lineage)      # history survived
    assert any(t.startswith("restore@") for t in rec.lineage)

    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(steps), "static:n=2",
                                   cfg_scale=1.5)
    pipe.prepare()
    for i in range(4):
        x = pipe.generate(params, serve.batch_key([100 + i]), 1,
                          label=jnp.asarray([i % cfg.num_classes],
                                            jnp.int32))
        np.testing.assert_array_equal(np.asarray(x[0]), res[i])


@needs_msgpack
def test_real_replay_from_start_bit_identical(small_dit, tmp_path):
    """Every snapshot tampered → every one quarantined with a reason →
    the pending requests replay from the start, and the row-keys
    contract still lands each latent bit-identical to a solo generate
    of the request's own key."""
    import jax.numpy as jnp
    from repro import cache
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    steps = 6
    jpath = str(tmp_path / "journal.jsonl")
    sdir = str(tmp_path / "snapshots")

    def build():
        ex = SmoothCacheExecutor(cfg, solvers.ddim(steps), cfg_scale=1.5)
        store = serve.ArtifactStore(cfg, solvers.ddim(steps),
                                    cfg_scale=1.5)
        store.add_policy("static2", "static:n=2")
        return serve.ServeEngine(
            ex, params, store, max_batch=2, max_inflight=1,
            clock=serve.VirtualClock(), check=True, continuous=True,
            journal=jpath, snapshot_dir=sdir)

    eng = build()
    eng.submit(*[serve.Request(rid=i, seed=100 + i, policy="static2",
                               label=i % cfg.num_classes, arrival=0.0)
                 for i in range(2)])
    _step_until(eng, lambda: bool(os.listdir(sdir))
                and eng._inflight and not eng._inflight[0].rs.done,
                limit=6)
    crash(eng)
    for name in os.listdir(sdir):
        p = os.path.join(sdir, name)
        raw = open(p, "rb").read()
        with open(p, "wb") as f:               # flip one body bit
            f.write(raw[:-1] + bytes([raw[-1] ^ 0xFF]))

    eng2 = build()
    summary = eng2.recover()
    assert summary["restored_runs"] == 0 and summary["replayed"] == 2
    assert len(summary["refused"]) >= 1
    for qname, reason in summary["refused"]:
        assert eng2.store.health.quarantine_reason(
            f"snapshot:{qname}") == reason
    res = eng2.run_until_drained()
    assert sorted(res) == [0, 1]

    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(steps), "static:n=2",
                                   cfg_scale=1.5)
    pipe.prepare()
    for i in range(2):
        x = pipe.generate(params, serve.batch_key([100 + i]), 1,
                          label=jnp.asarray([i % cfg.num_classes],
                                            jnp.int32))
        np.testing.assert_array_equal(np.asarray(x[0]), res[i])
