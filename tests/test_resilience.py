"""repro.resilience — fault injection, numerical-health sentinels, and
degrade-don't-die recovery.

Virtual-clock tests on the test_serve fakes exercise the engine's
recovery mechanics (row isolation, survivor re-queue at original
arrival, the degradation ladder, watchdog aborts, terminal outcomes,
entry health), pure tests cover the policy/plan determinism and the
artifact integrity layer, and two smoke-DiT tests prove the *real*
executor sentinels catch an injected NaN — with the healthy co-batched
row bit-identical to an uninjected run, and zero decision host syncs on
the fused path."""
import dataclasses
import json

import numpy as np
import pytest

from repro import serve
from repro.cache.artifact import CacheArtifact
from repro.resilience import (BatchFault, ChaosClock, ChaosExecutor,
                              FaultPlan, FaultSpec, HealthRegistry,
                              ResiliencePolicy, RetryPolicy,
                              corrupt_artifact, payload_checksum,
                              verify_payload)
from repro.resilience import faults
from repro.serve.store import DEGRADED_PREFIX, FALLBACK_ENTRY, TauLadder

from test_serve import (FakeExecutor, FakeFusedExecutor, _adaptive_artifact,
                        _static_artifact, make_store, req)


# ---------------------------------------------------------------------------
# Harness helpers
# ---------------------------------------------------------------------------

def chaos_engine(plan, *, store=None, num_steps=8, resilience=None,
                 fused=False, **kw):
    """Engine over a ChaosExecutor-wrapped fake on a virtual clock."""
    clock = serve.VirtualClock()
    store = store if store is not None else make_store(
        num_steps, no_cache="none", static2="static:n=2")
    inner = (FakeFusedExecutor if fused else FakeExecutor)(clock)
    ex = ChaosExecutor(inner, plan, clock)
    kw.setdefault("max_batch", 4)
    eng = serve.ServeEngine(
        ex, params=None, store=store, clock=clock,
        resilience=resilience if resilience is not None
        else ResiliencePolicy(), **kw)
    return eng, clock


def plain_engine(*, store=None, num_steps=8, **kw):
    clock = serve.VirtualClock()
    store = store if store is not None else make_store(
        num_steps, no_cache="none", static2="static:n=2")
    kw.setdefault("max_batch", 4)
    eng = serve.ServeEngine(FakeExecutor(clock), params=None, store=store,
                            clock=clock, **kw)
    return eng, clock


def adaptive_store(num_steps=8):
    store = make_store(num_steps, static2="static:n=2")
    store.add_artifact("adaptive", _adaptive_artifact(num_steps=num_steps))
    return store


# ---------------------------------------------------------------------------
# NaN isolation: poisoned rows go down the ladder, survivors deliver
# ---------------------------------------------------------------------------

def test_nan_row_isolated_survivors_bit_identical_faulted_degrades():
    """Acceptance (fake path): one poisoned row in a 4-batch — the engine
    finishes with zero crashes, the three healthy co-batched rows are
    bit-identical to an uninjected run, and the faulted request completes
    via the degradation ladder (τ=0 form of its adaptive entry)."""
    plan = FaultPlan(faults={0: FaultSpec(faults.NAN_LATENT, row=1,
                                          chunk=1)})
    eng, _ = chaos_engine(plan, store=adaptive_store())
    eng.submit(*[req(i, "adaptive") for i in range(4)])
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1, 2, 3]        # nobody crashed, nobody lost

    # healthy rows delivered from the original batch, bit-identical to the
    # same submissions served with no chaos and no resilience layer at all
    ref, _ = plain_engine(store=adaptive_store())
    ref.submit(*[req(i, "adaptive") for i in range(4)])
    ref_res = ref.run_until_drained()
    for rid in (0, 2, 3):
        assert np.array_equal(res[rid], ref_res[rid])

    # the poisoned request re-ran one rung down: τ=0 form of its entry
    groups = [r.group for r in eng.records]
    assert groups[0] == "adaptive"
    assert f"{DEGRADED_PREFIX}adaptive/tau0" in groups
    assert eng.metrics.fault_kinds == {faults.NAN_LATENT: 1}
    assert eng.metrics.retries == 1
    assert eng.metrics.degraded == 1
    assert eng.metrics.requeued == 0          # survivors delivered in place
    assert eng.outcome(1)[0] == "done"


def test_fused_path_nan_row_isolated():
    """Same isolation contract through the fused adaptive path (chunked
    on-device advances, ChaosRun proxying the fused run state)."""
    plan = FaultPlan(faults={0: FaultSpec(faults.NAN_LATENT, row=0,
                                          chunk=1)})
    eng, _ = chaos_engine(plan, store=adaptive_store(), fused=True,
                          adaptive_chunk=3)
    eng.submit(req(0, "adaptive"), req(1, "adaptive"))
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1]
    assert eng.metrics.fault_kinds == {faults.NAN_LATENT: 1}
    assert eng.metrics.retries == 1
    # the healthy row rode the original fused batch to completion
    assert eng.records[0].group == "adaptive"
    assert 1 in eng.records[0].rids


def test_all_rows_poisoned_aborts_once_and_falls_back_to_no_cache():
    """A fully poisoned batch aborts mid-run (counted exactly once, not
    re-counted by the abort) and — static entries having no τ=0 form —
    retries land directly on the materialized no_cache fallback."""
    plan = FaultPlan(faults={0: FaultSpec(faults.NAN_LATENT, row=0,
                                          chunk=1)})
    eng, _ = chaos_engine(plan)
    eng.submit(req(0, "static2"))
    res = eng.run_until_drained()
    assert sorted(res) == [0]
    assert eng.metrics.faults_total == 1      # detect + abort = ONE event
    assert eng.metrics.degraded == 1
    assert eng.records[-1].group == FALLBACK_ENTRY
    assert FALLBACK_ENTRY in eng.store


def test_persistent_faults_end_as_reasoned_terminal_outcome():
    """Every retry faults too → past the budget the request ends as an
    explicit ``fault:<kind>`` shed — never an exception, never silence."""
    plan = FaultPlan(seed=5, nan_rate=1.0, max_chunk=1)
    pol = ResiliencePolicy(retry=RetryPolicy(max_retries=1,
                                             backoff_base=0.01))
    eng, _ = chaos_engine(plan, resilience=pol)
    eng.submit(req(0, "static2"))
    eng.run_until_drained()
    assert eng.outcome(0) == ("shed", f"fault:{faults.NAN_LATENT}")
    assert eng.metrics.shed_reasons == {f"fault:{faults.NAN_LATENT}": 1}
    assert len(eng.results) == 0


# ---------------------------------------------------------------------------
# Whole-batch faults: injected exceptions + the stuck-batch watchdog
# ---------------------------------------------------------------------------

def test_injected_fault_requeues_all_rows_at_original_arrival():
    plan = FaultPlan(faults={0: FaultSpec(faults.INJECTED, chunk=1)})
    eng, _ = chaos_engine(plan)
    r0, r1 = req(0, "static2"), req(1, "static2")
    eng.submit(r0, r1)
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1]
    assert eng.metrics.fault_kinds == {faults.INJECTED: 1}
    assert eng.metrics.requeued == 2          # no per-row resolution: all
    assert eng.metrics.retries == 0           # ... survive, none degrade
    # the aborted attempt produced no record; the clean re-run did
    assert len(eng.records) == 1
    assert eng.records[0].rids == (0, 1)
    # arrival stamp survives the re-queue: queue wait keeps charging from
    # first arrival, not from the retry
    assert r0.arrival == 0.0
    assert r0.queue_wait == pytest.approx(r0.started)
    assert r0.started > 0.0


def test_watchdog_aborts_stuck_batch_and_excludes_it_from_cost_model():
    from repro.slo.admission import ServiceCostModel
    plan = FaultPlan(faults={0: FaultSpec(faults.STUCK_BATCH, chunk=1,
                                          stall_s=50.0)})
    pol = ResiliencePolicy(watchdog_factor=3.0, watchdog_floor_s=0.5)
    # prior matched to the fake's ~1 virtual-second step cost, so only
    # the injected stall (not a normal segment) blows the deadline
    eng, _ = chaos_engine(plan, resilience=pol,
                          cost_model=ServiceCostModel(default_step_cost=1.0))
    eng.submit(req(0, "static2"), req(1, "static2"))
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1]
    assert eng.metrics.fault_kinds == {faults.STUCK_BATCH: 1}
    assert eng.metrics.requeued == 2
    # EWMA hygiene: only the clean re-run's service time was observed —
    # the 50 s stall would have pushed the per-step estimate past 6 s
    assert eng.cost_model.per_step("static2") < 2.0


def test_watchdog_disabled_by_default_stall_just_serves_late():
    plan = FaultPlan(faults={0: FaultSpec(faults.STUCK_BATCH, chunk=1,
                                          stall_s=50.0)})
    eng, _ = chaos_engine(plan)                # watchdog_factor=None
    eng.submit(req(0, "static2"))
    res = eng.run_until_drained()
    assert sorted(res) == [0]
    assert eng.metrics.faults_total == 0       # slow ≠ fault without a net


def test_fault_threshold_marks_entry_unhealthy_and_sheds_its_traffic():
    plan = FaultPlan(faults={0: FaultSpec(faults.NAN_LATENT, row=0,
                                          chunk=1)})
    pol = ResiliencePolicy(entry_fault_threshold=1)
    eng, _ = chaos_engine(plan, resilience=pol)
    eng.submit(req(0, "static2", arrival=0.0),
               req(1, "static2", arrival=100.0))
    eng.run_until_drained()
    # the faulted request recovered via the ladder ...
    assert eng.outcome(0)[0] == "done"
    # ... but its group tripped the threshold: later traffic is shed with
    # an explicit reason instead of forming doomed batches
    assert eng.outcome(1) == ("shed", "unhealthy_entry")
    assert not eng.store.health.is_servable("static2")
    assert "threshold" in eng.store.health.status("static2")[
        "unhealthy_reason"]
    # an operator reset restores serving
    eng.store.health.mark_healthy("static2")
    eng.submit(req(2, "static2"))
    eng.run_until_drained()
    assert eng.outcome(2)[0] == "done"


# ---------------------------------------------------------------------------
# Policy knobs: determinism + validation
# ---------------------------------------------------------------------------

def test_retry_policy_backoff_deterministic_and_bounded():
    p = RetryPolicy(max_retries=3, backoff_base=0.1, backoff_factor=2.0,
                    jitter=0.2, seed=42)
    for attempt in (1, 2, 3):
        for rid in (0, 7):
            d = p.delay(attempt, rid)
            assert d == p.delay(attempt, rid)          # pure function
            nominal = 0.1 * 2.0 ** (attempt - 1)
            assert nominal * 0.8 <= d <= nominal * 1.2
    # jitter decorrelates rids; zero jitter is exactly exponential
    assert p.delay(1, 0) != p.delay(1, 1)
    q = RetryPolicy(backoff_base=0.5, jitter=0.0)
    assert q.delay(3) == pytest.approx(2.0)


def test_retry_and_resilience_policy_validation():
    with pytest.raises(ValueError, match="max_retries"):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=1.0)
    with pytest.raises(ValueError, match="factor"):
        RetryPolicy(backoff_factor=0.5)
    with pytest.raises(ValueError, match="attempt"):
        RetryPolicy().delay(0)
    with pytest.raises(ValueError, match="watchdog_factor"):
        ResiliencePolicy(watchdog_factor=0.0)
    with pytest.raises(ValueError, match="entry_fault_threshold"):
        ResiliencePolicy(entry_fault_threshold=0)


def test_fault_plan_deterministic_memoized_and_overridable():
    mk = lambda: FaultPlan(seed=3, nan_rate=0.5, stuck_rate=0.2,
                           error_rate=0.1, max_chunk=2)
    a, b = mk(), mk()
    for serial in range(50):
        sa, sb = a.for_batch(serial, 4), b.for_batch(serial, 4)
        assert sa == sb                        # same seed → same schedule
        assert a.for_batch(serial, 4) is sa    # memoized
        if sa is not None:
            assert sa.kind in faults.KINDS
            assert 1 <= sa.chunk <= 2
    # the realized fault fraction tracks the configured rates
    n = sum(1 for s in range(1000) if a.for_batch(s, 4) is not None)
    assert 0.75 <= n / 1000 <= 0.85
    # explicit entries override the draw — how a test aims at one batch
    spec = FaultSpec(faults.INJECTED, chunk=2)
    c = FaultPlan(faults={3: spec})
    assert c.for_batch(3, 4) is spec
    assert c.for_batch(2, 4) is None


def test_fault_plan_and_spec_validation():
    with pytest.raises(ValueError, match="sum"):
        FaultPlan(nan_rate=0.7, stuck_rate=0.7)
    with pytest.raises(ValueError, match="nan_rate"):
        FaultPlan(nan_rate=1.5)
    with pytest.raises(ValueError, match="chunk"):
        FaultSpec(faults.NAN_LATENT, chunk=0)


def test_chaos_clock_taxes_a_seeded_fraction_of_advances():
    mk = lambda: ChaosClock(serve.VirtualClock(), seed=11, slow_rate=0.5,
                            slow_s=10.0)
    c1, c2 = mk(), mk()
    for _ in range(200):
        c1.advance(1.0)
        c2.advance(1.0)
    assert c1.slowed == c2.slowed              # deterministic weather
    assert 60 <= c1.slowed <= 140
    assert c1.now() == pytest.approx(200 + 10.0 * c1.slowed)
    with pytest.raises(ValueError, match="slow_rate"):
        ChaosClock(serve.VirtualClock(), slow_rate=2.0)


def test_batch_fault_carries_typed_rows():
    bf = BatchFault(faults.NAN_LATENT, sample_flags=[True, False, True],
                    detail="why")
    assert bf.poisoned_rows == (1,)
    assert "poisoned_rows=[1]" in str(bf) and "why" in str(bf)
    assert BatchFault(faults.STUCK_BATCH).poisoned_rows == ()


# ---------------------------------------------------------------------------
# τ-ladder boundaries (degradation routing depends on rung_for_cap)
# ---------------------------------------------------------------------------

def test_rung_for_cap_boundaries():
    lad = TauLadder(name="l", rung_names=("a", "b", "c"),
                    taus=(0.05, 0.1, 0.2))
    assert lad.rung_for_cap(0.01) is None      # below the lowest rung
    assert lad.rung_for_cap(0.05) == 0         # exactly equal admits
    assert lad.rung_for_cap(0.05 - 1e-13) == 0  # float-tolerant equality
    assert lad.rung_for_cap(0.1) == 1
    assert lad.rung_for_cap(0.15) == 1         # between rungs → lower
    assert lad.rung_for_cap(1.0) == 2
    assert lad.rung_for_cap(0.0) is None


def test_add_ladder_rejects_non_monotone_taus_both_paths():
    art = _adaptive_artifact()
    store = make_store()
    with pytest.raises(ValueError, match="ascending"):
        store.add_ladder("lad", art, taus=[0.2, 0.1])
    with pytest.raises(ValueError, match="ascending"):
        store.add_ladder("lad", art, taus=[0.1, 0.1])
    with pytest.raises(ValueError, match="ascending"):
        store.add_ladder("lad", art,
                         spec="adaptive:base=static(n=2),tau=[0.2,0.1]")
    # rejection is all-or-nothing: no partial rungs became visible
    assert "lad" not in store
    assert len(store) == 0


# ---------------------------------------------------------------------------
# Artifact integrity: checksums, ±Inf encoding, atomic reload
# ---------------------------------------------------------------------------

def _curvy_artifact(**vals):
    curves = {"attn": np.asarray([[1.0, np.nan], [0.5, 2.0]], np.float64)}
    curves.update({t: np.asarray(c, np.float64) for t, c in vals.items()})
    return dataclasses.replace(_static_artifact(), curves=curves)


def test_checksum_roundtrip_and_tamper_detection(tmp_path):
    art = _curvy_artifact()
    s = art.to_json()
    payload = json.loads(s)
    assert payload["checksum"].startswith("sha256:")
    assert payload["checksum"] == payload_checksum(payload)
    back = CacheArtifact.from_json(s)
    assert np.array_equal(back.curves["attn"], art.curves["attn"],
                          equal_nan=True)
    # seeded bit-rot (one numeric leaf, checksum untouched) fails loudly
    path = str(tmp_path / "a.cache.json")
    art.save(path)
    corrupt_artifact(path, seed=0)
    with pytest.raises(ValueError, match="checksum mismatch"):
        CacheArtifact.load(path)


def test_corrupt_artifact_rejected_at_store_load(tmp_path):
    path = str(tmp_path / "a.cache.json")
    _static_artifact().save(path)
    corrupt_artifact(path, seed=1)
    with pytest.raises(ValueError, match="checksum"):
        make_store().add_artifact("entry", path)


def test_pre_checksum_artifacts_load_unchanged():
    payload = json.loads(_curvy_artifact().to_json())
    del payload["checksum"]
    payload["format_version"] = 2
    art = CacheArtifact.from_json(json.dumps(payload))
    assert art.arch == "fake-arch"
    verify_payload(payload)                    # no checksum key → passes


def test_inf_curves_roundtrip_but_never_serve():
    art = _curvy_artifact(ffn=[[np.inf, 1.0], [-np.inf, np.nan]])
    back = CacheArtifact.from_json(art.to_json())   # explicit ±Inf tags
    assert np.array_equal(back.curves["ffn"], art.curves["ffn"],
                          equal_nan=True)
    with pytest.raises(ValueError, match="calibration diverged"):
        back.validate_for(arch="fake-arch")
    # and the store's strict load refuses it up front
    with pytest.raises(ValueError, match="calibration diverged"):
        make_store().add_artifact("bad", back)


def test_unrecognized_curve_string_raises_clear_error():
    payload = json.loads(_curvy_artifact().to_json())
    del payload["checksum"]                    # isolate the value error
    payload["curves"]["attn"][0][0] = "bogus"
    with pytest.raises(ValueError, match="unrecognized value 'bogus'"):
        CacheArtifact.from_json(json.dumps(payload))


def test_reload_failure_is_atomic_and_quarantined(tmp_path):
    path = str(tmp_path / "entry.cache.json")
    _static_artifact().save(path)
    store = make_store()
    old = store.add_artifact("entry", path)
    eng, _ = plain_engine(store=store, max_batch=2)
    eng.submit(req(0, "entry"), req(1, "entry"))
    eng.run_until_drained()
    programs_before = eng.executor.compiled_variant_count()

    corrupt_artifact(path, seed=2)
    with pytest.raises(ValueError, match="checksum"):
        store.reload("entry")
    # atomic: the exact old entry object keeps serving, same version, and
    # serving it again compiles nothing new
    assert store.get("entry") is old
    assert store.get("entry").version == 1
    reason = store.health.quarantine_reason("entry")
    assert "hot-reload rejected" in reason and "checksum" in reason
    assert store.health.is_servable("entry")   # quarantine ≠ unserving
    eng.submit(req(2, "entry"), req(3, "entry"))
    eng.run_until_drained()
    assert eng.executor.compiled_variant_count() == programs_before
    assert sorted(eng.results) == [0, 1, 2, 3]

    # a good replacement swaps in, bumps the version, clears the ledger
    _static_artifact(n=4).save(path)
    new = store.reload("entry")
    assert new.version == 2
    assert store.health.quarantine_reason("entry") is None


# ---------------------------------------------------------------------------
# Chaos lane: seeded fault ramps — every request resolves, zero crashes
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_chaos_ramp_every_request_resolves(seed):
    """The CI chaos lane: a mixed static/adaptive trace under a seeded
    fault ramp (NaN rows, stalls, injected exceptions, slow-device
    weather) drains with an explicit outcome for every rid and internally
    consistent fault accounting — and the whole trace is replayable:
    a second engine under the same seed resolves every rid identically."""

    def run():
        from repro.slo.admission import ServiceCostModel
        clock = serve.VirtualClock()
        weather = ChaosClock(clock, seed=seed, slow_rate=0.2, slow_s=0.5)
        store = make_store(8, static2="static:n=2")
        store.add_artifact("adaptive", _adaptive_artifact())
        plan = FaultPlan(seed=seed, nan_rate=0.15, stuck_rate=0.1,
                         error_rate=0.05, stall_s=30.0, max_chunk=2)
        ex = ChaosExecutor(FakeExecutor(weather), plan, clock)
        pol = ResiliencePolicy(
            retry=RetryPolicy(max_retries=2, backoff_base=0.05, seed=seed),
            watchdog_factor=4.0, watchdog_floor_s=1.0)
        eng = serve.ServeEngine(
            ex, params=None, store=store, clock=clock, max_batch=4,
            resilience=pol,
            cost_model=ServiceCostModel(default_step_cost=1.0))
        eng.submit(*[req(i, "adaptive" if i % 2 else "static2",
                         arrival=0.3 * i) for i in range(24)])
        eng.run_until_drained()
        return eng

    eng = run()
    outcomes = {rid: eng.outcome(rid) for rid in range(24)}
    assert all(kind in ("done", "shed") for kind, _ in outcomes.values())
    assert len(eng.results) + len(eng.shed) == 24
    assert len(eng.results) > 0                # the ramp never starves out
    m = eng.metrics
    assert m.faults_total == sum(m.fault_kinds.values())
    assert set(m.fault_kinds) <= set(faults.KINDS)
    for reason in m.shed_reasons:
        assert reason == "stalled" or reason.startswith("fault:")

    again = run()
    assert {rid: again.outcome(rid)[0] for rid in range(24)} \
        == {rid: kind for rid, (kind, _) in outcomes.items()}


@pytest.mark.chaos
def test_chaos_clean_plan_changes_nothing():
    """Rate-0 plan + resilience on ≡ the plain engine: same results, same
    records, zero faults — the healthy path is untouched."""
    eng, _ = chaos_engine(FaultPlan())
    eng.submit(*[req(i, "static2", arrival=0.1 * i) for i in range(6)])
    res = eng.run_until_drained()
    ref, _ = plain_engine()
    ref.submit(*[req(i, "static2", arrival=0.1 * i) for i in range(6)])
    ref_res = ref.run_until_drained()
    assert sorted(res) == sorted(ref_res) == list(range(6))
    assert all(np.array_equal(res[i], ref_res[i]) for i in range(6))
    assert [r.rids for r in eng.records] == [r.rids for r in ref.records]
    assert eng.metrics.faults_total == 0
    assert eng.records[-1].finished_at \
        == pytest.approx(ref.records[-1].finished_at)


# ---------------------------------------------------------------------------
# Real executor: the sentinels themselves (smoke DiT)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dit():
    import jax
    from repro import configs
    from repro.core import diffusion
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape),
        params)
    return cfg, params


def test_executor_sentinels_flag_poisoned_row(small_dit):
    """Direct sentinel check on the segmented plan path: poison one row's
    latent between advances — the carry flags must mark exactly that row
    at the next segment boundary and stay monotone to completion."""
    import jax
    import jax.numpy as jnp
    from repro.cache import registry
    from repro.core import plan as plan_lib
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    solver = solvers.ddim(6)
    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
    sch = registry.get("static:n=2").build(cfg.layer_types(), 6)
    plan = plan_lib.analyze(sch)
    label = jnp.zeros((2,), jnp.int32)
    rs = ex.start_run(params, jax.random.PRNGKey(0), 2, plan=plan,
                      schedule=sch, label=label)
    rs = ex.advance_run(params, rs)
    assert np.asarray(rs.healthy).all()
    rs = dataclasses.replace(rs, x=rs.x.at[1].set(jnp.nan))
    while not rs.done:
        rs = ex.advance_run(params, rs)
    assert np.asarray(rs.healthy).tolist() == [True, False]
    # row independence: the healthy row's latent is untouched by its
    # poisoned neighbor
    assert np.isfinite(np.asarray(rs.x)[0]).all()


def test_real_nan_row_served_healthy_row_bit_identical(small_dit):
    """Acceptance (real path): a NaN injected into one row of a served
    smoke-DiT batch — the engine finishes with zero crashes, the real
    sentinels (not the chaos flags: ``mark_flags=False``) catch it, the
    healthy co-batched request's latent is bit-identical to an uninjected
    run, and the faulted request completes on the no_cache fallback."""
    import jax.numpy as jnp                                     # noqa: F401
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    steps = 6

    def build(chaos):
        solver = solvers.ddim(steps)
        inner = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
        store = serve.ArtifactStore(cfg, solver, cfg_scale=1.5)
        store.add_policy("static2", "static:n=2")
        if chaos:
            plan = FaultPlan(faults={0: FaultSpec(faults.NAN_LATENT,
                                                  row=1, chunk=1)})
            ex = ChaosExecutor(inner, plan, mutate_latent=True,
                               mark_flags=False)
        else:
            ex = inner
        eng = serve.ServeEngine(
            ex, params, store, max_batch=2, clock=serve.VirtualClock(),
            resilience=ResiliencePolicy() if chaos else None)
        eng.submit(req(0, "static2", seed=100, label=0),
                   req(1, "static2", seed=101, label=1))
        eng.run_until_drained()
        return eng

    eng, ref = build(chaos=True), build(chaos=False)
    assert eng.outcome(0)[0] == "done"
    assert eng.outcome(1)[0] == "done"
    # detection came from the executor's carry sentinels alone
    assert eng.metrics.fault_kinds == {faults.NAN_LATENT: 1}
    assert np.array_equal(eng.results[0], ref.results[0])       # bitwise
    assert eng.records[-1].group == FALLBACK_ENTRY
    assert np.isfinite(eng.results[1]).all()


def test_real_fused_sentinels_detect_with_zero_host_syncs(small_dit,
                                                          tmp_path):
    """Fused adaptive path: sentinel detection of an injected NaN costs
    zero decision host syncs — ``host_sync_count`` stays 0, exactly as on
    the healthy path — and the faulted request recovers via the ladder's
    τ=0 form."""
    import jax
    import jax.numpy as jnp
    from repro import cache
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    steps = 6
    calib = cache.DiffusionPipeline(
        cfg, solvers.ddim(steps),
        "adaptive:base=smoothcache(alpha=0.5),tau=0.3", cfg_scale=1.5)
    calib.calibrate(params, jax.random.PRNGKey(1), 2,
                    cond_args={"label": jnp.zeros((2,), jnp.int32)})
    path = str(tmp_path / "adaptive.cache.json")
    calib.save_artifact(path)

    solver = solvers.ddim(steps)
    inner = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
    store = serve.ArtifactStore(cfg, solver, cfg_scale=1.5)
    store.add_artifact("adaptive", path)
    plan = FaultPlan(faults={0: FaultSpec(faults.NAN_LATENT, row=0,
                                          chunk=1)})
    ex = ChaosExecutor(inner, plan, mutate_latent=True, mark_flags=False)
    eng = serve.ServeEngine(ex, params, store, max_batch=2,
                            adaptive_chunk=2, clock=serve.VirtualClock(),
                            resilience=ResiliencePolicy())
    eng.submit(req(0, "adaptive", seed=100, label=0))
    eng.run_until_drained()
    assert eng.outcome(0)[0] == "done"
    assert eng.metrics.fault_kinds.get(faults.NAN_LATENT, 0) >= 1
    assert eng.metrics.degraded == 1
    assert eng.records[-1].group == f"{DEGRADED_PREFIX}adaptive/tau0"
    # the load-bearing assertion: sentinels + recovery added no decision
    # syncs to the fused path
    assert inner.host_sync_count == 0
    assert inner.compiled_variant_count("fused") >= 1
    assert inner.compiled_variant_count("sigstep") == 0
