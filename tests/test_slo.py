"""repro.slo — SLO / quality-elastic serving tests on a virtual clock.

Fake-executor coverage: EDF vs fairness ordering invariants, quality-floor
and admission shedding under a step load, deferral + aging (no
starvation), τ-ladder registration/resolution in the store, controller
hysteresis (no rung flapping on a steady trace), elastic end-to-end rung
movement with zero extra fused programs, and shed-safe metrics.  Plus one
slow end-to-end test on the smoke DiT proving ladder-served latents at a
fixed rung are bit-identical to ``DiffusionPipeline.generate`` at that τ.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro import serve, slo
from repro.cache import registry
from repro.cache.artifact import CacheArtifact
from repro.core import plan as plan_lib
from repro.core import schedule as S


# ---------------------------------------------------------------------------
# Fakes (mirroring tests/test_serve.py): virtual-clock executor where
# adaptive cost shrinks with τ, so the elastic lever is measurable
# ---------------------------------------------------------------------------

class FakeCfg:
    name = "fake-arch"

    def layer_types(self):
        return ("attn", "ffn")


class FakeSolver:
    name = "ddim"

    def __init__(self, num_steps=8):
        self.num_steps = num_steps


@dataclasses.dataclass
class FakeRunState:
    plan: plan_lib.ExecutionPlan
    batch: int
    run_index: int = 0
    x: object = None
    decisions = None

    @property
    def done(self):
        return self.run_index >= len(self.plan.runs)


@dataclasses.dataclass
class FakeFusedState:
    schedule: object
    tau: float
    batch: int
    step: int = 0
    x: object = None

    @property
    def done(self):
        return self.step >= self.schedule.num_steps

    @property
    def num_steps(self):
        return self.schedule.num_steps

    @property
    def decisions(self):
        return tuple(_tau_skips(self.schedule, self.tau, s)
                     for s in range(self.step))


def _tau_skips(schedule, tau, s):
    """The fake's runtime rule: τ=0 realizes the static schedule; τ>0
    reuses *all* types except every ``period``-th step, with the period
    growing with τ — so higher rungs are strictly cheaper."""
    if tau <= 0:
        return tuple(sorted(t for t, v in schedule.skip.items() if v[s]))
    period = 1 + math.ceil(tau * 20)          # 0.05→2, 0.1→3, 0.3→7
    if s % period == 0:
        return ()
    return tuple(sorted(schedule.skip))


class FakeExecutor:
    """Resumable-run surface charging virtual seconds per *computed*
    layer evaluation (see tests/test_serve.py)."""

    supports_fused_adaptive = True

    def __init__(self, clock, step_cost=1.0):
        self.clock = clock
        self.step_cost = step_cost
        self._programs = set()

    def _charge(self, skip, length):
        computed = sum(1 for sk in skip.values() if not sk)
        self.clock.advance(self.step_cost * length
                           * computed / max(len(skip), 1))

    def start_run(self, params, key, batch, *, plan, schedule=None,
                  label=None, memory=None):
        return FakeRunState(plan=plan, batch=batch)

    def advance_run(self, params, rs, *, check=False):
        run = rs.plan.runs[rs.run_index]
        self._programs.add(("seg", run.sig, rs.batch))
        self._charge(run.sig.skip, run.length)
        rs = dataclasses.replace(rs, run_index=rs.run_index + 1)
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def start_adaptive_fused_run(self, params, key, batch, *, schedule,
                                 tau, proxy_map=None, pool=None, k_max=3,
                                 label=None, memory=None):
        # one fused program per (pool, runtime-vs-skip-table, batch) —
        # τ is a traced argument, so every τ>0 rung shares one program
        pool_key = tuple(sorted(tuple(s.live_in) for s in pool))
        self._programs.add(("fused", pool_key, tau > 0, batch))
        return FakeFusedState(schedule=schedule, tau=tau, batch=batch)

    def advance_adaptive_fused(self, params, rs, n_steps=None):
        remaining = rs.schedule.num_steps - rs.step
        length = remaining if n_steps is None else min(n_steps, remaining)
        for s in range(rs.step, rs.step + length):
            skips = set(_tau_skips(rs.schedule, rs.tau, s))
            self._charge({t: t in skips for t in rs.schedule.skip}, 1)
        rs = dataclasses.replace(rs, step=rs.step + length)
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def compiled_variant_count(self, kind=None):
        if kind is None:
            return len(self._programs)
        return len({p for p in self._programs if p[0] == kind})

    def xla_program_count(self, kind=None):
        return self.compiled_variant_count(kind)


def _adaptive_artifact(num_steps=8, tau=0.1, k_max=1):
    types = ("attn", "ffn")
    sch = S.fora(types, num_steps, 2)
    pool = [list(sig.live_in) for sig in plan_lib.mask_lattice(sch)]
    return CacheArtifact(
        arch="fake-arch", solver="ddim", num_steps=num_steps,
        policy={"name": "adaptive", "base": {"name": "static", "n": 2},
                "tau": tau},
        curves={}, schedule=sch,
        plan=plan_lib.analyze(sch).to_jsonable(),
        adaptive={"tau": tau, "k_max": k_max,
                  "proxy_map": {"coeffs": {"attn": [0.0, 0.01],
                                           "ffn": [0.0, 0.01]},
                                "mean_proxy": None},
                  "pool": pool},
        meta={})


def make_engine(num_steps=8, entries=None, ladder_spec=None, **kw):
    clock = serve.VirtualClock()
    store = serve.ArtifactStore(FakeCfg(), FakeSolver(num_steps))
    for name, spec in (entries or {}).items():
        store.add_policy(name, spec)
    if ladder_spec is not None:
        store.add_ladder("gen", _adaptive_artifact(num_steps),
                         spec=ladder_spec)
    ex = FakeExecutor(clock)
    kw.setdefault("max_batch", 4)
    eng = serve.ServeEngine(ex, params=None, store=store, clock=clock, **kw)
    return eng, clock, ex


def req(rid, policy, arrival=0.0, priority=0, deadline=None, max_tau=None):
    s = None
    if deadline is not None or max_tau is not None:
        s = slo.SLO(deadline=deadline, max_tau=max_tau)
    return serve.Request(rid=rid, seed=rid, policy=policy,
                         priority=priority, arrival=arrival, slo=s)


LADDER3 = "adaptive:base=static(n=2),tau=[0.0,0.05,0.2],k_max=1"


# ---------------------------------------------------------------------------
# SLO dataclass + Request plumbing
# ---------------------------------------------------------------------------

def test_slo_and_request_properties():
    r = req(1, "p", arrival=0.0, deadline=5.0, max_tau=0.1)
    assert r.deadline == 5.0 and r.max_tau == 0.1
    assert not r.attained()                   # unfinished / shed
    r.finished = 4.0
    assert r.attained()
    r.finished = 6.0
    assert not r.attained()
    bare = req(2, "p")
    assert bare.deadline is None and bare.max_tau is None
    bare.finished = 100.0
    assert bare.attained()                    # no deadline: any finish
    with pytest.raises(ValueError):
        slo.SLO(max_tau=-0.1)
    assert slo.slack(None, 0.0, 1.0) == math.inf
    assert slo.slack(10.0, 4.0, 2.0) == pytest.approx(4.0)


def test_remaining_steps_across_state_shapes():
    sch = S.fora(("attn", "ffn"), 8, 2)
    plan = plan_lib.analyze(sch)
    rs = FakeRunState(plan=plan, batch=1)
    assert slo.remaining_steps(rs) == 8
    fused = FakeFusedState(schedule=sch, tau=0.0, batch=1, step=3)
    assert slo.remaining_steps(fused) == 5


# ---------------------------------------------------------------------------
# Trace helpers: deadline-bearing arrivals, overload ramp
# ---------------------------------------------------------------------------

def test_poisson_arrivals_deadline_budget():
    rng = np.random.RandomState(0)
    plain = serve.poisson_arrivals(2.0, 10, np.random.RandomState(0))
    assert all(isinstance(a, float) for a in plain)   # back-compat shape
    pairs = serve.poisson_arrivals(2.0, 10, np.random.RandomState(0),
                                   deadline_budget=(1.0, 2.0))
    arrivals = [a for a, _ in pairs]
    assert arrivals == sorted(arrivals) and len(pairs) == 10
    assert arrivals[0] == plain[0]            # same underlying process
    assert all(1.0 <= d - a <= 2.0 for a, d in pairs)
    fixed = serve.poisson_arrivals(2.0, 5, rng, deadline_budget=3.0)
    assert all(d - a == pytest.approx(3.0) for a, d in fixed)


def test_overload_trace_deterministic_and_classed():
    classes = [
        slo.RequestClass("bulk", "gen", weight=3.0,
                         deadline_budget=(5.0, 8.0)),
        slo.RequestClass("strict", "gen", weight=1.0, priority=1,
                         deadline_budget=4.0, max_tau=0.05),
    ]
    t1 = slo.overload_trace(classes, [(1.0, 20), (4.0, 20)],
                            np.random.RandomState(7))
    t2 = slo.overload_trace(classes, [(1.0, 20), (4.0, 20)],
                            np.random.RandomState(7))
    assert [(r.rid, r.arrival, r.deadline, r.max_tau) for r in t1] \
        == [(r.rid, r.arrival, r.deadline, r.max_tau) for r in t2]
    assert len(t1) == 40
    assert all(r.deadline is not None and r.deadline > r.arrival
               for r in t1)
    names = {r.slo.cls for r in t1}
    assert names == {"bulk", "strict"}
    # the 4 rps phase is denser than the 1 rps phase
    assert (t1[39].arrival - t1[20].arrival) \
        < (t1[19].arrival - t1[0].arrival)
    for r in t1:
        if r.slo.cls == "strict":
            assert r.max_tau == 0.05 and r.priority == 1


# ---------------------------------------------------------------------------
# Registry τ-ladder grammar
# ---------------------------------------------------------------------------

def test_registry_bracket_list_grammar():
    name, kw = registry.parse(
        "adaptive:base=smoothcache(alpha=0.18),tau=[0.0,0.05,0.2]")
    assert name == "adaptive" and kw["tau"] == [0.0, 0.05, 0.2]
    # nested paren values still split correctly next to bracket lists
    assert kw["base"].spec().startswith("smoothcache")
    assert registry.parse("adaptive:tau=[]")[1]["tau"] == []


def test_registry_ladder_expansion_and_validation():
    pols = registry.expand_ladder(LADDER3)
    assert [p.tau for p in pols] == [0.0, 0.05, 0.2]
    assert len({p.base.spec() for p in pols}) == 1
    with pytest.raises(ValueError, match="ascending"):
        registry.expand_ladder("adaptive:tau=[0.2,0.05]")
    with pytest.raises(ValueError, match="ascending"):
        registry.expand_ladder("adaptive:tau=[0.1,0.1]")
    with pytest.raises(ValueError, match="adaptive"):
        registry.expand_ladder("static:n=2")
    with pytest.raises(ValueError, match="tau"):
        registry.expand_ladder("adaptive:base=static(n=2)")
    # a ladder spec is NOT a single policy — get() refuses with a pointer
    with pytest.raises(ValueError, match="expand_ladder"):
        registry.get("adaptive:tau=[0.0,0.1]")


def test_artifact_at_tau():
    art = _adaptive_artifact(tau=0.1)
    re = art.at_tau(0.3)
    assert re.adaptive["tau"] == 0.3 and re.policy["tau"] == 0.3
    assert art.adaptive["tau"] == 0.1         # original untouched
    assert re.schedule is art.schedule and re.curves is art.curves
    with pytest.raises(ValueError):
        art.at_tau(-1.0)
    static = CacheArtifact(arch="a", solver="s", num_steps=4,
                           policy={"name": "static", "n": 2}, curves={})
    with pytest.raises(ValueError, match="adaptive"):
        static.at_tau(0.1)


# ---------------------------------------------------------------------------
# Store: ladder registration, rung resolution, quality floors
# ---------------------------------------------------------------------------

def test_store_ladder_registration_and_rungs():
    store = serve.ArtifactStore(FakeCfg(), FakeSolver(8))
    lad = store.add_ladder("gen", _adaptive_artifact(8), spec=LADDER3)
    assert lad.taus == (0.0, 0.05, 0.2)
    assert store.ladders() == ["gen"]
    assert "gen" in store and "gen/tau=0.05" in store
    assert set(store.names()) == {"gen/tau=0", "gen/tau=0.05",
                                  "gen/tau=0.2"}
    assert store.get("gen").tau == 0.0        # active rung 0
    store.set_rung("gen", 2)
    assert store.get("gen").tau == 0.2
    store.set_rung("gen", 99)                 # clamped
    assert store.ladder("gen").active == 2
    # per-request caps clamp below the active rung
    capped = req(0, "gen", max_tau=0.05)
    assert store.resolve_entry_for("gen", capped).tau == 0.05
    uncapped = req(1, "gen")
    assert store.resolve_entry_for("gen", uncapped).tau == 0.2
    # all rungs share proxy map + pool; τ is the only difference
    e0, e2 = store.get("gen/tau=0"), store.get("gen/tau=0.2")
    assert e0.proxy_map.to_jsonable() == e2.proxy_map.to_jsonable()
    assert e0.pool() == e2.pool()
    # duplicate name and malformed arg combos are rejected
    with pytest.raises(ValueError, match="exists"):
        store.add_ladder("gen", _adaptive_artifact(8), spec=LADDER3)
    with pytest.raises(ValueError, match="exactly one"):
        store.add_ladder("g2", _adaptive_artifact(8))


def test_store_ladder_from_taus_uses_stored_policy():
    store = serve.ArtifactStore(FakeCfg(), FakeSolver(8))
    lad = store.add_ladder("gen", _adaptive_artifact(8, tau=0.1),
                           taus=[0.0, 0.1, 0.3])
    assert lad.taus == (0.0, 0.1, 0.3)
    with pytest.raises(ValueError, match="ascending"):
        store.add_ladder("g2", _adaptive_artifact(8), taus=[0.3, 0.1])
    static = CacheArtifact(arch="fake-arch", solver="ddim", num_steps=8,
                           policy={"name": "static", "n": 2}, curves={},
                           schedule=S.fora(("attn", "ffn"), 8, 2))
    with pytest.raises(ValueError, match="adaptive"):
        store.add_ladder("g3", static, taus=[0.0, 0.1])


def test_rung_for_cap():
    lad = serve.TauLadder("x", ("a", "b", "c"), (0.05, 0.1, 0.2))
    assert lad.rung_for_cap(0.01) is None     # floor below every rung
    assert lad.rung_for_cap(0.05) == 0
    assert lad.rung_for_cap(0.15) == 1
    assert lad.rung_for_cap(1.0) == 2


# ---------------------------------------------------------------------------
# EDF vs fairness ordering invariants
# ---------------------------------------------------------------------------

def _drain_two(scheduler):
    eng, clock, _ = make_engine(
        num_steps=16, entries={"full": "static:n=2"},
        max_batch=1, max_inflight=2, scheduler=scheduler)
    eng.submit(req(0, "full", arrival=0.0),                # no deadline
               req(1, "full", arrival=0.0, deadline=10.0))  # urgent
    eng.run_until_drained()
    return {rec.rids[0]: rec.finished_at for rec in eng.records}


def test_edf_prioritizes_deadline_batch_over_round_robin():
    edf = _drain_two("edf")
    fair = _drain_two("interleave")
    # same total work either way...
    assert max(edf.values()) == pytest.approx(max(fair.values()))
    # ...but EDF runs the deadline batch to completion first, while
    # fairness interleaves both to a near-simultaneous finish
    assert edf[1] < fair[1]
    assert edf[1] < edf[0]
    assert edf[1] <= fair[1] - 1.0


def test_edf_falls_back_to_round_robin_without_deadlines():
    eng, _, _ = make_engine(
        num_steps=16, entries={"full": "static:n=2"},
        max_batch=1, max_inflight=2, scheduler="edf")
    eng.submit(req(0, "full"), req(1, "full"))
    eng.run_until_drained()
    done = sorted(rec.finished_at for rec in eng.records)
    assert done[1] - done[0] <= 1.0           # interleaved, not convoyed


def test_unknown_scheduler_rejected():
    with pytest.raises(ValueError, match="scheduler"):
        make_engine(entries={"p": "none"}, scheduler="bogus")
    with pytest.raises(ValueError, match="controller"):
        make_engine(entries={"p": "none"}, scheduler="elastic")


# ---------------------------------------------------------------------------
# Quality floors + admission control
# ---------------------------------------------------------------------------

def test_quality_floor_shed_and_rung_clamp():
    eng, _, _ = make_engine(ladder_spec=LADDER3, max_batch=1)
    eng.store.set_rung("gen", 2)              # active: τ=0.2
    eng.submit(req(0, "gen"),                 # uncapped → τ=0.2
               req(1, "gen", max_tau=0.05),   # capped → τ=0.05 rung
               req(2, "gen", max_tau=-0.0))   # floor met by τ=0 rung
    eng.run_until_drained()
    taus = {rec.rids[0]: rec.tau for rec in eng.records}
    assert taus == {0: 0.2, 1: 0.05, 2: 0.0}
    # a floor below every rung is shed with an explicit reason
    eng2, _, _ = make_engine(ladder_spec=LADDER3.replace("0.0,", ""),
                             max_batch=1)
    eng2.submit(req(0, "gen", max_tau=0.01), req(1, "gen"))
    res = eng2.run_until_drained()
    assert sorted(res) == [1]
    assert eng2.outcome(0) == ("shed", "quality_floor")
    assert eng2.outcome(1)[0] == "done"
    rep = eng2.report()
    assert rep["shed"] == {"total": 1, "reasons": {"quality_floor": 1}}
    assert rep["slo"]["offered"] == 2
    assert rep["slo"]["goodput_fraction"] == pytest.approx(0.5)


def test_admission_decisions_unit():
    ctl = slo.AdmissionController(max_backlog_s=2.0, admit_priority=1.0,
                                  aging_rate=0.0, defer_interval=1.0)
    calm = ctl.decide(req(0, "p"), 0.0, backlog_s=1.0)
    assert calm.action == "admit"
    over = ctl.decide(req(1, "p"), 0.0, backlog_s=5.0)
    assert (over.action, over.reason, over.retry_at) \
        == ("defer", "overloaded", 1.0)
    vip = ctl.decide(req(2, "p", priority=1), 0.0, backlog_s=5.0)
    assert vip.action == "admit"
    # infeasible deadline → immediate shed, regardless of load
    late = ctl.decide(req(3, "p", deadline=3.0), 0.0, backlog_s=5.0,
                      est_service_s=1.0)
    assert (late.action, late.reason) == ("shed", "deadline_infeasible")
    # overloaded AND a deferral would come back past the deadline → shed
    doomed = ctl.decide(req(4, "p", deadline=0.8), 0.0, backlog_s=5.0)
    assert doomed.action == "shed" or doomed.reason == "deadline_infeasible"


def test_aging_lifts_effective_priority():
    ctl = slo.AdmissionController(max_backlog_s=1.0, admit_priority=1.0,
                                  aging_rate=0.5, defer_interval=1.0)
    r = req(0, "p", arrival=0.0)
    assert ctl.decide(r, 0.0, backlog_s=9.0).action == "defer"
    assert ctl.decide(r, 1.0, backlog_s=9.0).action == "defer"
    assert ctl.effective_priority(r, 2.0) == pytest.approx(1.0)
    assert ctl.decide(r, 2.0, backlog_s=9.0).action == "admit"
    # without aging the same request would starve forever
    frozen = slo.AdmissionController(max_backlog_s=1.0,
                                     admit_priority=1.0, aging_rate=0.0)
    assert frozen.decide(r, 1000.0, backlog_s=9.0).action == "defer"


def test_aging_prevents_starvation_end_to_end():
    eng, _, _ = make_engine(
        num_steps=8, entries={"full": "none"}, max_batch=1,
        max_inflight=1,
        admission=slo.AdmissionController(max_backlog_s=2.0,
                                          admit_priority=1.0,
                                          aging_rate=1.0,
                                          defer_interval=0.5))
    eng.submit(*[req(i, "full", priority=1) for i in range(6)],
               req(99, "full", priority=0))
    res = eng.run_until_drained()
    assert 99 in res                          # aged in, not starved
    assert len(res) == 7
    assert eng.metrics.deferrals >= 1
    # the low-priority request was served last
    order = [rec.rids[0] for rec in eng.records]
    assert order[-1] == 99


def test_admission_sheds_infeasible_deadlines_under_step_load():
    eng, _, _ = make_engine(
        num_steps=8, entries={"full": "none"}, max_batch=1,
        max_inflight=1,
        admission=slo.AdmissionController(max_backlog_s=1e9))
    # prime the cost model pessimistically high via one observed run
    eng.submit(req(0, "full"))
    eng.run_until_drained()                   # 8 virtual s → 1 s/step
    eng.submit(req(1, "full", deadline=eng.clock.now() + 2.0),
               req(2, "full", deadline=eng.clock.now() + 100.0))
    res = eng.run_until_drained()
    assert 2 in res and 1 not in res
    assert eng.outcome(1) == ("shed", "deadline_infeasible")
    rep = eng.report()
    assert rep["slo"]["with_deadline"] == 2
    assert rep["slo"]["attained"] == 1
    assert rep["slo"]["attainment"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Controller hysteresis
# ---------------------------------------------------------------------------

def test_controller_hysteresis_no_flapping_on_steady_trace():
    c = slo.ElasticTauController(3, target_p95_wait_s=1.0, window=16,
                                 min_samples=2, interval_s=1.0, band=0.3,
                                 cooldown_s=2.0, settle=2)
    t = 0.0
    for _ in range(50):                       # steady: waits ≈ target
        c.observe_wait(1.0, t)
        c.update(t)
        t += 0.5
    assert c.history == [] and c.rung == 0


def test_controller_ramps_up_and_settles_down():
    c = slo.ElasticTauController(3, target_p95_wait_s=1.0, window=8,
                                 min_samples=2, interval_s=1.0, band=0.3,
                                 cooldown_s=2.0, settle=2)
    t = 0.0
    while c.rung < 2:                         # overload → ramp to top
        c.observe_wait(5.0, t)
        c.update(t)
        t += 0.5
        assert t < 30.0
    ups = list(c.history)
    assert [r for _, r, _ in ups] == [1, 2]   # monotone, no oscillation
    # changes respect the cooldown
    assert ups[1][0] - ups[0][0] >= 2.0
    # calm traffic: needs `settle` consecutive calm windows to step down
    down_start = t
    while c.rung > 0:
        c.observe_wait(0.1, t)
        c.update(t)
        t += 0.5
        assert t < down_start + 60.0
    rungs = [r for _, r, _ in c.history]
    assert rungs == [1, 2, 1, 0]              # up, up, down, down — no flap


# ---------------------------------------------------------------------------
# Elastic end-to-end on the fake executor
# ---------------------------------------------------------------------------

def test_elastic_controller_moves_rungs_under_overload():
    ctrl = slo.ElasticTauController(3, target_p95_wait_s=2.0, window=16,
                                    min_samples=2, interval_s=0.5,
                                    band=0.25, cooldown_s=1.0, settle=2)
    eng, _, ex = make_engine(
        ladder_spec=LADDER3, num_steps=8, max_batch=2, max_inflight=2,
        scheduler=slo.ElasticPolicy(ctrl))
    eng.submit(*[req(i, "gen", arrival=0.0, deadline=200.0)
                 for i in range(24)])
    res = eng.run_until_drained()
    assert len(res) == 24
    assert ctrl.history, "overload must trigger rung changes"
    assert eng.store.ladder("gen").active > 0
    rep = eng.report()
    assert len(rep["realized_tau"]) >= 2      # served at multiple rungs
    # τ is a traced argument of the fused program: all τ>0 rungs share
    # one program per batch shape, τ=0 compiles its own skip-table
    # variant — so ≤ 2 fused programs per bucket, and within budget
    buckets = {p[3] for p in ex._programs if p[0] == "fused"}
    assert ex.compiled_variant_count("fused") <= 2 * len(buckets)
    assert rep["compiles"]["xla_programs"] <= rep["program_budget"]
    # quality cost is predicted from the shared proxy map
    assert rep["predicted_quality_cost"]["n"] == 24


def test_metrics_empty_and_shed_only_report():
    m = serve.ServerMetrics()
    rep = m.report()                          # nothing observed at all
    assert rep["requests"] == 0
    assert rep["slo"]["attainment"] is None
    assert rep["predicted_quality_cost"]["n"] == 0
    m.observe_shed(req(0, "p", deadline=1.0), "overloaded", 2.0)
    rep = m.report()                          # sheds only, zero finishes
    assert rep["shed"]["total"] == 1
    assert rep["slo"] == {
        "with_deadline": 1, "attained": 0, "attainment": 0.0,
        "good_requests": 0, "offered": 1, "goodput_fraction": 0.0}


def test_queue_take_rids_and_resubmit():
    clock = serve.VirtualClock()
    q = serve.RequestQueue(clock)
    rs = [req(i, "p", arrival=0.0) for i in range(4)]
    for r in rs:
        q.submit(r)
    taken = q.take_rids("p", [2, 0], now=0.0)
    assert [r.rid for r in taken] == [0, 2]   # ready order preserved
    assert [r.rid for r in q.peek("p", 0.0)] == [1, 3]
    q.resubmit(taken[0], not_before=5.0)
    assert [r.rid for r in q.peek("p", 4.9)] == [1, 3]
    # back at 5.0; ready order re-sorts on (-priority, arrival, rid) and
    # the deferred request kept its original arrival stamp
    assert [r.rid for r in q.peek("p", 5.0)] == [0, 1, 3]
    assert taken[0].arrival == 0.0            # wait accounting untouched


# ---------------------------------------------------------------------------
# End-to-end (slow): ladder rung ≡ DiffusionPipeline.generate at that τ
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dit():
    import jax
    from repro import configs
    from repro.core import diffusion
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape),
        params)
    return cfg, params


def test_ladder_rung_bit_identical_to_generate(small_dit, tmp_path):
    """Elastic serving pinned at a fixed rung is *bit-identical* to
    ``DiffusionPipeline.generate`` at that τ — degradation changes which
    rung serves, never what a rung computes."""
    import jax
    import jax.numpy as jnp
    from repro import cache
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    steps = 6
    calib = cache.DiffusionPipeline(
        cfg, solvers.ddim(steps),
        "adaptive:base=smoothcache(alpha=0.5),tau=0.3", cfg_scale=1.5)
    calib.calibrate(params, jax.random.PRNGKey(1), 2,
                    cond_args={"label": jnp.zeros((2,), jnp.int32)})
    path = str(tmp_path / "adaptive.cache.json")
    calib.save_artifact(path)

    solver = solvers.ddim(steps)
    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
    store = serve.ArtifactStore(cfg, solver, cfg_scale=1.5)
    ladder = store.add_ladder("gen", path, taus=[0.0, 0.3])
    assert ladder.taus == (0.0, 0.3)

    # a pinned controller (huge target + unreachable sample count) keeps
    # the active rung fixed for the whole run
    ctrl = slo.ElasticTauController(2, target_p95_wait_s=1e9,
                                    min_samples=10**6, start_rung=1)
    store.set_rung("gen", 1)                  # τ=0.3
    eng = serve.ServeEngine(ex, params, store, max_batch=2,
                            max_inflight=2, clock=serve.VirtualClock(),
                            scheduler=slo.ElasticPolicy(ctrl), check=True)
    eng.submit(*[serve.Request(rid=i, seed=100 + i, policy="gen",
                               label=i % cfg.num_classes, arrival=0.0,
                               slo=slo.SLO(deadline=1e9))
                 for i in range(3)])
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1, 2]
    assert all(rec.group == "gen/tau=0.3" and rec.tau == 0.3
               for rec in eng.records)

    rep = eng.report()
    assert rep["compiles"]["xla_programs"] <= rep["program_budget"]
    assert rep["slo"]["attainment"] == 1.0
    assert set(rep["realized_tau"]) == {"0.3"}

    # replay every batch through the pipeline facade at the rung's τ
    pipe = cache.DiffusionPipeline(
        cfg, solvers.ddim(steps),
        "adaptive:base=smoothcache(alpha=0.5),tau=0.3", cfg_scale=1.5)
    pipe.load_artifact(CacheArtifact.load(path).at_tau(0.3))
    for rec in eng.records:
        key = serve.batch_key(rec.seeds)
        lab = jnp.asarray(rec.labels, jnp.int32)
        x, dec = pipe.generate(params, key, rec.bucket, label=lab,
                               return_decisions=True)
        assert dec == rec.decisions
        for j, rid in enumerate(rec.rids):
            np.testing.assert_array_equal(np.asarray(x[j]), res[rid])
