"""Execution-plan core: segmentation + liveness analysis (pure), the
segmented executor path (compile-count regression, bit-identity with the
eager path, runtime liveness invariant), and plan provenance round-trips."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import cache, configs
from repro.core import diffusion, plan as plan_lib, schedule as S, solvers
from repro.core.executor import SmoothCacheExecutor, cache_entry_names


# ---------------------------------------------------------------------------
# Plan analysis (no model involved)
# ---------------------------------------------------------------------------

def _sched(skip_rows, types=("attn", "ffn")):
    skip = {t: np.asarray(v, bool) for t, v in zip(types, skip_rows)}
    return S.Schedule(skip, len(skip_rows[0]))


def test_liveness_is_next_step_lookahead():
    # attn: C S S C C ; ffn: C C S C C
    p = plan_lib.analyze(_sched([[0, 1, 1, 0, 0], [0, 0, 1, 0, 0]]))
    # attn collected only at step 0 (read at 1); its entry computed at step 3
    # is dead (step 4 recomputes) and must never be collected
    assert p.collect_at(0) == ("attn",)
    assert p.collect_at(1) == ("ffn",)       # read at step 2
    assert p.collect_at(2) == ()             # steps 3+ recompute everything
    assert p.collect_at(3) == ()
    assert p.collect_at(4) == ()
    assert p.live_in_at(2) == ("attn", "ffn")
    assert p.live_in_at(3) == ()             # dead after the last read


def test_never_skipped_type_is_dead_everywhere():
    p = plan_lib.analyze(_sched([[0, 1, 0, 1], [0, 0, 0, 0]]))
    assert "ffn" not in p.live_types()
    for r in p.runs:
        assert "ffn" not in r.sig.collect
        assert "ffn" not in r.sig.structure
        assert "ffn" not in r.live_out


def test_runs_are_maximal_mask_segments():
    """Runs RLE the mask sequence exactly: consecutive runs differ in mask,
    runs tile [0, S), the program set is one signature per distinct mask,
    and each run's structure (live_in ∪ collect) is a loop invariant that
    covers the exact boundary live set."""
    rng = np.random.RandomState(0)
    for _ in range(20):
        rows = [np.r_[False, rng.rand(19) < 0.6] for _ in range(2)]
        sch = _sched(rows)
        p = plan_lib.analyze(sch)
        steps = [s for r in p.runs for s in range(r.start, r.start + r.length)]
        assert steps == list(range(p.num_steps))
        for a, b in zip(p.runs, p.runs[1:]):
            assert a.sig.mask != b.sig.mask
            assert set(a.live_out) == set(b.sig.live_in)
            assert set(a.live_out) <= set(a.sig.structure)
        assert p.num_unique_signatures == len(sch.distinct_masks())


@given(st.lists(st.booleans(), min_size=1, max_size=40))
@settings(max_examples=100, deadline=None)
def test_plan_collect_iff_next_step_reads(bits):
    rows = [np.r_[False, np.asarray(bits, bool)]]
    p = plan_lib.analyze(_sched(rows, types=("attn",)))
    v = np.r_[False, np.asarray(bits, bool)]
    for s in range(p.num_steps):
        nxt_reads = s + 1 < p.num_steps and v[s + 1]
        assert (("attn" in p.collect_at(s)) ==
                (bool(nxt_reads) and not v[s]))
        assert (("attn" in p.live_in_at(s)) == bool(v[s]))


def test_plan_rejects_step0_skip():
    with pytest.raises(ValueError, match="step 0"):
        plan_lib.analyze(_sched([[1, 0], [0, 0]]))


def test_plan_json_roundtrip():
    sch = _sched([[0, 1, 1, 0, 1, 0], [0, 0, 1, 1, 0, 0]])
    p = plan_lib.analyze(sch)
    p2 = plan_lib.ExecutionPlan.from_json(p.to_json())
    assert p2 == p
    assert p2.schedule_fingerprint == plan_lib.schedule_fingerprint(sch)
    json.loads(p.to_json())  # strict JSON


def test_peak_live_bytes_counts_only_live_types():
    p = plan_lib.analyze(_sched([[0, 1, 1, 0], [0, 0, 0, 0]]))
    tb = {"attn": 100, "ffn": 10_000}
    assert p.peak_live_bytes(tb) == 100     # ffn never resident
    p0 = plan_lib.analyze(_sched([[0, 0], [0, 0]]))
    assert p0.peak_live_bytes(tb) == 0


def test_branch_cache_type_bytes_matches_layer_count():
    cfg = configs.get("dit-xl-256", "smoke")
    tb = plan_lib.branch_cache_type_bytes(cfg, batch=2)
    n_tok, _, _ = diffusion.token_shape(cfg)
    per_layer = 2 * n_tok * cfg.d_model * 4
    layers = {t: 0 for t in cfg.layer_types()}
    for st_ in cfg.stages:
        for b in st_.unit:
            for t in b.branch_types():
                layers[t] += st_.repeat
    assert tb == {t: n * per_layer for t, n in layers.items()}


# ---------------------------------------------------------------------------
# Segmented executor
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dit():
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    # perturb zero-inits so branches matter
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7), a.shape),
        params)
    return cfg, params


def _mixed_schedule(num_steps=10):
    return S.Schedule({
        "attn": np.asarray([0, 1, 1, 0, 1, 1, 0, 1, 0, 0][:num_steps], bool),
        "ffn":  np.asarray([0, 1, 0, 1, 1, 0, 1, 1, 1, 0][:num_steps], bool),
    }, num_steps)


def test_segmented_bit_identical_to_eager(small_dit):
    cfg, params = small_dit
    sch = _mixed_schedule()
    label = jnp.zeros((2,), jnp.int32)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(10), cfg_scale=1.5)
    x_eager = ex.sample(params, jax.random.PRNGKey(2), 2, schedule=sch,
                        label=label)
    x_seg = ex.sample_compiled(params, jax.random.PRNGKey(2), 2, schedule=sch,
                               label=label, check=True)
    np.testing.assert_array_equal(np.asarray(x_eager), np.asarray(x_seg))


def test_segmented_no_cache_matches_plain(small_dit):
    cfg, params = small_dit
    label = jnp.zeros((1,), jnp.int32)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(6), cfg_scale=1.5)
    x_plain = ex.sample(params, jax.random.PRNGKey(1), 1, label=label)
    x_seg = ex.sample_compiled(params, jax.random.PRNGKey(1), 1,
                               label=label, check=True)
    np.testing.assert_array_equal(np.asarray(x_plain), np.asarray(x_seg))
    # an uncached run is ONE signature → one compiled segment program
    assert ex.compiled_variant_count("seg") == 1


def test_compile_count_equals_unique_signatures(small_dit):
    cfg, params = small_dit
    sch = _mixed_schedule()
    label = jnp.zeros((1,), jnp.int32)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(10), cfg_scale=1.5)
    plan = ex.plan_for(sch)
    assert ex.compiled_variant_count("seg") == 0
    ex.sample_compiled(params, jax.random.PRNGKey(0), 1, schedule=sch,
                       label=label)
    assert ex.compiled_variant_count("seg") == plan.num_unique_signatures
    # re-sampling compiles nothing new
    ex.sample_compiled(params, jax.random.PRNGKey(1), 1, schedule=sch,
                       label=label)
    assert ex.compiled_variant_count("seg") == plan.num_unique_signatures
    # far fewer programs than steps or segments
    assert plan.num_unique_signatures <= len(plan.runs) <= sch.num_steps


def test_dead_branches_never_resident(small_dit):
    """'ffn' is never skipped → its branch outputs must never enter the
    cache pytree (check=True asserts the resident set equals the plan's
    live set after every segment)."""
    cfg, params = small_dit
    sch = S.Schedule({
        "attn": np.asarray([0, 1, 0, 1, 0, 1], bool),
        "ffn":  np.zeros(6, bool)}, 6)
    plan = plan_lib.analyze(sch)
    assert "ffn" not in plan.live_types()
    assert all("ffn" != t for r in plan.runs for t in r.sig.collect)
    # the runtime cache for the skip-attn steps holds attn entries only
    names = cache_entry_names(cfg, ("attn",))
    assert names and all(n == "mixer" for _, _, n in names)
    label = jnp.zeros((1,), jnp.int32)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(6), cfg_scale=1.5)
    x = ex.sample_compiled(params, jax.random.PRNGKey(0), 1, schedule=sch,
                           label=label, check=True)
    assert bool(jnp.all(jnp.isfinite(x)))


def test_segmented_non_scannable_solver(small_dit):
    """DPM++(3M) SDE steps in Python (state structure changes) — the
    segmented path falls back to per-signature model programs + eager
    solver and still matches the eager path bitwise."""
    cfg, params = small_dit
    assert not solvers.dpmpp_3m_sde(8).scannable
    sch = S.fora(cfg.layer_types(), 8, 2)
    label = jnp.zeros((1,), jnp.int32)
    ex = SmoothCacheExecutor(cfg, solvers.dpmpp_3m_sde(8), cfg_scale=1.5)
    xa = ex.sample(params, jax.random.PRNGKey(3), 1, schedule=sch, label=label)
    xb = ex.sample_compiled(params, jax.random.PRNGKey(3), 1, schedule=sch,
                            label=label, check=True)
    np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    assert 0 < ex.compiled_variant_count("sigstep") \
        <= ex.plan_for(sch).num_unique_signatures


def test_rectified_flow_segmented(small_dit):
    cfg, params = small_dit
    sch = S.fora(cfg.layer_types(), 6, 3)
    label = jnp.zeros((1,), jnp.int32)
    ex = SmoothCacheExecutor(cfg, solvers.rectified_flow(6), cfg_scale=1.5)
    xa = ex.sample(params, jax.random.PRNGKey(4), 1, schedule=sch, label=label)
    xb = ex.sample_compiled(params, jax.random.PRNGKey(4), 1, schedule=sch,
                            label=label, check=True)
    np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def test_eager_memo_has_no_duplicate_programs(small_dit):
    """Regression for the duplicate-compilation bug: the eager fn table is
    keyed only by (mask, has_cache) — running with and without a collect
    hook reuses the same programs."""
    cfg, params = small_dit
    sch = _mixed_schedule(8)
    label = jnp.zeros((1,), jnp.int32)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(8), cfg_scale=1.5)
    ex.sample(params, jax.random.PRNGKey(0), 1, schedule=sch, label=label)
    n = ex.compiled_variant_count("eager")
    seen = []
    ex.sample(params, jax.random.PRNGKey(0), 1, schedule=sch, label=label,
              collect_hook=lambda s, c: seen.append(s))
    assert len(seen) == 8
    assert ex.compiled_variant_count("eager") == n
    distinct = len(sch.distinct_masks())
    assert n <= distinct + 1      # +1: the first step runs without a cache


def test_plan_mismatch_rejected(small_dit):
    cfg, params = small_dit
    ex = SmoothCacheExecutor(cfg, solvers.ddim(6), cfg_scale=1.5)
    other = plan_lib.analyze(S.fora(cfg.layer_types(), 6, 3))
    with pytest.raises(ValueError, match="fingerprint"):
        ex.sample_compiled(params, jax.random.PRNGKey(0), 1,
                           schedule=S.fora(cfg.layer_types(), 6, 2),
                           label=jnp.zeros((1,), jnp.int32), plan=other)


# ---------------------------------------------------------------------------
# Plan provenance through artifacts / pipeline
# ---------------------------------------------------------------------------

def test_artifact_plan_round_trip(small_dit, tmp_path):
    cfg, params = small_dit
    label = jnp.zeros((2,), jnp.int32)
    calib = cache.DiffusionPipeline(cfg, solvers.ddim(6),
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    calib.calibrate(params, jax.random.PRNGKey(1), 2,
                    cond_args={"label": label})
    assert calib.artifact.plan is not None
    assert calib.plan.num_steps == 6
    path = str(tmp_path / "plan.cache.json")
    calib.save_artifact(path)

    serve = cache.DiffusionPipeline(cfg, solvers.ddim(6),
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    serve.load_artifact(path)
    assert serve.plan == calib.plan          # reloaded, not re-derived
    x1 = calib.generate(params, jax.random.PRNGKey(2), 2, label=label)
    x2 = serve.generate(params, jax.random.PRNGKey(2), 2, label=label)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    # compiled (segmented) and eager generate agree bitwise
    x3 = serve.generate(params, jax.random.PRNGKey(2), 2, label=label,
                        compiled=False)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x3))


def test_artifact_stale_plan_discarded(small_dit):
    cfg, _ = small_dit
    types = cfg.layer_types()
    sch_a = S.fora(types, 6, 2)
    sch_b = S.fora(types, 6, 3)
    art = cache.CacheArtifact(
        arch=cfg.name, solver="ddim", num_steps=6,
        policy={"kind": "static", "n": 3}, curves={}, schedule=sch_b,
        plan=plan_lib.analyze(sch_a).to_jsonable())
    p = art.execution_plan()
    assert p.schedule_fingerprint == plan_lib.schedule_fingerprint(sch_b)
