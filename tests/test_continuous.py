"""Continuous in-flight batching: run-state split/merge bitwise
row-equivalence across all three run kinds, boundary joins / regroups /
per-row retries on a virtual clock, the (rung, bucket) cost-model key,
and the program-budget / host-sync regressions with joining enabled."""
import dataclasses

import numpy as np
import pytest

import test_serve as ts
from repro import serve
from repro.serve.batcher import bucket_sizes


# ---------------------------------------------------------------------------
# Fakes: the split/merge surface over test_serve's virtual-clock executor
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SplitRunState(ts.FakeRunState):
    keys: tuple = ()                          # per-row PRNG keys (opaque)

    @property
    def step(self):
        if self.done:
            return self.plan.num_steps
        return self.plan.runs[self.run_index].start

    @property
    def num_steps(self):
        return self.plan.num_steps


def _payload(keys, batch):
    """Row j's 'latent' identifies its PRNG key — the same function of
    the same key no matter which batch the row rode in, which is exactly
    the per-row determinism contract split/merge must preserve."""
    if keys:
        return np.asarray([np.asarray(k, np.uint32).astype(np.float64)
                           for k in keys])
    return np.arange(batch, dtype=np.float64)[:, None]


class SplitFakeExecutor(ts.FakeExecutor):
    supports_split = True

    def start_run(self, params, key, batch, *, plan, schedule=None,
                  label=None, memory=None, row_keys=None):
        return SplitRunState(plan=plan, batch=batch,
                             keys=tuple(row_keys or ()))

    def advance_run(self, params, rs, *, check=False):
        run = rs.plan.runs[rs.run_index]
        self._programs.add(("seg", run.sig, rs.batch))
        self._charge(run.sig.skip, run.length)
        rs = dataclasses.replace(rs, run_index=rs.run_index + 1)
        if rs.done:
            rs.x = _payload(rs.keys, rs.batch)
        return rs

    def split_run(self, rs, groups):
        return [dataclasses.replace(
            rs, batch=len(g), keys=tuple(rs.keys[j] for j in g))
            for g in groups]

    def merge_runs(self, runs):
        r0 = runs[0]
        assert all(r.plan is r0.plan and r.run_index == r0.run_index
                   for r in runs)
        return dataclasses.replace(
            r0, batch=sum(r.batch for r in runs),
            keys=tuple(k for r in runs for k in r.keys))


@dataclasses.dataclass
class SplitFusedState:
    """Fused-adaptive fake whose rows *want* different masks mid-run:
    per-row signatures diverge by key parity on steps [2, 4) and
    reconverge after — driving one boundary regroup and one coalesce."""
    schedule: object
    batch: int
    step: int = 0
    x: object = None
    keys: tuple = ()
    decisions = None

    @property
    def done(self):
        return self.step >= self.schedule.num_steps

    @property
    def num_steps(self):
        return self.schedule.num_steps

    def row_signatures(self):
        if 2 <= self.step < 4:
            return tuple((int(np.asarray(k, np.uint32)[-1]) & 1,)
                         for k in self.keys)
        return tuple((9,) for _ in self.keys)


class SplitFusedExecutor(SplitFakeExecutor):
    supports_fused_adaptive = True

    def start_adaptive_fused_run(self, params, key, batch, *, schedule,
                                 tau, proxy_map=None, pool=None, k_max=3,
                                 label=None, memory=None, row_keys=None):
        self._programs.add(("fused", tuple(sorted(
            tuple(s.live_in) for s in pool)), batch))
        return SplitFusedState(schedule=schedule, batch=batch,
                               keys=tuple(row_keys or ()))

    def advance_adaptive_fused(self, params, rs, n_steps=None):
        remaining = rs.schedule.num_steps - rs.step
        length = remaining if n_steps is None else min(n_steps, remaining)
        for s in range(rs.step, rs.step + length):
            self._charge({t: bool(v[s])
                          for t, v in rs.schedule.skip.items()}, 1)
        rs = dataclasses.replace(rs, step=rs.step + length)
        if rs.done:
            rs.x = _payload(rs.keys, rs.batch)
        return rs

    def merge_runs(self, runs):
        r0 = runs[0]
        if isinstance(r0, SplitFusedState):
            assert all(r.schedule is r0.schedule and r.step == r0.step
                       for r in runs)
            return dataclasses.replace(
                r0, batch=sum(r.batch for r in runs),
                keys=tuple(k for r in runs for k in r.keys))
        return super().merge_runs(runs)


def make_continuous_engine(store=None, **kw):
    clock = serve.VirtualClock()
    store = store if store is not None else ts.make_store(
        8, static2="static:n=2")
    ex = SplitFakeExecutor(clock)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_inflight", 1)
    kw.setdefault("continuous", True)
    eng = serve.ServeEngine(ex, params=None, store=store, clock=clock,
                            **kw)
    return eng, clock, ex


def _expected_row(seed):
    return _payload([serve.batch_key([seed])], 1)[0]


def _run_join_scenario(continuous):
    """Two requests form a batch; two more become ready while it is in
    flight.  With one in-flight slot the late pair can only run by
    joining at a boundary (continuous) or waiting for the slot
    (baseline)."""
    eng, clock, ex = make_continuous_engine(continuous=continuous)
    eng.submit(ts.req(0, "static2"), ts.req(1, "static2"))
    assert eng.step()                  # launch [0, 1], advance one segment
    eng.submit(ts.req(2, "static2"), ts.req(3, "static2"))
    res = eng.run_until_drained()
    return eng, res


def test_join_at_boundary_routes_and_is_deterministic():
    eng, res = _run_join_scenario(True)
    assert sorted(res) == [0, 1, 2, 3]
    for rid in range(4):
        np.testing.assert_array_equal(res[rid], _expected_row(rid))
    m = eng.metrics
    assert m.joins == 1 and m.joined_requests == 2 and m.merges == 1
    # the joiners' queue wait ended at the join launch, and lineage
    # records the join for replay
    assert any("join@" in t for r in eng.records for t in r.lineage)
    # exact determinism: the same trace replays to the same schedule
    eng2, res2 = _run_join_scenario(True)
    assert [r.lineage for r in eng2.records] == \
        [r.lineage for r in eng.records]
    assert eng2.metrics.queue_waits == eng.metrics.queue_waits
    for rid in res:
        np.testing.assert_array_equal(res2[rid], res[rid])


def test_join_beats_join_disabled_on_p95_wait():
    eng_c, _ = _run_join_scenario(True)
    eng_b, _ = _run_join_scenario(False)
    assert eng_b.metrics.joins == 0
    p95 = lambda e: serve.percentile(e.metrics.queue_waits, 95)
    assert p95(eng_c) < p95(eng_b)


def test_join_respects_program_budget():
    eng, _ = _run_join_scenario(True)
    rep = eng.report()
    assert rep["compiles"]["xla_programs"] <= rep["program_budget"]
    # every shape the join path touched is an admissible p2 bucket
    sizes = set(bucket_sizes(eng.batcher.max_batch))
    assert {p[2] for p in eng.executor._programs} <= sizes


def test_take_join_only_lands_on_p2_shapes():
    eng, clock, ex = make_continuous_engine()
    entry = eng.store.get("static2")
    eng.queue.submit_many([ts.req(i, "static2") for i in range(3)])
    # bucket 2 can only grow to 4 (k=2): a lone third request fits, the
    # join takes exactly two
    taken = eng.batcher.take_join(0.0, entry, 2)
    assert [r.rid for r in taken] == [0, 1]
    # bucket at max_batch never joins
    assert eng.batcher.take_join(0.0, entry, 4) == []
    # k=1 only fits bucket 1 (1+1=2); 2+1=3 is not a shape we compile
    assert eng.batcher.take_join(0.0, entry, 2) == []
    taken = eng.batcher.take_join(0.0, entry, 1)
    assert [r.rid for r in taken] == [2]


def test_join_requires_matching_entry_version():
    eng, clock, ex = make_continuous_engine(
        store=ts.make_store(8, static2="static:n=2", other="none"))
    entry = eng.store.get("static2")
    eng.queue.submit_many([ts.req(0, "other")])
    assert eng.batcher.take_join(0.0, entry, 1) == []


def _parity(seed):
    return int(np.asarray(serve.batch_key([seed]), np.uint32)[-1]) & 1


def test_regroup_and_coalesce_on_diverging_masks():
    """A τ>0 fused batch whose rows realize different mask signatures
    splits into per-signature sub-runs at the boundary, and the sub-runs
    merge back once their signatures reconverge — with every row's bits
    untouched."""
    evens = [s for s in range(64) if _parity(s) == 0][:2]
    odds = [s for s in range(64) if _parity(s) == 1][:2]
    seeds = evens + odds
    clock = serve.VirtualClock()
    store = ts.make_store(8, static2="static:n=2")
    store.add_artifact("adaptive", ts._adaptive_artifact(num_steps=8))
    ex = SplitFusedExecutor(clock)
    eng = serve.ServeEngine(ex, params=None, store=store, clock=clock,
                            max_batch=4, max_inflight=2,
                            adaptive_chunk=1, continuous=True)
    eng.submit(*[serve.Request(rid=i, seed=s, policy="adaptive")
                 for i, s in enumerate(seeds)])
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1, 2, 3]
    m = eng.metrics
    assert m.regroups == 1 and m.merges == 1 and m.joins == 0
    tags = [t for r in eng.records for t in r.lineage]
    assert any(t.startswith("regroup@2:") for t in tags)
    assert any(t.startswith("coalesce@4:") for t in tags)
    for i, s in enumerate(seeds):
        np.testing.assert_array_equal(res[i], _expected_row(s))


def test_split_retry_keeps_survivor_run_state():
    """A row poisoned mid-run is split out and retried while the
    surviving row keeps its run-state (lineage shows the split, no
    survivor re-queue)."""
    from repro.resilience import chaos, faults
    from repro.resilience.recovery import ResiliencePolicy, RetryPolicy

    clock = serve.VirtualClock()
    store = ts.make_store(8, static2="static:n=2")
    plan = chaos.FaultPlan(faults={0: chaos.FaultSpec(
        faults.NAN_LATENT, row=1, chunk=1)})
    ex = chaos.ChaosExecutor(SplitFakeExecutor(clock), plan, clock)
    pol = ResiliencePolicy(
        retry=RetryPolicy(max_retries=2, backoff_base=0.0, jitter=0.0),
        degrade=False, split_retry=True)
    eng = serve.ServeEngine(ex, params=None, store=store, clock=clock,
                            max_batch=4, continuous=True, resilience=pol)
    eng.submit(ts.req(0, "static2"), ts.req(1, "static2"))
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1]
    m = eng.metrics
    assert m.row_retries == 1 and m.retries == 1 and m.requeued == 0
    survivor = [r for r in eng.records if r.rids == (0,)]
    assert survivor and any("split_retry@" in t
                            for t in survivor[0].lineage)
    # the survivor kept its bits
    np.testing.assert_array_equal(res[0], _expected_row(0))


def test_split_retry_off_restores_carry_to_finish():
    from repro.resilience import chaos, faults
    from repro.resilience.recovery import ResiliencePolicy, RetryPolicy

    clock = serve.VirtualClock()
    store = ts.make_store(8, static2="static:n=2")
    plan = chaos.FaultPlan(faults={0: chaos.FaultSpec(
        faults.NAN_LATENT, row=1, chunk=1)})
    ex = chaos.ChaosExecutor(SplitFakeExecutor(clock), plan, clock)
    pol = ResiliencePolicy(
        retry=RetryPolicy(max_retries=2, backoff_base=0.0, jitter=0.0),
        degrade=False, split_retry=False)
    eng = serve.ServeEngine(ex, params=None, store=store, clock=clock,
                            max_batch=4, continuous=True, resilience=pol)
    eng.submit(ts.req(0, "static2"), ts.req(1, "static2"))
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1]
    assert eng.metrics.row_retries == 0


def test_cost_model_keys_on_rung_and_bucket():
    from repro.slo.admission import ServiceCostModel
    m = ServiceCostModel(default_step_cost=0.5, alpha=0.3)
    m.observe("rung", 8.0, 8, bucket=4)       # 1.0 s/step at (rung, 4)
    m.observe("rung", 1.6, 8, bucket=1)       # 0.2 s/step at (rung, 1)
    assert m.per_step("rung", bucket=4) == pytest.approx(1.0)
    assert m.per_step("rung", bucket=1) == pytest.approx(0.2)
    # unseen (rung, bucket) falls back to the rung EWMA, unseen rung to
    # the global one, a fresh model to the seed default
    assert m.per_step("rung", bucket=2) == m.per_step("rung")
    assert m.per_step("other") == m.per_step()
    assert ServiceCostModel(default_step_cost=0.5).per_step(
        "g", bucket=1) == 0.5
    assert m.estimate(10, "rung", bucket=1) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# Real executor: bitwise split/merge over all three run kinds, and the
# end-to-end continuous determinism contract on the smoke DiT
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dit():
    import jax
    from repro import configs
    from repro.core import diffusion
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape),
        params)
    return cfg, params


def _row_keys(n):
    return [serve.batch_key([100 + i]) for i in range(n)]


def _drain(ex, advance, rs):
    while not rs.done:
        rs = advance(rs)
    return rs


def test_split_merge_bitwise_all_three_kinds(small_dit):
    """split → advance → merge produces bit-identical rows to advancing
    the unsplit batch, for segmented, host-adaptive, and fused-adaptive
    run states (static masks, so every row's trajectory is row-local)."""
    import jax.numpy as jnp
    from repro.core import calibration, plan as plan_lib
    from repro.core import schedule as S, solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    steps = 6
    sch = S.fora(cfg.layer_types(), steps, 2)
    pm = calibration.ProxyMap(
        {t: (0.5, 0.01) for t in cfg.layer_types()})
    pool = plan_lib.mask_lattice(sch)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(steps), cfg_scale=1.5)
    assert ex.supports_split
    keys = _row_keys(2)
    label = jnp.zeros((2,), jnp.int32)

    def seg_start():
        return ex.start_run(params, None, 2, plan=ex.plan_for(sch),
                            schedule=sch, label=label, row_keys=keys)

    def host_start():
        return ex.start_adaptive_run(params, None, 2, schedule=sch,
                                     tau=0.0, proxy_map=pm, pool=pool,
                                     k_max=2, label=label, row_keys=keys)

    def fused_start():
        return ex.start_adaptive_fused_run(params, None, 2, schedule=sch,
                                           tau=0.0, proxy_map=pm,
                                           pool=pool, k_max=2,
                                           label=label, row_keys=keys)

    cases = [
        (seg_start, lambda rs: ex.advance_run(params, rs)),
        (host_start, lambda rs: ex.advance_adaptive_run(params, rs)),
        (fused_start,
         lambda rs: ex.advance_adaptive_fused(params, rs, n_steps=2)),
    ]
    for start, advance in cases:
        whole = _drain(ex, advance, start())
        rs = advance(start())                 # one boundary in
        subs = ex.split_run(rs, [[0], [1]])
        subs = [_drain(ex, advance, s) for s in subs]
        merged = ex.merge_runs(subs)
        np.testing.assert_array_equal(np.asarray(merged.x),
                                      np.asarray(whole.x))
        # rows survive a plain split+merge round-trip mid-run too
        rs2 = advance(start())
        rt = ex.merge_runs(ex.split_run(rs2, [[0], [1]]))
        np.testing.assert_array_equal(np.asarray(rt.x),
                                      np.asarray(rs2.x))


def test_split_rows_match_solo_runs(small_dit):
    """Row i of a split sub-run finishes bit-identical to a B=1 run from
    row i's own key — the per-request replay contract joins rely on."""
    import jax.numpy as jnp
    from repro.core import schedule as S, solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    steps = 6
    sch = S.fora(cfg.layer_types(), steps, 2)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(steps), cfg_scale=1.5)
    keys = _row_keys(2)
    label = jnp.zeros((2,), jnp.int32)
    rs = ex.start_run(params, None, 2, plan=ex.plan_for(sch),
                      schedule=sch, label=label, row_keys=keys)
    rs = ex.advance_run(params, rs)
    sub = _drain(ex, lambda r: ex.advance_run(params, r),
                 ex.split_run(rs, [[1]])[0])
    solo = _drain(ex, lambda r: ex.advance_run(params, r),
                  ex.start_run(params, None, 1, plan=ex.plan_for(sch),
                               schedule=sch,
                               label=jnp.zeros((1,), jnp.int32),
                               row_keys=[keys[1]]))
    np.testing.assert_array_equal(np.asarray(sub.x), np.asarray(solo.x))


def test_stochastic_solver_rejects_split(small_dit):
    import jax.numpy as jnp
    from repro.core import schedule as S, solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    sch = S.fora(cfg.layer_types(), 4, 2)
    ex = SmoothCacheExecutor(cfg, solvers.dpmpp_3m_sde(4), cfg_scale=1.5)
    assert not ex.supports_split
    with pytest.raises(ValueError, match="stochastic"):
        ex.start_run(params, None, 1, plan=ex.plan_for(sch), schedule=sch,
                     label=jnp.zeros((1,), jnp.int32),
                     row_keys=_row_keys(1))
    import jax
    rs = ex.start_run(params, jax.random.PRNGKey(0), 1,
                      plan=ex.plan_for(sch), schedule=sch,
                      label=jnp.zeros((1,), jnp.int32))
    with pytest.raises(ValueError, match="stochastic"):
        ex.split_run(rs, [[0]])


def test_continuous_serving_real_dit_bit_identical(small_dit):
    """End-to-end with joining enabled on the smoke DiT: late arrivals
    join an in-flight static batch at a segment boundary; every served
    latent is bit-identical to a solo ``generate`` of that request's own
    key; programs stay within budget and the fused path never syncs."""
    import jax.numpy as jnp
    from repro import cache
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg, params = small_dit
    steps = 6
    solver = solvers.ddim(steps)
    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
    store = serve.ArtifactStore(cfg, solver, cfg_scale=1.5)
    store.add_policy("static2", "static:n=2")
    eng = serve.ServeEngine(ex, params, store, max_batch=4,
                            max_inflight=1, clock=serve.VirtualClock(),
                            check=True, continuous=True)

    def rq(i):
        return serve.Request(rid=i, seed=100 + i, policy="static2",
                             label=i % cfg.num_classes)

    eng.submit(rq(0), rq(1))
    assert eng.step()                        # in flight at a boundary
    eng.submit(rq(2), rq(3))
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1, 2, 3]
    assert eng.metrics.joins == 1 and eng.metrics.joined_requests == 2
    assert ex.host_sync_count == 0
    rep = eng.report()
    assert rep["compiles"]["xla_programs"] <= rep["program_budget"]

    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(steps), "static:n=2",
                                   cfg_scale=1.5)
    pipe.prepare()
    for i in range(4):
        x = pipe.generate(params, serve.batch_key([100 + i]), 1,
                          label=jnp.asarray([i % cfg.num_classes],
                                            jnp.int32))
        np.testing.assert_array_equal(np.asarray(x[0]), res[i])
