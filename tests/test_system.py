"""End-to-end system tests: the full paper pipeline (train → calibrate →
schedule → cached sampling) and the AR serving pipeline, on CPU."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint, configs, optim
from repro.core import calibration, diffusion, schedule as S, solvers
from repro.core.executor import SmoothCacheExecutor
from repro.data import BlobLatents, TokenStream
from repro.launch.serve import generate
from repro.models import transformer as T


def test_full_smoothcache_pipeline():
    """Paper pipeline: train a DiT, calibrate (Eq. 4), build an α-schedule,
    sample cached; assert quality degrades gracefully and FLOPs shrink."""
    cfg = configs.get("dit-xl-256", "smoke")
    sched = diffusion.vp_schedule()
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    data = BlobLatents(cfg.latent_shape, cfg.num_classes, 8)
    ocfg = optim.AdamWConfig(lr=2e-3, weight_decay=0.0)
    ostate = optim.init_state(params)

    @jax.jit
    def step(p, s, k, x0, label):
        l, g = jax.value_and_grad(
            lambda p_: diffusion.eps_loss(cfg, p_, k, x0, sched=sched,
                                          label=label))(p)
        p, s, _ = optim.apply_updates(ocfg, p, g, s)
        return p, s, l

    losses = []
    for i in range(40):
        x0, label = data.batch_at(i)
        params, ostate, l = step(params, ostate,
                                 jax.random.fold_in(jax.random.PRNGKey(1), i),
                                 x0, label)
        losses.append(float(l))
    assert np.mean(losses[-10:]) < np.mean(losses[:10])

    solver = solvers.ddim(10)
    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
    label = jnp.arange(4) % cfg.num_classes
    curves, _, _ = calibration.calibrate(ex, params, jax.random.PRNGKey(2), 4,
                                         cond_args={"label": label})
    sch = S.smoothcache(curves, alpha=0.3, k_max=3)
    x_cached = ex.sample(params, jax.random.PRNGKey(3), 4, schedule=sch,
                         label=label)
    x_plain = ex.sample(params, jax.random.PRNGKey(3), 4, label=label)
    assert bool(jnp.all(jnp.isfinite(x_cached)))
    rel = float(jnp.linalg.norm(x_cached - x_plain)
                / (jnp.linalg.norm(x_plain) + 1e-9))
    assert rel < 1.0

    # compiled-FLOP reduction matches the schedule (paper's TMACs claim)
    from repro.launch import hlo_analysis
    def flops_of(schedule):
        fn = ex.build_sampler_fn(schedule)
        lab = jax.ShapeDtypeStruct((2,), jnp.int32)
        xs = jax.ShapeDtypeStruct((2,) + tuple(cfg.latent_shape), jnp.float32)
        ps = jax.eval_shape(lambda: params)
        txt = jax.jit(fn).lower(ps, xs, lab, None, None).compile().as_text()
        return hlo_analysis.analyze(txt).flops
    f_cached = flops_of(sch)
    f_plain = flops_of(S.no_cache(cfg.layer_types(), 10))
    frac = np.mean([sch.compute_fraction(t) for t in sch.skip])
    assert f_cached < f_plain
    np.testing.assert_allclose(f_cached / f_plain, frac, atol=0.15)


def test_ar_serving_pipeline_with_checkpoint():
    cfg = configs.get("internvl2-1b", "smoke")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        pth = os.path.join(d, "m.ckpt")
        checkpoint.save(pth, {"params": params}, {"arch": cfg.name})
        tree, meta = checkpoint.restore(pth)
    stream = TokenStream(cfg.vocab_size, 12, 2)
    prompts, _ = stream.batch_at(0)
    toks = generate(cfg, tree["params"], prompts, 6,
                    key=jax.random.PRNGKey(1))
    assert toks.shape == (2, 6)
    assert int(jnp.max(toks)) < cfg.vocab_size
