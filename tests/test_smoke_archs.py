"""Deliverable (f): per-architecture smoke tests — a REDUCED variant of each
assigned family runs one forward + one train step on CPU, asserting output
shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.launch import programs
from repro.models import transformer as T


def _inputs(cfg, key, b=2, l=16):
    kw = {}
    if cfg.num_codebooks > 1:
        toks = jax.random.randint(key, (b, l + 1, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (b, l + 1), 0, cfg.vocab_size)
    if cfg.cond_dim:
        kw["memory"] = jax.random.normal(jax.random.fold_in(key, 1),
                                         (b, 4, cfg.cond_dim))
    if cfg.num_prefix_embeds:
        kw["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.num_prefix_embeds, cfg.d_model))
    return toks[:, :-1], toks[:, 1:], kw


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_forward_no_nans(arch):
    cfg = configs.get(arch, "smoke")
    assert cfg.d_model <= 512
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks, _, kw = _inputs(cfg, jax.random.PRNGKey(1))
    logits, _ = T.forward(cfg, params, toks, moe_strategy="dense", **kw)
    b, l = toks.shape[:2]
    exp_l = l + cfg.num_prefix_embeds if cfg.num_prefix_embeds else l
    if cfg.num_codebooks > 1:
        assert logits.shape == (b, exp_l, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, exp_l, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_train_step_no_nans(arch):
    cfg = configs.get(arch, "smoke")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    ostate = optim.init_state(params)
    toks, tgts, kw = _inputs(cfg, jax.random.PRNGKey(1))
    step = programs.make_train_step(
        cfg, optim.AdamWConfig(lr=1e-3), moe_strategy="dense", remat=False)
    params2, ostate2, loss, metrics = step(
        params, ostate, toks, tgts,
        prefix_embeds=kw.get("prefix_embeds"), memory=kw.get("memory"))
    assert np.isfinite(float(loss)), f"{arch} loss = {loss}"
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params2),
                                jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_decode_matches_forward(arch):
    """Prefill + one decode step == full forward at the next position."""
    cfg = configs.get(arch, "smoke")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks, _, kw = _inputs(cfg, jax.random.PRNGKey(1), l=9)  # 9 ids: 8+1
    mem = kw.get("memory")
    full_logits, _ = T.forward(cfg, params, toks, memory=mem,
                               moe_strategy="dense")
    _, caches = T.prefill(cfg, params, toks[:, :8], cache_len=9,
                          cache_dtype=jnp.float32, memory=mem,
                          moe_strategy="dense")
    dec, _ = T.decode_step(cfg, params, toks[:, 8:9], 8, caches, memory=mem)
    a = np.asarray(full_logits[:, 8], np.float32)
    d = np.asarray(dec[:, 0], np.float32)
    err = np.max(np.abs(a - d)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 2e-3, f"{arch}: decode/forward mismatch {err}"


def test_paper_model_configs_exist():
    for name in configs.PAPER_MODELS:
        cfg = configs.get(name)
        assert cfg.task == "diffusion"
        assert cfg.latent_shape
        smoke = configs.get(name, "smoke")
        assert smoke.d_model <= 512
