"""Sharding-rule tests (AbstractMesh — no devices needed) + HLO analyzer
regression tests for the accounting bugs found in §Perf."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import configs
from repro.launch import hlo_analysis, programs, sharding

def _abstract_mesh(sizes, names):
    """jax >= 0.5 takes (axis_sizes, axis_names); 0.4.x takes one
    tuple of (name, size) pairs."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


MESH_1POD = _abstract_mesh((16, 16), ("data", "model"))
MESH_2POD = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_prod(mesh, axes):
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
    return n


@pytest.mark.parametrize("mesh", [MESH_1POD, MESH_2POD],
                         ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_param_specs_divisible(arch, mesh):
    cfg = configs.get(arch)
    ps = programs.params_struct(cfg)
    specs = sharding.param_specs(mesh, ps, cfg)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

    def check(path, leaf, spec):
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            n = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                n *= sizes[a]
            assert leaf.shape[i] % n == 0, \
                f"{jax.tree_util.keystr(path)} {leaf.shape} {spec}"

    jax.tree_util.tree_map_with_path(check, ps, specs)


@pytest.mark.parametrize("arch", ["qwen3-14b", "mamba2-1.3b",
                                  "deepseek-v3-671b", "recurrentgemma-2b"])
def test_cache_specs_divisible(arch):
    from repro.config import SHAPES
    from repro.models import transformer as T
    mesh = MESH_1POD
    for shape_name in ("decode_32k", "long_500k"):
        shape = SHAPES[shape_name]
        cfg = programs.adapt_for_shape(configs.get(arch), shape)
        caches = jax.eval_shape(
            lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len))
        specs = sharding.cache_specs(mesh, cfg, caches, shape.global_batch)
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

        def check(path, leaf, spec):
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                n = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    n *= sizes[a]
                assert leaf.shape[i] % n == 0, \
                    f"{shape_name} {jax.tree_util.keystr(path)} {leaf.shape} {spec}"

        jax.tree_util.tree_map_with_path(check, caches, specs)


def test_tp_only_specs_have_no_batch_axes():
    cfg = configs.get("qwen3-14b")
    ps = programs.params_struct(cfg)
    specs = sharding.param_specs(MESH_1POD, ps, cfg, fsdp=False)
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        for ax in spec:
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            assert "data" not in axes and "pod" not in axes


def test_attn_not_sharded_when_heads_dont_divide():
    """internvl (14 heads) must not split heads over model=16."""
    cfg = configs.get("internvl2-1b")
    ps = programs.params_struct(cfg)
    specs = sharding.param_specs(MESH_1POD, ps, cfg)
    wq_spec = specs["stages"][0][0]["mixer"]["wq"]
    assert wq_spec[2] is None            # (repeat, D, H·dh): no model axis


def test_mla_sharded_when_heads_divide():
    """deepseek MLA (128 heads) keeps head-TP."""
    cfg = configs.get("deepseek-v3-671b")
    ps = programs.params_struct(cfg)
    specs = sharding.param_specs(MESH_1POD, ps, cfg)
    wq_b = specs["stages"][0][0]["mixer"]["wq_b"]
    assert wq_b[2] == "model"


# ---------------------------------------------------------------------------
# HLO analyzer regressions (§Perf-3 accounting bugs)
# ---------------------------------------------------------------------------

def test_loop_carry_not_counted_per_trip():
    """A scan that only slices a big carried buffer must not charge the
    whole buffer per iteration."""
    def f(buf):
        def body(c, i):
            return c + jnp.sum(jax.lax.dynamic_index_in_dim(buf, i, 0,
                                                            False)), None
        out, _ = jax.lax.scan(body, 0.0, jnp.arange(10))
        return out

    big = jax.ShapeDtypeStruct((10, 1024, 1024), jnp.float32)
    t = hlo_analysis.analyze(jax.jit(f).lower(big).compile().as_text())
    # buffer = 40 MB; per-trip slice = 4 MB; total must be << 10 × 40 MB
    assert t.bytes < 1.5e8, t.bytes


def test_dus_counted_at_slice_size():
    def f(buf, x):
        def body(c, i):
            return jax.lax.dynamic_update_index_in_dim(c, x, i, 0), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(8))
        return out

    buf = jax.ShapeDtypeStruct((8, 512, 512), jnp.float32)
    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    t = hlo_analysis.analyze(jax.jit(f).lower(buf, x).compile().as_text())
    # 8 slice writes of 1 MB + args ≈ ~2e7, not 8 × 8 MB
    assert t.bytes < 5e7, t.bytes
