"""Tests for the first-class cache-policy API (`repro.cache`):
registry specs, policy semantics, artifact round-trips, composites, and
pipeline-vs-hand-wired equivalence on the smoke DiT."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cache, configs
from repro.core import calibration, diffusion, schedule as S, solvers
from repro.core.executor import SmoothCacheExecutor


def _synthetic_curves(s_total=12, k_max=3, seed=0, types=("attn", "ffn")):
    rng = np.random.RandomState(seed)
    out = {}
    for t in types:
        c = np.full((s_total, k_max + 1), np.nan)
        c[:, 0] = 0.0
        for i in range(s_total):
            for k in range(1, min(k_max, i) + 1):
                c[i, k] = rng.uniform(0.01, 0.4) * k
        out[t] = c
    return out


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_flat_spec():
    p = cache.get("smoothcache:alpha=0.18")
    assert isinstance(p, cache.SmoothCache)
    assert p.alpha == 0.18 and p.k_max == 3
    p2 = cache.get("smoothcache:alpha=0.05,k_max=5")
    assert p2.alpha == 0.05 and p2.k_max == 5


def test_registry_aliases_and_passthrough():
    assert isinstance(cache.get("none"), cache.NoCache)
    assert isinstance(cache.get("fora:n=2"), cache.StaticInterval)
    assert isinstance(cache.get("budget:target=0.5"),
                      cache.BudgetedSmoothCache)
    p = cache.SmoothCache(0.1)
    assert cache.get(p) is p                       # policy passthrough
    assert cache.get(p.to_config()) == p           # config dict round-trip


def test_registry_unknown_name_raises():
    # ("teacache" used to be the canonical unknown name here — it is now a
    # registered alias of the adaptive policy)
    with pytest.raises(KeyError, match="unknown cache policy"):
        cache.get("fancycache:alpha=1")
    with pytest.raises(KeyError, match="unknown cache policy"):
        cache.from_config({"name": "nope"})


def test_registry_malformed_spec_raises():
    with pytest.raises(ValueError):
        cache.get("per_type(attn=static(n=2)")    # unbalanced paren
    with pytest.raises(ValueError):
        cache.get("static:2")                     # not k=v


def test_registry_nested_spec():
    p = cache.get("per_type(attn=smoothcache(alpha=0.1,k_max=2),"
                  "ffn=static(n=2),default=none)")
    assert isinstance(p, cache.PerLayerType)
    assert isinstance(p.policies["attn"], cache.SmoothCache)
    assert p.policies["attn"].k_max == 2
    assert isinstance(p.policies["ffn"], cache.StaticInterval)
    assert isinstance(p.default, cache.NoCache)
    # canonical spec re-parses to an equal policy
    assert cache.get(p.spec()) == p


def test_spec_round_trip_all_builtins():
    for spec in ("none", "static:n=3", "smoothcache:alpha=0.18,k_max=3",
                 "budget:k_max=3,target=0.5"):
        p = cache.get(spec)
        assert cache.get(p.spec()) == p
        assert cache.from_config(p.to_config()) == p


# ---------------------------------------------------------------------------
# policy semantics
# ---------------------------------------------------------------------------

def test_static_interval_equals_fora():
    types = ("attn", "ffn")
    for n in (1, 2, 3):
        sch_p = cache.StaticInterval(n).build(types, 20)
        sch_f = S.fora(types, 20, n)
        for t in types:
            np.testing.assert_array_equal(sch_p.skip[t], sch_f.skip[t])


def test_smoothcache_policy_matches_schedule_fn():
    curves = _synthetic_curves()
    sch_p = cache.SmoothCache(0.2, k_max=3).build(["attn", "ffn"], 12, curves)
    sch_f = S.smoothcache(curves, 0.2, k_max=3)
    for t in curves:
        np.testing.assert_array_equal(sch_p.skip[t], sch_f.skip[t])


def test_smoothcache_requires_curves():
    with pytest.raises(ValueError, match="curves"):
        cache.SmoothCache(0.2).build(["attn"], 10)


def test_budgeted_policy_hits_target():
    curves = _synthetic_curves(s_total=50)
    sch = cache.BudgetedSmoothCache(target=0.6).build(["attn", "ffn"], 50,
                                                      curves)
    frac = np.mean([sch.compute_fraction(t) for t in sch.skip])
    assert abs(frac - 0.6) < 0.15


def test_mismatched_curves_rejected():
    curves = _synthetic_curves(s_total=12, k_max=3)
    # wrong step count (e.g. stale artifact + strict=False pipeline)
    with pytest.raises(ValueError, match="12 steps"):
        cache.SmoothCache(0.2).build(["attn"], 30, curves)
    with pytest.raises(ValueError, match="steps"):
        cache.BudgetedSmoothCache(0.5).build(["attn"], 30, curves)
    # lag horizon smaller than the policy's k_max (would silently clamp)
    with pytest.raises(ValueError, match="k_max"):
        cache.SmoothCache(0.2, k_max=5).build(["attn"], 12, curves)
    with pytest.raises(ValueError, match="k_max"):
        cache.PerLayerType({"attn": cache.SmoothCache(0.2, k_max=5)}) \
            .build(["attn"], 12, curves)


def test_empty_error_curves_raises():
    with pytest.raises(ValueError, match="empty"):
        S.smoothcache({}, 0.1)


def test_per_type_composite_masks():
    curves = _synthetic_curves(s_total=10)
    p = cache.PerLayerType({"attn": cache.StaticInterval(2)},
                           default=cache.NoCache())
    sch = p.build(["attn", "ffn"], 10, None)
    np.testing.assert_array_equal(sch.skip["attn"],
                                  S.fora(["attn"], 10, 2).skip["attn"])
    assert not sch.skip["ffn"].any()               # default NoCache
    # calibrated sub-policy only sees its own type's curve
    p2 = cache.PerLayerType({"attn": cache.SmoothCache(0.2)},
                            default=cache.StaticInterval(3))
    sch2 = p2.build(["attn", "ffn"], 10, curves)
    np.testing.assert_array_equal(
        sch2.skip["attn"],
        S.smoothcache({"attn": curves["attn"]}, 0.2).skip["attn"])
    np.testing.assert_array_equal(sch2.skip["ffn"],
                                  S.fora(["ffn"], 10, 3).skip["ffn"])
    assert p2.requires_calibration and p2.k_max == 3


# ---------------------------------------------------------------------------
# schedule / artifact serialization
# ---------------------------------------------------------------------------

def test_schedule_from_json_tolerates_missing_fields():
    d = json.loads(S.fora(["attn"], 8, 2).to_json())
    del d["alpha"], d["name"]
    sch = S.Schedule.from_json(json.dumps(d))
    assert sch.alpha is None and sch.name == "schedule"
    np.testing.assert_array_equal(sch.skip["attn"],
                                  S.fora(["attn"], 8, 2).skip["attn"])


def test_schedule_content_key_stable():
    a = S.fora(["attn", "ffn"], 10, 2)
    b = S.Schedule({t: v.copy() for t, v in reversed(list(a.skip.items()))},
                   10, name=a.name)
    assert a.content_key() == b.content_key()      # key order irrelevant
    assert a.content_key() != S.fora(["attn", "ffn"], 10, 3).content_key()


def test_artifact_round_trip_bit_identical(tmp_path):
    curves = _synthetic_curves(s_total=16, seed=3)
    policy = cache.SmoothCache(alpha=0.17, k_max=3)
    sch = policy.build(["attn", "ffn"], 16, curves)
    art = cache.CacheArtifact(
        arch="dit-xl-256-smoke", solver="ddim", num_steps=16,
        policy=policy.to_config(), curves=curves, schedule=sch,
        meta={"calib_batch": 8})
    path = str(tmp_path / "a.cache.json")
    art.save(path)
    art2 = cache.CacheArtifact.load(path)
    # provenance survives
    assert art2.arch == art.arch and art2.solver == "ddim"
    assert art2.policy == policy.to_config()
    # curves are float-exact (Python repr floats are shortest-roundtrip)
    for t in curves:
        np.testing.assert_array_equal(
            np.nan_to_num(art2.curves[t]), np.nan_to_num(curves[t]))
    # stored schedule is bit-identical...
    assert art2.schedule.content_key() == sch.content_key()
    # ...and so is the one re-resolved from the stored curves + policy
    assert art2.resolve().content_key() == sch.content_key()
    # resolving a different policy against the same curves also works
    sch_b = art2.resolve(cache.BudgetedSmoothCache(target=0.5))
    assert sch_b.num_steps == 16


def test_artifact_future_format_rejected():
    curves = _synthetic_curves()
    art = cache.CacheArtifact("a", "ddim", 12, {"name": "none"}, curves)
    d = json.loads(art.to_json())
    d["format_version"] = 99
    with pytest.raises(ValueError, match="newer"):
        cache.CacheArtifact.from_json(json.dumps(d))


# ---------------------------------------------------------------------------
# pipeline vs hand-wired equivalence (smoke DiT)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dit():
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7), a.shape),
        params)
    return cfg, params


def test_pipeline_matches_hand_wired(small_dit):
    cfg, params = small_dit
    label = jnp.zeros((2,), jnp.int32)
    cond = {"label": label}

    # hand-wired flow (the pre-facade API)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(6), cfg_scale=1.5)
    curves, _, _ = calibration.calibrate(ex, params, jax.random.PRNGKey(1), 2,
                                         cond_args=cond, k_max=3)
    sch = S.smoothcache(curves, alpha=0.5, k_max=3)
    x_hand = ex.sample(params, jax.random.PRNGKey(2), 2, schedule=sch,
                       label=label)

    # facade flow
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(6),
                                   "smoothcache:alpha=0.5", cfg_scale=1.5)
    art = pipe.calibrate(params, jax.random.PRNGKey(1), 2, cond_args=cond)
    assert art.schedule.content_key() == sch.content_key()
    for t in curves:
        np.testing.assert_array_equal(np.nan_to_num(art.curves[t]),
                                      np.nan_to_num(curves[t]))
    x_pipe = pipe.generate(params, jax.random.PRNGKey(2), 2, label=label,
                           compiled=False)
    np.testing.assert_array_equal(np.asarray(x_hand), np.asarray(x_pipe))


def test_pipeline_artifact_serving_round_trip(small_dit, tmp_path):
    """A serving pipeline that loads the artifact reproduces the calibrating
    pipeline's schedule bit-identically and never recalibrates."""
    cfg, params = small_dit
    label = jnp.zeros((2,), jnp.int32)
    calib = cache.DiffusionPipeline(cfg, solvers.ddim(6),
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    calib.calibrate(params, jax.random.PRNGKey(1), 2,
                    cond_args={"label": label})
    path = str(tmp_path / "serve.cache.json")
    calib.save_artifact(path)

    serve = cache.DiffusionPipeline(cfg, solvers.ddim(6),
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    serve.load_artifact(path)
    assert serve.schedule.content_key() == calib.schedule.content_key()
    x1 = calib.generate(params, jax.random.PRNGKey(2), 2, label=label,
                        compiled=False)
    x2 = serve.generate(params, jax.random.PRNGKey(2), 2, label=label,
                        compiled=False)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


def test_pipeline_artifact_mismatch_rejected(small_dit, tmp_path):
    cfg, params = small_dit
    label = jnp.zeros((2,), jnp.int32)
    calib = cache.DiffusionPipeline(cfg, solvers.ddim(6),
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    calib.calibrate(params, jax.random.PRNGKey(1), 2,
                    cond_args={"label": label})
    path = str(tmp_path / "a.cache.json")
    calib.save_artifact(path)
    other = cache.DiffusionPipeline(cfg, solvers.ddim(9),      # wrong steps
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    with pytest.raises(ValueError, match="solver"):
        other.load_artifact(path)
    other.load_artifact(path, strict=False)        # explicit override works


def test_pipeline_calibration_free_policy_needs_no_calibrate(small_dit):
    cfg, params = small_dit
    label = jnp.zeros((1,), jnp.int32)
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(5), "static:n=2",
                                   cfg_scale=1.5)
    x = pipe.generate(params, jax.random.PRNGKey(0), 1, label=label,
                      compiled=False)
    assert x.shape == (1,) + tuple(cfg.latent_shape)
    assert pipe.schedule.content_key() == \
        S.fora(cfg.layer_types(), 5, 2).content_key()


def test_pipeline_uncalibrated_smoothcache_raises(small_dit):
    cfg, params = small_dit
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                   "smoothcache:alpha=0.2", cfg_scale=1.5)
    with pytest.raises(ValueError, match="calibrat"):
        pipe.generate(params, jax.random.PRNGKey(0), 1,
                      label=jnp.zeros((1,), jnp.int32), compiled=False)
