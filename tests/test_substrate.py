"""Substrate tests: optimizer, data pipeline, checkpoint, diffusion math,
solvers, analytic flops vs compiled-HLO cross-check."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # noqa: F401

from repro import checkpoint, configs, optim
from repro.core import diffusion, solvers
from repro.data import BlobLatents, CondLatents, TokenStream
from repro.launch import hlo_analysis
from repro.utils import flops


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = optim.init_state(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = optim.apply_updates(cfg, params, g, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_bounds_update():
    cfg = optim.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = optim.init_state(params)
    g = {"w": jnp.full(4, 1e6)}
    _, _, metrics = optim.apply_updates(cfg, params, g, state)
    assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip


def test_cosine_schedule_shape():
    f = optim.cosine_schedule(10, 100, final_frac=0.1)
    assert float(f(jnp.array(0))) < 0.11
    np.testing.assert_allclose(float(f(jnp.array(10))), 1.0, atol=0.01)
    np.testing.assert_allclose(float(f(jnp.array(1000))), 0.1, atol=0.01)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_token_stream_deterministic():
    s = TokenStream(100, 16, 2, seed=3)
    a, ta = s.batch_at(5)
    b, tb = s.batch_at(5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 16) and ta.shape == (2, 16)
    # targets are the next token
    full, _ = s.batch_at(5)


def test_blob_latents_class_separation():
    d = BlobLatents((16, 16, 4), 8, 64, seed=0)
    x, y = d.batch_at(0)
    assert x.shape == (64, 16, 16, 4)
    # same-class latents are closer than cross-class ones
    x0 = np.asarray(x[np.asarray(y) == 0])
    x1 = np.asarray(x[np.asarray(y) == 4])
    if len(x0) > 1 and len(x1) > 0:
        intra = np.linalg.norm(x0[0] - x0[1])
        inter = np.linalg.norm(x0[0] - x1[0])
        assert inter > intra


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_nested():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": (jnp.ones(4), None, [jnp.zeros(2), jnp.array(3)]),
            "c": {"d": jnp.float32(1.5)}}
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.ckpt")
        checkpoint.save(p, tree, {"k": 1})
        out, meta = checkpoint.restore(p)
    assert meta == {"k": 1}
    assert out["b"][1] is None
    assert isinstance(out["b"], tuple) and isinstance(out["b"][2], list)
    np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))


# ---------------------------------------------------------------------------
# diffusion math + solvers
# ---------------------------------------------------------------------------

def test_patchify_roundtrip():
    for arch in ("dit-xl-256", "opensora-v12", "stable-audio-open"):
        cfg = configs.get(arch, "smoke")
        x = jax.random.normal(jax.random.PRNGKey(0),
                              (2,) + tuple(cfg.latent_shape))
        tok = diffusion.patchify(cfg, x)
        back = diffusion.unpatchify(cfg, tok)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_q_sample_snr_monotone():
    sched = diffusion.vp_schedule()
    x0 = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 4, 2))
    noise = jax.random.normal(jax.random.PRNGKey(0), x0.shape)
    lo = diffusion.q_sample(sched, x0, jnp.array([10]), noise)
    hi = diffusion.q_sample(sched, x0, jnp.array([900]), noise)
    # at high t the sample is mostly noise; at low t mostly signal
    corr = lambda a, b: float(jnp.corrcoef(a.ravel(), b.ravel())[0, 1])
    assert corr(hi, noise) > corr(lo, noise)
    assert corr(lo, x0) > corr(hi, x0)


def test_ddim_recovers_known_eps():
    """If the model predicts the exact noise, DDIM recovers x0 exactly."""
    sched = diffusion.vp_schedule()
    solver = solvers.ddim(25, sched)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (2, 8))
    eps = jax.random.normal(jax.random.PRNGKey(1), (2, 8))
    ab = sched["alpha_bar"][solver.model_times.astype(jnp.int32)]
    x = jnp.sqrt(ab[0]) * x0 + jnp.sqrt(1 - ab[0]) * eps
    state = solver.init_state()
    for s in range(solver.num_steps):
        x, state = solver.step(x, eps, s, state)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x0), atol=1e-3)


def test_rf_euler_integrates_constant_velocity():
    solver = solvers.rectified_flow(20)
    v = jnp.full((1, 4), 2.0)
    x = jnp.zeros((1, 4))
    state = solver.init_state()
    for s in range(20):
        x, state = solver.step(x, v, s, state)
    np.testing.assert_allclose(np.asarray(x), -2.0, atol=1e-5)


def test_dpmpp_reduces_to_x0_at_end():
    solver = solvers.dpmpp_3m_sde(10, eta=0.0)
    x0 = jnp.ones((1, 4)) * 0.3
    sched = diffusion.vp_schedule()
    ab = sched["alpha_bar"][solver.model_times.astype(jnp.int32)]
    eps = jax.random.normal(jax.random.PRNGKey(0), (1, 4))
    x = jnp.sqrt(ab[0]) * x0 + jnp.sqrt(1 - ab[0]) * eps
    state = solver.init_state()
    for s in range(10):
        x, state = solver.step(x, eps, s, state, jax.random.PRNGKey(s))
    # exact-eps oracle → final x ≈ x0
    np.testing.assert_allclose(np.asarray(x), 0.3, atol=5e-2)


# ---------------------------------------------------------------------------
# analytic flops vs compiled HLO
# ---------------------------------------------------------------------------

def test_analytic_macs_matches_compiled_hlo():
    """Forward-pass FLOPs of a smoke model: analytic ≈ compiled (±20%)."""
    from repro.models import transformer as T
    cfg = configs.get("qwen3-14b", "smoke")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((2, 64), jnp.int32)
    fn = jax.jit(lambda p, t: T.forward(cfg, p, t)[0])
    txt = fn.lower(params, toks).compile().as_text()
    hlo_flops = hlo_analysis.analyze(txt).flops
    per = flops.model_macs_by_type(cfg, 64)
    analytic = 2 * 2 * (sum(per.values()) + flops.non_block_macs(cfg, 64))
    assert 0.8 < hlo_flops / analytic < 1.25, (hlo_flops, analytic)


def test_hlo_analyzer_counts_scan_trips():
    def f(a, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), a, ws)[0]
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    t = hlo_analysis.analyze(jax.jit(f).lower(x, w).compile().as_text())
    np.testing.assert_allclose(t.flops, 7 * 2 * 64 ** 3, rtol=1e-6)
