"""repro.obs — tracer/registry/cache-report units, the ServerMetrics
registry view, and the zero-sync telemetry regression on the smoke DiT.

The fast half runs against a virtual clock and a local fake executor
(same pattern as ``tests/test_serve.py`` — engine behavior is exact,
deterministic assertions).  The slow half (``small_dit`` fixture) pins
the acceptance invariants: fused step telemetry keeps
``executor.host_sync_count`` at 0, and per-row :class:`CacheReport`
realized decisions bit-match the host dispatch loop's
``return_decisions``.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro import serve
from repro.core import plan as plan_lib
from repro.obs import (CacheReport, MetricsRegistry, NULL_TRACER,
                       NullTracer, Tracer, TimeSeries, run_cache_reports,
                       schedule_cache_report, validate_chrome_trace)
from repro.serve.metrics import ServerMetrics, _dist, percentile
from repro.serve.request import VirtualClock

pytestmark = pytest.mark.obs


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_tracer_spans_export_and_validate():
    clock = VirtualClock()
    tr = Tracer(clock)
    t1 = tr.new_track("batch#1")
    tr.begin(t1, "run", group="g", bucket=2)
    clock.advance(1.0)
    tr.begin(t1, "advance")
    clock.advance(0.5)
    tr.end(t1, "advance", step_to=3)
    tr.instant("rung_move", rung=1)
    clock.advance(0.5)
    tr.end(t1, "run", outcome="done")
    obj = tr.to_chrome_trace()
    n = validate_chrome_trace(obj)
    assert n == 5                             # 2 B + 2 E + 1 i
    evs = obj["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {e["args"]["name"] for e in meta} == {"engine", "batch#1"}
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t" and inst["tid"] == 0
    # ts is microseconds of the virtual clock
    ends = [e for e in evs if e["ph"] == "E"]
    assert ends[0]["ts"] == pytest.approx(1.5e6)
    assert ends[1]["ts"] == pytest.approx(2.0e6)
    assert not tr.open_spans()


def test_tracer_span_contextmanager_and_len():
    tr = Tracer(VirtualClock())
    with tr.span(0, "outer"):
        with tr.span(0, "inner"):
            pass
    assert len(tr) == 4
    validate_chrome_trace(tr.to_chrome_trace())


def test_tracer_end_discipline():
    tr = Tracer(VirtualClock())
    with pytest.raises(ValueError, match="no open span"):
        tr.end(0)
    tr.begin(0, "run")
    with pytest.raises(ValueError, match="open .*span is 'run'"):
        tr.end(0, "advance")
    # the mismatch left the stack intact — the right end still works
    tr.end(0, "run")
    assert not tr.open_spans()


def test_tracer_open_spans_reported():
    tr = Tracer(VirtualClock())
    t1 = tr.new_track("b")
    tr.begin(t1, "run")
    assert tr.open_spans() == {t1: ("run",)}


def test_validate_rejects_malformed_traces():
    def ev(ph, ts, tid, name):
        return {"ph": ph, "ts": ts, "pid": 1, "tid": tid, "name": name}
    # dangling B
    with pytest.raises(ValueError, match="unclosed"):
        validate_chrome_trace({"traceEvents": [ev("B", 0, 1, "run")]})
    # E without B
    with pytest.raises(ValueError, match="without an open B"):
        validate_chrome_trace({"traceEvents": [ev("E", 0, 1, "run")]})
    # E name mismatch
    with pytest.raises(ValueError, match="closes"):
        validate_chrome_trace({"traceEvents": [
            ev("B", 0, 1, "run"), ev("E", 1, 1, "advance")]})
    # backwards timestamps within one track
    with pytest.raises(ValueError, match="backwards"):
        validate_chrome_trace({"traceEvents": [
            ev("B", 5, 1, "run"), ev("E", 1, 1, "run")]})


def test_null_tracer_is_inert():
    tr = NULL_TRACER
    assert isinstance(tr, NullTracer) and not tr.enabled
    assert tr.new_track("x") == 0
    tr.begin(3, "run")
    tr.end(3)                                 # no raise — no state at all
    tr.instant("anything")
    with tr.span(0, "s"):
        pass
    assert tr.to_chrome_trace() == {"traceEvents": []}
    with pytest.raises(ValueError, match="NullTracer"):
        tr.save("/tmp/never.json")


def test_tracer_save_roundtrip(tmp_path):
    tr = Tracer(VirtualClock())
    tr.instant("tick")
    path = tr.save(str(tmp_path / "t.trace.json"))
    with open(path) as f:
        assert validate_chrome_trace(json.load(f)) == 1


# ---------------------------------------------------------------------------
# MetricsRegistry / TimeSeries
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.inc("serve.shed", reason="backlog")
    reg.inc("serve.shed", 2, reason="deadline")
    assert reg.counter("serve.shed", reason="backlog") == 1
    assert reg.counter_total("serve.shed") == 3
    assert reg.labeled("serve.shed", "reason") == {"backlog": 1,
                                                   "deadline": 2}
    reg.set_gauge("slo.step_cost_s", 0.25, group="g")
    assert reg.gauge("slo.step_cost_s", group="g") == 0.25
    assert reg.gauge("slo.step_cost_s") is None
    reg.observe("serve.queue_wait_s", 1.0)
    reg.observe("serve.queue_wait_s", 3.0)
    assert reg.samples("serve.queue_wait_s") == [1.0, 3.0]
    snap = reg.snapshot()
    assert snap["counters"]['serve.shed{reason="backlog"}'] == 1
    assert snap["histograms"]["serve.queue_wait_s"] == {
        "n": 2, "sum": 4.0, "min": 1.0, "max": 3.0}
    names = reg.names()
    assert "serve.shed" in names["counters"]
    assert "serve.queue_wait_s" in names["histograms"]


def test_registry_exposition_format():
    reg = MetricsRegistry()
    reg.inc("serve.batches", 4)
    reg.observe("serve.service_s", 2.0)
    reg.series("slo.rung").record(0.0, 1.0)
    text = reg.exposition()
    assert "# TYPE serve.batches counter\nserve.batches 4" in text
    assert "serve.service_s_count 1" in text
    assert "serve.service_s_sum 2" in text
    assert "# TYPE slo.rung gauge\nslo.rung 1" in text


def test_registry_rejects_non_finite():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="non-finite"):
        reg.inc("c", float("nan"))
    with pytest.raises(ValueError, match="non-finite"):
        reg.set_gauge("g", float("inf"))
    with pytest.raises(ValueError, match="non-finite"):
        reg.observe("h", float("-inf"))
    with pytest.raises(ValueError, match="non-finite"):
        reg.series("s").record(0.0, float("nan"))


def test_timeseries_ring_eviction():
    ts = TimeSeries("x", capacity=3)
    for i in range(5):
        ts.record(float(i), float(i * 10))
    assert len(ts) == 3
    assert ts.items() == [(2.0, 20.0), (3.0, 30.0), (4.0, 40.0)]
    assert ts.last() == (4.0, 40.0)
    with pytest.raises(ValueError):
        TimeSeries("bad", capacity=0)


def test_registry_series_get_or_create():
    reg = MetricsRegistry()
    s1 = reg.series("slo.p95_wait_s", capacity=4)
    s2 = reg.series("slo.p95_wait_s")
    assert s1 is s2 and s1.capacity == 4


# ---------------------------------------------------------------------------
# percentile / _dist edge cases (satellite)
# ---------------------------------------------------------------------------

def test_percentile_single_sample_all_p():
    for p in (0, 37.5, 50, 100):
        assert percentile([4.2], p) == 4.2


def test_percentile_two_samples_boundaries():
    assert percentile([1.0, 3.0], 0) == 1.0
    assert percentile([1.0, 3.0], 100) == 3.0
    assert percentile([1.0, 3.0], 50) == 2.0
    assert percentile([3.0, 1.0], 25) == 1.5  # order-independent


def test_percentile_rejects_bad_inputs():
    with pytest.raises(ValueError, match="empty"):
        percentile([], 50)
    for p in (-1, 101):
        with pytest.raises(ValueError, match=r"\[0, 100\]"):
            percentile([1.0], p)
    for bad in (float("nan"), float("inf"), float("-inf")):
        with pytest.raises(ValueError, match="non-finite"):
            percentile([1.0, bad], 95)
        with pytest.raises(ValueError, match="non-finite"):
            _dist([1.0, bad])
    # NaN would otherwise corrupt silently: sorted() leaves it in place
    assert math.isnan(sorted([1.0, float("nan"), 0.5])[1])


def test_dist_empty_is_null_shape():
    assert _dist([]) == {"mean": None, "p50": None, "p95": None,
                         "max": None, "n": 0}


# ---------------------------------------------------------------------------
# ServerMetrics as a registry view (satellite: first-class lineage)
# ---------------------------------------------------------------------------

def _req(rid, arrival=0.0, started=1.0, finished=2.0, joined_at=None):
    r = serve.Request(rid=rid, seed=rid, policy="p", arrival=arrival)
    r.started, r.finished, r.joined_at = started, finished, joined_at
    return r


def test_server_metrics_is_a_registry_view():
    reg = MetricsRegistry()
    m = ServerMetrics(registry=reg)
    assert m.registry is reg
    m.observe_request(_req(0))
    m.observe_request(_req(1, started=2.0, finished=5.0, joined_at=1.5))
    m.observe_batch("g", 2, 0.5, num_steps=4, num_types=2)
    m.observe_merge(kind="join")
    m.observe_merge(kind="coalesce")
    m.observe_lineage("join")
    m.observe_lineage("regroup", 3)
    m.observe_fault("g", "nan_latent")
    # the legacy attribute surface reads through the registry
    assert m.requests == 2 and m.batches == 1
    assert m.queue_waits == [1.0, 2.0]
    assert m.joined_queue_waits == [2.0]      # joiner-specific wait dist
    assert m.merges == 2
    assert m.lineage_events == {"join": 1, "regroup": 3}
    assert m.fault_kinds == {"nan_latent": 1}
    # and the same numbers are visible in the raw registry
    assert reg.counter("continuous.merges", kind="coalesce") == 1
    assert reg.samples("serve.queue_wait_joined_s") == [2.0]


def test_report_extends_continuous_with_lineage_and_joined_waits():
    m = ServerMetrics()
    m.observe_request(_req(0))
    m.observe_request(_req(1, started=2.0, finished=5.0, joined_at=1.5))
    m.observe_join(1)
    m.observe_merge(kind="join")
    m.observe_lineage("join")
    rep = m.report()
    cont = rep["continuous"]
    assert cont["joins"] == 1 and cont["join_merges"] == 1
    assert cont["coalesces"] == 0
    assert cont["lineage_events"] == {"join": 1}
    assert cont["joined_queue_wait_s"]["n"] == 1
    assert cont["joined_queue_wait_s"]["p50"] == 2.0
    json.dumps(rep)                           # JSON-safe end to end


# ---------------------------------------------------------------------------
# CacheReport builders
# ---------------------------------------------------------------------------

def _static_schedule(steps=4):
    from repro.core import schedule as S
    return S.fora(("attn", "ffn"), steps, 2)


def test_schedule_cache_report_matches_schedule():
    sch = _static_schedule(4)
    rep = schedule_cache_report(sch, tau=0.0)
    assert rep.num_steps == 4 and rep.types == ("attn", "ffn")
    assert rep.desired == rep.realized
    skipped = sum(len(s) for s in rep.realized)
    assert rep.realized_compute_fraction() == \
        pytest.approx(1.0 - skipped / 8.0)
    assert rep.skipped_per_type() == rep.desired_per_type()
    traj = rep.proxy_vs_threshold()
    assert len(traj) == 4 and traj[0]["proxy"] is None
    json.dumps(rep.to_jsonable())


def test_run_cache_reports_decisions_fallback():
    @dataclasses.dataclass
    class FakeState:
        decisions: tuple
        tau: float = 0.1
    rs = FakeState(decisions=((), ("attn",), ("attn", "ffn")))
    reps = run_cache_reports(rs, 2, schedule=_static_schedule(3))
    assert len(reps) == 2
    assert reps[0].desired == reps[0].realized == \
        ((), ("attn",), ("attn", "ffn"))
    assert reps[0].tau == 0.1
    assert reps[0].skipped_per_type() == {"attn": 2, "ffn": 1}


def test_run_cache_reports_schedule_fallback_and_empty():
    class Bare:
        pass
    assert run_cache_reports(Bare(), 2) == []
    reps = run_cache_reports(Bare(), 3, schedule=_static_schedule(4),
                             tau=0.2)
    assert len(reps) == 3 and reps[0].tau == 0.2


def test_cache_report_zero_steps_fraction():
    rep = CacheReport(tau=0.0, types=(), desired=(), realized=())
    assert rep.realized_compute_fraction() == 1.0


# ---------------------------------------------------------------------------
# Controller → registry/tracer hooks
# ---------------------------------------------------------------------------

def test_controller_records_series_and_rung_instants():
    from repro import slo
    reg = MetricsRegistry()
    tr = Tracer(VirtualClock())
    ctrl = slo.ElasticTauController(
        3, target_p95_wait_s=1.0, min_samples=2, interval_s=0.0,
        cooldown_s=0.0, registry=reg, tracer=tr)
    for t in (0.0, 1.0, 2.0):
        ctrl.observe_wait(10.0, t)
        ctrl.update(t)
    assert ctrl.rung >= 1
    p95 = reg.series("slo.p95_wait_s")
    assert len(p95) >= 1 and p95.last()[1] == pytest.approx(10.0)
    rungs = [v for _, v in reg.series("slo.rung").items()]
    assert rungs and rungs[0] == 1.0
    moves = [e for e in tr.to_chrome_trace()["traceEvents"]
             if e.get("name") == "rung_move"]
    assert moves and moves[0]["args"]["from_rung"] == 0


# ---------------------------------------------------------------------------
# Engine lifecycle tracing on the virtual clock (fake executor)
# ---------------------------------------------------------------------------

class _FakeCfg:
    name = "fake-arch"

    def layer_types(self):
        return ("attn", "ffn")


class _FakeSolver:
    name = "ddim"

    def __init__(self, num_steps=8):
        self.num_steps = num_steps


@dataclasses.dataclass
class _FakeRunState:
    plan: plan_lib.ExecutionPlan
    batch: int
    run_index: int = 0
    x: object = None
    decisions = None

    @property
    def done(self):
        return self.run_index >= len(self.plan.runs)


class _FakeExecutor:
    def __init__(self, clock, step_cost=1.0):
        self.clock = clock
        self.step_cost = step_cost
        self._programs = set()

    def start_run(self, params, key, batch, *, plan, schedule=None,
                  label=None, memory=None):
        return _FakeRunState(plan=plan, batch=batch)

    def advance_run(self, params, rs, *, check=False):
        run = rs.plan.runs[rs.run_index]
        self._programs.add(("seg", run.sig, rs.batch))
        computed = sum(1 for sk in run.sig.skip.values() if not sk)
        self.clock.advance(self.step_cost * run.length
                           * computed / max(len(run.sig.skip), 1))
        rs = dataclasses.replace(rs, run_index=rs.run_index + 1)
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def compiled_variant_count(self, kind=None):
        if kind is None:
            return len(self._programs)
        return len({p for p in self._programs if p[0] == kind})

    def xla_program_count(self, kind=None):
        return self.compiled_variant_count(kind)


def _run_fake_engine(tracer=None, n=5):
    clock = serve.VirtualClock()
    store = serve.ArtifactStore(_FakeCfg(), _FakeSolver(8))
    store.add_policy("static2", "static:n=2")
    eng = serve.ServeEngine(_FakeExecutor(clock), params=None, store=store,
                            clock=clock, max_batch=4, tracer=tracer)
    eng.submit(*[serve.Request(rid=i, seed=i, policy="static2",
                               arrival=0.1 * i) for i in range(n)])
    res = eng.run_until_drained()
    return eng, res


def test_engine_traced_run_validates_and_is_identical(tmp_path):
    eng_off, res_off = _run_fake_engine(tracer=None)
    clock = serve.VirtualClock()              # tracer shares engine clock
    tr = Tracer(clock)
    store = serve.ArtifactStore(_FakeCfg(), _FakeSolver(8))
    store.add_policy("static2", "static:n=2")
    eng_on = serve.ServeEngine(_FakeExecutor(clock), params=None,
                               store=store, clock=clock, max_batch=4,
                               tracer=tr)
    assert eng_on.tracer is tr
    assert store.tracer is tr and eng_on.batcher.tracer is tr
    eng_on.submit(*[serve.Request(rid=i, seed=i, policy="static2",
                                  arrival=0.1 * i) for i in range(5)])
    res_on = eng_on.run_until_drained()
    # tracing changes nothing observable: same latents, same records
    assert sorted(res_on) == sorted(res_off)
    for rid in res_on:
        np.testing.assert_array_equal(res_on[rid], res_off[rid])
    assert [r.bucket for r in eng_on.records] \
        == [r.bucket for r in eng_off.records]
    # the exported trace validates and covers the full lifecycle
    obj = tr.to_chrome_trace()
    assert validate_chrome_trace(obj) > 0
    evs = obj["traceEvents"]
    names = {e["name"] for e in evs if e["ph"] != "M"}
    assert {"submit", "form", "run", "advance"} <= names
    # one track per launched batch, named by serial
    tracks = [e["args"]["name"] for e in evs if e["ph"] == "M"]
    batch_tracks = [t for t in tracks if t.startswith("batch#")]
    assert len(batch_tracks) == len(eng_on.records)
    # every run span ended with an outcome
    outcomes = [e["args"]["outcome"] for e in evs
                if e["ph"] == "E" and e["name"] == "run"]
    assert outcomes and all(o == "done" for o in outcomes)
    # plan advances carry the segment label from ExecutionPlan.run_label
    segs = [e["args"]["segment"] for e in evs
            if e["ph"] == "B" and e["name"] == "advance"
            and "segment" in e.get("args", {})]
    assert segs and all(s.startswith("seg[") for s in segs)
    path = tr.save(str(tmp_path / "serve.trace.json"))
    with open(path) as f:
        validate_chrome_trace(json.load(f))


def test_engine_shed_and_reject_instants():
    clock = serve.VirtualClock()
    tr = Tracer(clock)
    store = serve.ArtifactStore(_FakeCfg(), _FakeSolver(8))
    store.add_policy("static2", "static:n=2")
    eng = serve.ServeEngine(_FakeExecutor(clock), params=None, store=store,
                            clock=clock, max_batch=4, tracer=tr)
    eng.submit(serve.Request(rid=0, seed=0, policy="nope", arrival=0.0))
    eng.submit(serve.Request(rid=1, seed=1, policy="static2", arrival=0.0))
    eng.submit(serve.Request(rid=1, seed=1, policy="static2", arrival=0.0))
    eng.run_until_drained()
    evs = tr.to_chrome_trace()["traceEvents"]
    rejects = [e for e in evs if e.get("name") == "reject"]
    assert {e["args"]["reason"] for e in rejects} \
        == {"no_entry", "duplicate_rid"}
    assert eng.report()["faults"]["rejected_submissions"] \
        == {"duplicate_rid": 1, "no_entry": 1}


def test_engine_run_label_helper():
    sch = _static_schedule(6)
    plan = plan_lib.analyze(sch)
    labels = [plan.run_label(i) for i in range(len(plan.runs))]
    assert all(lab.startswith("seg[") and "steps[" in lab
               for lab in labels)
    with pytest.raises(IndexError):
        plan.run_label(len(plan.runs))


def test_resilience_policy_deadline_helper():
    from repro.resilience import ResiliencePolicy
    pol = ResiliencePolicy(watchdog_factor=3.0, watchdog_floor_s=0.5)
    assert pol.deadline(2.0) == pytest.approx(6.5)
    none_pol = ResiliencePolicy(watchdog_factor=None)
    with pytest.raises(ValueError, match="watchdog_factor"):
        none_pol.deadline(1.0)


def test_cost_model_snapshot_shapes():
    from repro.slo.admission import ServiceCostModel
    m = ServiceCostModel()
    assert m.snapshot() == {"global": None, "per_group": {},
                            "per_key": {}}
    m.observe("g", 2.0, 4, bucket=2)
    snap = m.snapshot()
    assert snap["global"] is not None
    assert "g" in snap["per_group"] and "g|b2" in snap["per_key"]


# ---------------------------------------------------------------------------
# Zero-sync telemetry on the smoke DiT (slow; acceptance regression)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dit():
    import jax
    from repro import configs
    from repro.core import diffusion
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape),
        params)
    return cfg, params


def _calibrated(cfg, params, tau, steps=6):
    import jax
    import jax.numpy as jnp
    from repro import cache
    from repro.core import solvers
    pipe = cache.DiffusionPipeline(
        cfg, solvers.ddim(steps),
        f"adaptive:base=smoothcache(alpha=0.5),tau={tau}", cfg_scale=1.5)
    pipe.calibrate(params, jax.random.PRNGKey(1), 2,
                   cond_args={"label": jnp.zeros((2,), jnp.int32)})
    return pipe


def test_fused_telemetry_zero_sync_and_reports_match_host(small_dit,
                                                          monkeypatch):
    """Acceptance: step telemetry ON adds zero host syncs, and the
    per-row CacheReport realized decisions bit-match the host dispatch
    loop's ``return_decisions``."""
    import jax
    import jax.numpy as jnp
    cfg, params = small_dit
    steps, tau = 6, 0.3
    pipe = _calibrated(cfg, params, tau, steps)
    ex = pipe.executor
    label = jnp.zeros((2,), jnp.int32)
    key = jax.random.PRNGKey(4)
    # warm the telemetry program (compilation is not a sync)
    rs0 = ex.start_adaptive_fused_run(
        params, key, 2, schedule=pipe.schedule, tau=tau,
        proxy_map=pipe.proxy_map, label=label, telemetry=True)
    while not rs0.done:
        rs0 = ex.advance_adaptive_fused(params, rs0, n_steps=2)
    ex.host_sync_count = 0
    d2h = {"n": 0}
    real_device_get = jax.device_get

    def counting_device_get(x):
        d2h["n"] += 1
        return real_device_get(x)

    monkeypatch.setattr(jax, "device_get", counting_device_get)
    with jax.transfer_guard_device_to_host("disallow"):
        rs = ex.start_adaptive_fused_run(
            params, key, 2, schedule=pipe.schedule, tau=tau,
            proxy_map=pipe.proxy_map, label=label, telemetry=True)
        while not rs.done:
            rs = ex.advance_adaptive_fused(params, rs, n_steps=3)
    assert d2h["n"] == 0 and ex.host_sync_count == 0
    monkeypatch.undo()
    # one boundary read builds every row's report
    reps = run_cache_reports(rs, 2)
    assert len(reps) == 2
    # realized decisions bit-match the host dispatch loop
    _, d_host = ex.sample_adaptive(
        params, key, 2, schedule=pipe.schedule, tau=tau,
        proxy_map=pipe.proxy_map, label=label, return_decisions=True)
    for rep in reps:
        assert rep.realized == d_host == rs.decisions
        assert rep.num_steps == steps
        # realized is the AND of the rows' desires
        for s in range(steps):
            for t in rep.realized[s]:
                assert all(t in r.desired[s] for r in reps)
        # proxy trajectory recorded: step 0 masked, the rest finite
        assert rep.proxy is not None and rep.proxy[0] is None
        assert all(p is not None and math.isfinite(p)
                   for p in rep.proxy[1:])
    # telemetry never changes the latents: same run without it
    rs_plain = ex.start_adaptive_fused_run(
        params, key, 2, schedule=pipe.schedule, tau=tau,
        proxy_map=pipe.proxy_map, label=label)
    while not rs_plain.done:
        rs_plain = ex.advance_adaptive_fused(params, rs_plain, n_steps=3)
    np.testing.assert_array_equal(np.asarray(rs.x), np.asarray(rs_plain.x))


def test_engine_telemetry_and_tracing_bit_identical(small_dit, tmp_path):
    """Serving with tracer + telemetry on produces bit-identical latents
    to serving with both off, populates per-request cache reports, and
    exports a valid trace."""
    import jax
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor
    cfg, params = small_dit
    steps, tau = 6, 0.3
    pipe = _calibrated(cfg, params, tau, steps)
    path = str(tmp_path / "adaptive.cache.json")
    pipe.save_artifact(path)

    def serve_once(obs):
        clock = serve.VirtualClock()
        solver = solvers.ddim(steps)
        ex = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
        store = serve.ArtifactStore(cfg, solver, cfg_scale=1.5)
        store.add_artifact("gen", path)
        kw = {}
        if obs:
            kw = {"tracer": Tracer(clock), "telemetry": True}
        eng = serve.ServeEngine(ex, params, store, clock=clock,
                                max_batch=2, adaptive_chunk=3, **kw)
        eng.submit(*[serve.Request(rid=i, seed=100 + i, policy="gen",
                                   label=i % cfg.num_classes, arrival=0.0)
                     for i in range(2)])
        res = eng.run_until_drained()
        return eng, res, ex

    eng_on, res_on, ex_on = serve_once(True)
    eng_off, res_off, _ = serve_once(False)
    assert sorted(res_on) == sorted(res_off) == [0, 1]
    for rid in res_on:
        np.testing.assert_array_equal(res_on[rid], res_off[rid])
    # telemetry stayed sync-free on the fused path
    assert ex_on.host_sync_count == 0
    assert not eng_off.cache_reports
    assert sorted(eng_on.cache_reports) == [0, 1]
    rec = eng_on.records[0]
    for rid in rec.rids:
        rep = eng_on.cache_reports[rid]
        assert rep.realized == rec.decisions
        assert rep.tau == tau and rep.proxy is not None
    # trace validates after the drain (all spans closed)
    assert not eng_on.tracer.open_spans()
    assert validate_chrome_trace(eng_on.tracer.to_chrome_trace()) > 0
