"""Input-adaptive runtime caching (`AdaptivePolicy` / `sample_adaptive`):
mask-lattice candidate pools, proxy→error map fitting, τ=0 bitwise
reduction to the static segmented path, compile-count bounds, artifact
round-trips — plus regression tests for the PR's latent-bugfix sweep
(plan-property routing in generate(), flat registry grammar with nested
values, strict cfg_scale validation, CFG cond-half calibration)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cache, configs
from repro.core import calibration, diffusion, plan as plan_lib
from repro.core import schedule as S, solvers
from repro.core.executor import SmoothCacheExecutor


# ---------------------------------------------------------------------------
# Candidate pool (pure)
# ---------------------------------------------------------------------------

def _sched(skip_rows, types=("attn", "ffn")):
    skip = {t: np.asarray(v, bool) for t, v in zip(types, skip_rows)}
    return S.Schedule(skip, len(skip_rows[0]))


def test_mask_lattice_is_powerset_of_ever_skipped():
    sch = _sched([[0, 1, 1, 0, 1], [0, 0, 1, 0, 0]])
    pool = plan_lib.mask_lattice(sch)
    assert len(pool) == 4                       # 2^2
    # all-compute first; every signature shares one cache structure
    assert pool[0].live_in == ()
    assert {sig.structure for sig in pool} == {("attn", "ffn")}
    # every static mask of the schedule is in the pool
    idx = plan_lib.pool_index(pool)
    for s in range(sch.num_steps):
        skipset = frozenset(t for t, sk in sch.mask_key_at(s) if sk)
        assert skipset in idx
    # collect is the complement of the skip set within the lattice types
    for sig in pool:
        assert set(sig.collect) == {"attn", "ffn"} - set(sig.live_in)


def test_mask_lattice_excludes_never_skipped_types():
    sch = _sched([[0, 1, 0, 1], [0, 0, 0, 0]])    # ffn never skipped
    pool = plan_lib.mask_lattice(sch)
    assert len(pool) == 2
    for sig in pool:
        assert "ffn" not in sig.structure         # never resident
        assert "ffn" not in sig.collect


def test_mask_lattice_no_skips_is_single_program():
    pool = plan_lib.mask_lattice(_sched([[0, 0, 0], [0, 0, 0]]))
    assert len(pool) == 1 and pool[0].collect == ()


def test_mask_lattice_size_guard():
    types = tuple(f"t{i}" for i in range(plan_lib.MAX_LATTICE_TYPES + 1))
    rows = [[0, 1] for _ in types]
    with pytest.raises(ValueError, match="lattice"):
        plan_lib.mask_lattice(_sched(rows, types=types))


# ---------------------------------------------------------------------------
# Proxy map (pure)
# ---------------------------------------------------------------------------

def test_fit_proxy_map_recovers_linear_relation():
    s_total, a, b = 20, 0.7, 0.02
    proxies = np.full(s_total, np.nan)
    proxies[1:] = np.linspace(0.1, 0.5, s_total - 1)
    err = np.full((s_total, 4), np.nan)
    err[:, 0] = 0.0
    err[1:, 1] = a * proxies[1:] + b
    pm = calibration.fit_proxy_map({"attn": err}, proxies)
    fa, fb = pm.coeffs["attn"]
    assert abs(fa - a) < 1e-8 and abs(fb - b) < 1e-8
    assert pm.est("attn", 0.3) == pytest.approx(a * 0.3 + b)
    # estimates are clamped at zero
    assert pm.est("attn", -100.0) == 0.0


def test_fit_proxy_map_degenerate_falls_back_to_mean():
    s_total = 8
    proxies = np.full(s_total, np.nan)
    proxies[1:] = 0.25                           # constant proxy
    err = np.full((s_total, 2), np.nan)
    err[:, 0] = 0.0
    err[1:, 1] = 0.1
    pm = calibration.fit_proxy_map({"ffn": err}, proxies)
    assert pm.coeffs["ffn"][0] == 0.0
    assert pm.est("ffn", 123.0) == pytest.approx(0.1)


def test_proxy_map_json_roundtrip():
    pm = calibration.ProxyMap({"attn": (0.5, 0.01), "ffn": (0.0, 0.2)},
                              mean_proxy=0.3)
    pm2 = calibration.ProxyMap.from_jsonable(pm.to_jsonable())
    assert pm2 == pm
    nan_pm = calibration.ProxyMap({"attn": (1.0, 0.0)})
    back = calibration.ProxyMap.from_jsonable(nan_pm.to_jsonable())
    assert np.isnan(back.mean_proxy)


def test_proxies_from_inputs_alignment():
    inputs = [np.zeros((1, 4)), np.ones((1, 4)), np.ones((1, 4))]
    p = calibration.proxies_from_inputs(inputs)
    assert np.isnan(p[0])                        # step 0 has no predecessor
    assert p[2] == 0.0                           # identical inputs
    assert p[1] > 0


# ---------------------------------------------------------------------------
# Policy / registry specs
# ---------------------------------------------------------------------------

def test_adaptive_spec_roundtrip():
    p = cache.get("adaptive:base=smoothcache(alpha=0.18,k_max=3),tau=0.05")
    assert isinstance(p, cache.AdaptivePolicy)
    assert isinstance(p.base, cache.SmoothCache)
    assert p.tau == 0.05 and p.k_max == 3
    assert cache.get(p.spec()) == p
    assert cache.from_config(p.to_config()) == p
    # teacache alias, calibration-free base
    q = cache.get("teacache:base=static(n=2),tau=0.1")
    assert isinstance(q.base, cache.StaticInterval)
    assert q.requires_calibration                 # proxy map needs a pass
    assert cache.get(q.spec()) == q


def test_adaptive_policy_validation():
    with pytest.raises(ValueError, match="nest"):
        cache.AdaptivePolicy(base=cache.AdaptivePolicy())
    with pytest.raises(ValueError, match="tau"):
        cache.AdaptivePolicy(tau=-0.1)


def test_adaptive_build_is_base_schedule():
    curves_err = np.full((10, 4), np.nan)
    curves_err[:, 0] = 0.0
    curves_err[1:, 1:] = 0.01
    curves = {"attn": curves_err, "ffn": curves_err.copy()}
    p = cache.AdaptivePolicy(base=cache.SmoothCache(0.1), tau=0.3)
    sch = p.build(["attn", "ffn"], 10, curves)
    base = cache.SmoothCache(0.1).build(["attn", "ffn"], 10, curves)
    assert sch.content_key() == base.content_key()


# -- flat-grammar bugfix: nested values in the CLI-friendly form -----------

def test_registry_flat_spec_with_nested_value():
    p = cache.get("per_type:attn=smoothcache(alpha=0.1)")
    assert isinstance(p, cache.PerLayerType)
    assert isinstance(p.policies["attn"], cache.SmoothCache)
    assert p.policies["attn"].alpha == 0.1
    # equivalent to the parenthesized form
    assert p == cache.get("per_type(attn=smoothcache(alpha=0.1))")
    # multiple args, nested + scalar mixed
    q = cache.get("per_type:attn=smoothcache(alpha=0.2,k_max=2),"
                  "default=static(n=3)")
    assert q.policies["attn"].k_max == 2
    assert isinstance(q.default, cache.StaticInterval)
    # genuinely malformed specs still fail
    with pytest.raises(ValueError):
        cache.get("per_type(attn=static(n=2)")


# ---------------------------------------------------------------------------
# Executor: the adaptive path (smoke DiT)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dit():
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7), a.shape),
        params)
    return cfg, params


def _calibrated_adaptive(cfg, params, tau, steps=8, alpha=0.5):
    label = jnp.zeros((2,), jnp.int32)
    pipe = cache.DiffusionPipeline(
        cfg, solvers.ddim(steps),
        f"adaptive:base=smoothcache(alpha={alpha}),tau={tau}", cfg_scale=1.5)
    pipe.calibrate(params, jax.random.PRNGKey(1), 2,
                   cond_args={"label": label})
    return pipe, label


def test_adaptive_tau0_bitwise_equals_sample_compiled(small_dit):
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0)
    assert any(v.any() for v in pipe.schedule.skip.values())
    x_ad = pipe.generate(params, jax.random.PRNGKey(2), 2, label=label)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(8), cfg_scale=1.5)
    x_st = ex.sample_compiled(params, jax.random.PRNGKey(2), 2,
                              schedule=pipe.schedule, label=label)
    np.testing.assert_array_equal(np.asarray(x_ad), np.asarray(x_st))


def test_adaptive_compile_count_bounded_by_pool(small_dit):
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0.3)
    pool = plan_lib.mask_lattice(pipe.schedule)
    # heterogeneous inputs: different seeds and labels force different
    # per-step decisions, but never new programs
    for seed in (2, 3, 4):
        lab = jnp.full((2,), seed % cfg.num_classes, jnp.int32)
        x, dec = pipe.generate(params, jax.random.PRNGKey(seed), 2,
                               label=lab, return_decisions=True)
        assert len(dec) == 8 and dec[0] == ()     # step 0 computes all
        assert bool(jnp.all(jnp.isfinite(x)))
    assert 0 < pipe.executor.compiled_variant_count("sigstep") <= len(pool)


def test_adaptive_decisions_respect_k_max(small_dit):
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=100.0)
    _, dec = pipe.generate(params, jax.random.PRNGKey(5), 2, label=label,
                           return_decisions=True)
    # an absurdly large tau reuses as hard as allowed: cache age caps at
    # the policy's k_max, so every k_max+1-length window recomputes
    k_max = pipe.policy.k_max
    age = {t: 0 for t in cfg.layer_types()}
    for step in dec:
        for t in cfg.layer_types():
            if t in step:
                age[t] += 1
                assert age[t] <= k_max
            else:
                age[t] = 0


def test_adaptive_tau_without_proxy_map_raises(small_dit):
    cfg, params = small_dit
    ex = SmoothCacheExecutor(cfg, solvers.ddim(6), cfg_scale=1.5)
    sch = S.fora(cfg.layer_types(), 6, 2)
    with pytest.raises(ValueError, match="proxy_map"):
        ex.sample_adaptive(params, jax.random.PRNGKey(0), 1, schedule=sch,
                           tau=0.1, label=jnp.zeros((1,), jnp.int32))


def test_adaptive_artifact_roundtrip(small_dit, tmp_path):
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0.3)
    assert pipe.artifact.adaptive is not None
    assert pipe.artifact.adaptive["tau"] == 0.3
    path = str(tmp_path / "adaptive.cache.json")
    pipe.save_artifact(path)

    serve = cache.DiffusionPipeline(
        cfg, solvers.ddim(8), "adaptive:base=smoothcache(alpha=0.5),tau=0.3",
        cfg_scale=1.5)
    art = serve.load_artifact(path)
    # adaptive config + fitted mapping survive; serving never recalibrates
    assert art.adaptive == pipe.artifact.adaptive
    assert serve.proxy_map == pipe.proxy_map
    assert cache.from_config(art.policy) == pipe.policy
    x1, d1 = pipe.generate(params, jax.random.PRNGKey(9), 2, label=label,
                           return_decisions=True)
    x2, d2 = serve.generate(params, jax.random.PRNGKey(9), 2, label=label,
                            return_decisions=True)
    assert d1 == d2
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


def test_adaptive_artifact_tau_mismatch_rejected(small_dit, tmp_path):
    """The runtime rule must use the artifact's decision parameters — a
    serving pipeline constructed with a different tau/k_max must not
    silently generate under the artifact's provenance."""
    cfg, params = small_dit
    pipe, _ = _calibrated_adaptive(cfg, params, tau=0.3)
    path = str(tmp_path / "tau.cache.json")
    pipe.save_artifact(path)
    other = cache.DiffusionPipeline(
        cfg, solvers.ddim(8), "adaptive:base=smoothcache(alpha=0.5),tau=0.05",
        cfg_scale=1.5)
    with pytest.raises(ValueError, match="tau"):
        other.load_artifact(path)
    other.load_artifact(path, strict=False)       # explicit override works
    # a matching policy loads fine
    same = cache.DiffusionPipeline(
        cfg, solvers.ddim(8), "adaptive:base=smoothcache(alpha=0.5),tau=0.3",
        cfg_scale=1.5)
    same.load_artifact(path)


def test_adaptive_explicit_schedule_override_is_static(small_dit):
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0.3)
    sch = S.fora(cfg.layer_types(), 8, 2)
    x = pipe.generate(params, jax.random.PRNGKey(2), 2, label=label,
                      schedule=sch)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(8), cfg_scale=1.5)
    x_st = ex.sample_compiled(params, jax.random.PRNGKey(2), 2, schedule=sch,
                              label=label)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(x_st))
    with pytest.raises(ValueError, match="return_decisions"):
        pipe.generate(params, jax.random.PRNGKey(2), 2, label=label,
                      schedule=sch, return_decisions=True)


# ---------------------------------------------------------------------------
# Bugfix regressions: pipeline plan routing
# ---------------------------------------------------------------------------

def _spy_sample_compiled(monkeypatch, captured):
    orig = SmoothCacheExecutor.sample_compiled

    def spy(self, *args, **kwargs):
        captured["plan"] = kwargs.get("plan")
        return orig(self, *args, **kwargs)

    monkeypatch.setattr(SmoothCacheExecutor, "sample_compiled", spy)


def test_generate_after_prepare_hands_plan_to_executor(small_dit,
                                                       monkeypatch):
    """prepare() resets _plan to None; generate() must route through the
    lazy .plan property instead of silently passing plan=None."""
    cfg, params = small_dit
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(5), "static:n=2",
                                   cfg_scale=1.5)
    pipe.prepare()
    captured = {}
    _spy_sample_compiled(monkeypatch, captured)
    pipe.generate(params, jax.random.PRNGKey(0), 1,
                  label=jnp.zeros((1,), jnp.int32))
    assert captured["plan"] is not None
    assert captured["plan"] is pipe.plan


def test_generate_hands_artifact_plan_to_executor(small_dit, monkeypatch,
                                                  tmp_path):
    """A serving pipeline must hand the artifact's pre-analyzed plan object
    to sample_compiled, not re-derive one."""
    cfg, params = small_dit
    label = jnp.zeros((2,), jnp.int32)
    calib = cache.DiffusionPipeline(cfg, solvers.ddim(6),
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    calib.calibrate(params, jax.random.PRNGKey(1), 2,
                    cond_args={"label": label})
    path = str(tmp_path / "p.cache.json")
    calib.save_artifact(path)
    serve = cache.DiffusionPipeline(cfg, solvers.ddim(6),
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    art = serve.load_artifact(path)
    captured = {}
    _spy_sample_compiled(monkeypatch, captured)
    serve.generate(params, jax.random.PRNGKey(2), 2, label=label)
    assert captured["plan"] is serve.plan
    assert captured["plan"] == art.execution_plan()


# ---------------------------------------------------------------------------
# Bugfix regressions: cfg_scale provenance + CFG calibration halves
# ---------------------------------------------------------------------------

def test_load_artifact_validates_cfg_scale(small_dit, tmp_path):
    cfg, params = small_dit
    label = jnp.zeros((2,), jnp.int32)
    calib = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    calib.calibrate(params, jax.random.PRNGKey(1), 2,
                    cond_args={"label": label})
    path = str(tmp_path / "cfg.cache.json")
    calib.save_artifact(path)

    # guidance-free pipeline must not silently adopt guided curves
    plain = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                    "smoothcache:alpha=0.5")
    with pytest.raises(ValueError, match="cfg_scale"):
        plain.load_artifact(path)
    # ... nor a pipeline at a different guidance strength
    other = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                    "smoothcache:alpha=0.5", cfg_scale=4.0)
    with pytest.raises(ValueError, match="cfg_scale"):
        other.load_artifact(path)
    # matching scale loads; strict=False overrides
    same = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                   "smoothcache:alpha=0.5", cfg_scale=1.5)
    same.load_artifact(path)
    plain.load_artifact(path, strict=False)

    # legacy artifacts without the key are tolerated
    art = cache.CacheArtifact.load(path)
    del art.meta["cfg_scale"]
    legacy = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                     "smoothcache:alpha=0.5")
    legacy.load_artifact(art)


def test_cfg_calibration_keeps_cond_half(small_dit):
    """Under CFG the executor doubles the batch to [cond; uncond]; the
    per-sample curves must cover exactly the conditioned calib_batch
    samples, not the doubled batch."""
    cfg, params = small_dit
    batch = 2
    label = jnp.zeros((batch,), jnp.int32)
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                   "smoothcache:alpha=0.5", cfg_scale=1.5)
    art = pipe.calibrate(params, jax.random.PRNGKey(1), batch,
                         cond_args={"label": label})
    for t, arr in pipe.per_sample.items():
        assert arr.shape[0] == batch, (t, arr.shape)
    assert art.meta["calib_cfg_half"] == "cond"
    # the mean curves are the mean of the recorded per-sample curves
    for t in art.curves:
        np.testing.assert_allclose(
            np.nan_to_num(art.curves[t]),
            np.nan_to_num(np.mean(pipe.per_sample[t], axis=0)), atol=1e-12)

    # no CFG → no halving, and the meta records that
    plain = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                    "smoothcache:alpha=0.5")
    art2 = plain.calibrate(params, jax.random.PRNGKey(1), batch,
                           cond_args={"label": label})
    for t, arr in plain.per_sample.items():
        assert arr.shape[0] == batch
    assert art2.meta["calib_cfg_half"] is None
