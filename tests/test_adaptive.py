"""Input-adaptive runtime caching (`AdaptivePolicy` / `sample_adaptive`):
mask-lattice candidate pools, proxy→error map fitting, τ=0 bitwise
reduction to the static segmented path, compile-count bounds, artifact
round-trips — plus regression tests for the PR's latent-bugfix sweep
(plan-property routing in generate(), flat registry grammar with nested
values, strict cfg_scale validation, CFG cond-half calibration)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cache, configs
from repro.core import calibration, diffusion, plan as plan_lib
from repro.core import schedule as S, solvers
from repro.core.executor import SmoothCacheExecutor


# ---------------------------------------------------------------------------
# Candidate pool (pure)
# ---------------------------------------------------------------------------

def _sched(skip_rows, types=("attn", "ffn")):
    skip = {t: np.asarray(v, bool) for t, v in zip(types, skip_rows)}
    return S.Schedule(skip, len(skip_rows[0]))


def test_mask_lattice_is_powerset_of_ever_skipped():
    sch = _sched([[0, 1, 1, 0, 1], [0, 0, 1, 0, 0]])
    pool = plan_lib.mask_lattice(sch)
    assert len(pool) == 4                       # 2^2
    # all-compute first; every signature shares one cache structure
    assert pool[0].live_in == ()
    assert {sig.structure for sig in pool} == {("attn", "ffn")}
    # every static mask of the schedule is in the pool
    idx = plan_lib.pool_index(pool)
    for s in range(sch.num_steps):
        skipset = frozenset(t for t, sk in sch.mask_key_at(s) if sk)
        assert skipset in idx
    # collect is the complement of the skip set within the lattice types
    for sig in pool:
        assert set(sig.collect) == {"attn", "ffn"} - set(sig.live_in)


def test_mask_lattice_excludes_never_skipped_types():
    sch = _sched([[0, 1, 0, 1], [0, 0, 0, 0]])    # ffn never skipped
    pool = plan_lib.mask_lattice(sch)
    assert len(pool) == 2
    for sig in pool:
        assert "ffn" not in sig.structure         # never resident
        assert "ffn" not in sig.collect


def test_mask_lattice_no_skips_is_single_program():
    pool = plan_lib.mask_lattice(_sched([[0, 0, 0], [0, 0, 0]]))
    assert len(pool) == 1 and pool[0].collect == ()


def test_mask_lattice_size_guard():
    types = tuple(f"t{i}" for i in range(plan_lib.MAX_LATTICE_TYPES + 1))
    rows = [[0, 1] for _ in types]
    with pytest.raises(ValueError, match="lattice"):
        plan_lib.mask_lattice(_sched(rows, types=types))


# ---------------------------------------------------------------------------
# Switch-branch table (pure)
# ---------------------------------------------------------------------------

def test_switch_branch_table_codes_map_to_skip_sets():
    sch = _sched([[0, 1, 1, 0, 1], [0, 0, 1, 0, 0]])
    table = plan_lib.switch_branch_table(plan_lib.mask_lattice(sch))
    assert table.types == ("attn", "ffn")
    assert len(table.branches) == 4
    # branches[code] skips exactly {types[i] : bit i of code}
    for code, sig in enumerate(table.branches):
        expect = {t for i, t in enumerate(table.types) if code >> i & 1}
        assert set(sig.live_in) == expect
        assert table.code_of(expect) == code
    # all branches share one cache structure — the lax.switch carry is
    # uniform by construction
    assert {sig.structure for sig in table.branches} == {("attn", "ffn")}
    with pytest.raises(KeyError, match="outside the pool"):
        table.code_of({"mlp"})


def test_switch_branch_table_rejects_partial_pool():
    sch = _sched([[0, 1, 1, 0, 1], [0, 0, 1, 0, 0]])
    pool = plan_lib.mask_lattice(sch)
    with pytest.raises(ValueError, match="full mask lattice"):
        plan_lib.switch_branch_table(pool[:-1])   # drop {attn, ffn}


def test_switch_branch_table_empty_pool_single_branch():
    table = plan_lib.switch_branch_table(
        plan_lib.mask_lattice(_sched([[0, 0], [0, 0]])))
    assert table.types == () and len(table.branches) == 1
    assert table.code_of(set()) == 0


# ---------------------------------------------------------------------------
# Proxy map (pure)
# ---------------------------------------------------------------------------

def test_fit_proxy_map_recovers_linear_relation():
    s_total, a, b = 20, 0.7, 0.02
    proxies = np.full(s_total, np.nan)
    proxies[1:] = np.linspace(0.1, 0.5, s_total - 1)
    err = np.full((s_total, 4), np.nan)
    err[:, 0] = 0.0
    err[1:, 1] = a * proxies[1:] + b
    pm = calibration.fit_proxy_map({"attn": err}, proxies)
    fa, fb = pm.coeffs["attn"]
    assert abs(fa - a) < 1e-8 and abs(fb - b) < 1e-8
    assert pm.est("attn", 0.3) == pytest.approx(a * 0.3 + b)
    # estimates are clamped at zero
    assert pm.est("attn", -100.0) == 0.0


def test_fit_proxy_map_degenerate_falls_back_to_mean():
    s_total = 8
    proxies = np.full(s_total, np.nan)
    proxies[1:] = 0.25                           # constant proxy
    err = np.full((s_total, 2), np.nan)
    err[:, 0] = 0.0
    err[1:, 1] = 0.1
    pm = calibration.fit_proxy_map({"ffn": err}, proxies)
    assert pm.coeffs["ffn"][0] == 0.0
    assert pm.est("ffn", 123.0) == pytest.approx(0.1)


def test_proxy_map_json_roundtrip():
    pm = calibration.ProxyMap({"attn": (0.5, 0.01), "ffn": (0.0, 0.2)},
                              mean_proxy=0.3)
    pm2 = calibration.ProxyMap.from_jsonable(pm.to_jsonable())
    assert pm2 == pm
    nan_pm = calibration.ProxyMap({"attn": (1.0, 0.0)})
    back = calibration.ProxyMap.from_jsonable(nan_pm.to_jsonable())
    assert np.isnan(back.mean_proxy)


def test_proxies_from_inputs_alignment():
    inputs = [np.zeros((1, 4)), np.ones((1, 4)), np.ones((1, 4))]
    p = calibration.proxies_from_inputs(inputs)
    assert np.isnan(p[0])                        # step 0 has no predecessor
    assert p[2] == 0.0                           # identical inputs
    assert p[1] > 0


def test_proxy_map_est_clamped_under_adversarial_fit():
    """Regression: a least-squares fit on decreasing error-vs-proxy data
    yields a negative slope AND a negative intercept is possible — the
    per-type estimate must clamp at zero or the accumulator would
    *decrease* while skipping and postpone recompute indefinitely."""
    s_total = 20
    proxies = np.full(s_total, np.nan)
    proxies[1:] = np.linspace(0.1, 0.9, s_total - 1)
    err = np.full((s_total, 2), np.nan)
    err[:, 0] = 0.0
    err[1:, 1] = 0.2 - 0.3 * proxies[1:]         # decreasing error signal
    pm = calibration.fit_proxy_map({"attn": err}, proxies)
    a, b = pm.coeffs["attn"]
    assert a < 0                                 # adversarial slope
    assert pm.est("attn", 0.9) == 0.0            # raw a·p+b < 0 → clamped
    for p in np.linspace(0.0, 5.0, 50):
        assert pm.est("attn", p) >= 0.0
    # the stacked device representation evaluates the same clamped rule
    ca, cb = pm.stacked(("attn",))
    est_dev = jnp.maximum(ca * jnp.float32(0.9) + cb, 0.0)
    assert float(est_dev[0]) == 0.0


def test_runtime_rule_accumulator_never_decreases_while_skipping():
    """The device rule shares the clamp: with adversarial (negative)
    coefficients the estimated delta is 0 — the accumulator stays flat
    while skipping (never decreases) and k_max still forces recompute."""
    a = jnp.asarray([-2.0], jnp.float32)         # est would be negative
    b = jnp.asarray([-0.1], jnp.float32)
    acc = jnp.asarray([0.05], jnp.float32)
    lag = jnp.asarray([0], jnp.int32)
    k_max, tau = 2, 0.5
    for step in range(1, 6):
        skip, acc2, lag2 = calibration.runtime_rule(
            jnp.float32(0.3), acc, lag, a, b, tau, k_max)
        if bool(skip[0]):
            assert float(acc2[0]) >= float(acc[0])   # clamp: never down
        acc, lag = acc2, lag2
        assert int(lag[0]) <= k_max                  # age cap still bites
    # with the cap at 2, a 5-step window must have recomputed at least once
    assert int(lag[0]) < 5


def test_proxy_map_stacked_device_representation():
    pm = calibration.ProxyMap({"attn": (0.5, 0.01), "ffn": (-0.2, 0.3)})
    a, b = pm.stacked(("attn", "ffn"))
    assert a.dtype == np.float32 and b.dtype == np.float32
    np.testing.assert_allclose(a, [0.5, -0.2], rtol=1e-6)
    np.testing.assert_allclose(b, [0.01, 0.3], rtol=1e-6)
    with pytest.raises(KeyError, match="mlp"):
        pm.stacked(("attn", "mlp"))


# ---------------------------------------------------------------------------
# Policy / registry specs
# ---------------------------------------------------------------------------

def test_adaptive_spec_roundtrip():
    p = cache.get("adaptive:base=smoothcache(alpha=0.18,k_max=3),tau=0.05")
    assert isinstance(p, cache.AdaptivePolicy)
    assert isinstance(p.base, cache.SmoothCache)
    assert p.tau == 0.05 and p.k_max == 3
    assert cache.get(p.spec()) == p
    assert cache.from_config(p.to_config()) == p
    # teacache alias, calibration-free base
    q = cache.get("teacache:base=static(n=2),tau=0.1")
    assert isinstance(q.base, cache.StaticInterval)
    assert q.requires_calibration                 # proxy map needs a pass
    assert cache.get(q.spec()) == q


def test_adaptive_policy_validation():
    with pytest.raises(ValueError, match="nest"):
        cache.AdaptivePolicy(base=cache.AdaptivePolicy())
    with pytest.raises(ValueError, match="tau"):
        cache.AdaptivePolicy(tau=-0.1)


def test_adaptive_k_max_validated_everywhere():
    """k_max=0 compiles the whole candidate pool yet silently never
    reuses a cache entry (≡ no_cache at pool-size compile cost), and
    negative values are nonsense — every entry point must reject them
    with a clear message."""
    # policy constructor
    with pytest.raises(ValueError, match="k_max must be >= 1"):
        cache.AdaptivePolicy(base="static:n=2", k_max=0)
    with pytest.raises(ValueError, match="k_max must be >= 1"):
        cache.AdaptivePolicy(base="static:n=2", k_max=-3)
    # registry spec parse path (flat grammar)
    with pytest.raises(ValueError, match="k_max must be >= 1"):
        cache.get("adaptive:base=static(n=2),k_max=0")
    # a base whose own k_max is 0 (none never caches) is equally useless
    with pytest.raises(ValueError, match="k_max must be >= 1"):
        cache.get("adaptive:base=none")
    # an explicit valid override round-trips through spec and config
    p = cache.get("adaptive:base=static(n=2),tau=0.1,k_max=5")
    assert p.k_max == 5
    assert cache.get(p.spec()) == p
    assert cache.from_config(p.to_config()) == p


def test_executor_adaptive_k_max_validated(small_dit):
    cfg, params = small_dit
    sch = S.fora(cfg.layer_types(), 6, 2)
    for start in ("start_adaptive_run", "start_adaptive_fused_run"):
        ex = SmoothCacheExecutor(cfg, solvers.ddim(6), cfg_scale=1.5)
        with pytest.raises(ValueError, match="k_max must be >= 1"):
            getattr(ex, start)(params, jax.random.PRNGKey(0), 1,
                               schedule=sch, tau=0.0, k_max=0,
                               label=jnp.zeros((1,), jnp.int32))


def test_adaptive_build_is_base_schedule():
    curves_err = np.full((10, 4), np.nan)
    curves_err[:, 0] = 0.0
    curves_err[1:, 1:] = 0.01
    curves = {"attn": curves_err, "ffn": curves_err.copy()}
    p = cache.AdaptivePolicy(base=cache.SmoothCache(0.1), tau=0.3)
    sch = p.build(["attn", "ffn"], 10, curves)
    base = cache.SmoothCache(0.1).build(["attn", "ffn"], 10, curves)
    assert sch.content_key() == base.content_key()


# -- flat-grammar bugfix: nested values in the CLI-friendly form -----------

def test_registry_flat_spec_with_nested_value():
    p = cache.get("per_type:attn=smoothcache(alpha=0.1)")
    assert isinstance(p, cache.PerLayerType)
    assert isinstance(p.policies["attn"], cache.SmoothCache)
    assert p.policies["attn"].alpha == 0.1
    # equivalent to the parenthesized form
    assert p == cache.get("per_type(attn=smoothcache(alpha=0.1))")
    # multiple args, nested + scalar mixed
    q = cache.get("per_type:attn=smoothcache(alpha=0.2,k_max=2),"
                  "default=static(n=3)")
    assert q.policies["attn"].k_max == 2
    assert isinstance(q.default, cache.StaticInterval)
    # genuinely malformed specs still fail
    with pytest.raises(ValueError):
        cache.get("per_type(attn=static(n=2)")


# ---------------------------------------------------------------------------
# Executor: the adaptive path (smoke DiT)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dit():
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7), a.shape),
        params)
    return cfg, params


def _calibrated_adaptive(cfg, params, tau, steps=8, alpha=0.5):
    label = jnp.zeros((2,), jnp.int32)
    pipe = cache.DiffusionPipeline(
        cfg, solvers.ddim(steps),
        f"adaptive:base=smoothcache(alpha={alpha}),tau={tau}", cfg_scale=1.5)
    pipe.calibrate(params, jax.random.PRNGKey(1), 2,
                   cond_args={"label": label})
    return pipe, label


def test_adaptive_tau0_bitwise_equals_sample_compiled(small_dit):
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0)
    assert any(v.any() for v in pipe.schedule.skip.values())
    x_ad = pipe.generate(params, jax.random.PRNGKey(2), 2, label=label)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(8), cfg_scale=1.5)
    x_st = ex.sample_compiled(params, jax.random.PRNGKey(2), 2,
                              schedule=pipe.schedule, label=label)
    np.testing.assert_array_equal(np.asarray(x_ad), np.asarray(x_st))


def test_adaptive_compile_count_bounded_by_pool(small_dit):
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0.3)
    pool = plan_lib.mask_lattice(pipe.schedule)
    # heterogeneous inputs: different seeds and labels force different
    # per-step decisions, but never new programs
    for seed in (2, 3, 4):
        lab = jnp.full((2,), seed % cfg.num_classes, jnp.int32)
        x, dec = pipe.generate(params, jax.random.PRNGKey(seed), 2,
                               label=lab, return_decisions=True)
        assert len(dec) == 8 and dec[0] == ()     # step 0 computes all
        assert bool(jnp.all(jnp.isfinite(x)))
    # generate() routes through the fused path (ddim is scannable): the
    # whole pool rides inside ONE lax.switch program — no per-signature
    # "sigstep" dispatch programs at all
    assert pipe.executor.compiled_variant_count("fused") == 1
    assert pipe.executor.compiled_variant_count("sigstep") == 0
    # the host-dispatched loop stays bounded by the pool
    x_host, dec_host = pipe.executor.sample_adaptive(
        params, jax.random.PRNGKey(2), 2, schedule=pipe.schedule, tau=0.3,
        proxy_map=pipe.proxy_map,
        label=jnp.full((2,), 2 % cfg.num_classes, jnp.int32),
        return_decisions=True)
    assert 0 < pipe.executor.compiled_variant_count("sigstep") <= len(pool)


def test_adaptive_decisions_respect_k_max(small_dit):
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=100.0)
    _, dec = pipe.generate(params, jax.random.PRNGKey(5), 2, label=label,
                           return_decisions=True)
    # an absurdly large tau reuses as hard as allowed: cache age caps at
    # the policy's k_max, so every k_max+1-length window recomputes
    k_max = pipe.policy.k_max
    age = {t: 0 for t in cfg.layer_types()}
    for step in dec:
        for t in cfg.layer_types():
            if t in step:
                age[t] += 1
                assert age[t] <= k_max
            else:
                age[t] = 0


def test_adaptive_tau_without_proxy_map_raises(small_dit):
    cfg, params = small_dit
    ex = SmoothCacheExecutor(cfg, solvers.ddim(6), cfg_scale=1.5)
    sch = S.fora(cfg.layer_types(), 6, 2)
    with pytest.raises(ValueError, match="proxy_map"):
        ex.sample_adaptive(params, jax.random.PRNGKey(0), 1, schedule=sch,
                           tau=0.1, label=jnp.zeros((1,), jnp.int32))
    # a map missing pool-type coefficients is the same misconfiguration
    # class: ValueError (not a KeyError escaping from stacked())
    partial = calibration.ProxyMap({"attn": (0.1, 0.0)})
    for start in ("start_adaptive_run", "start_adaptive_fused_run"):
        with pytest.raises(ValueError, match="lacks coefficients"):
            getattr(ex, start)(params, jax.random.PRNGKey(0), 1,
                               schedule=sch, tau=0.1, proxy_map=partial,
                               label=jnp.zeros((1,), jnp.int32))


def test_adaptive_artifact_roundtrip(small_dit, tmp_path):
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0.3)
    assert pipe.artifact.adaptive is not None
    assert pipe.artifact.adaptive["tau"] == 0.3
    path = str(tmp_path / "adaptive.cache.json")
    pipe.save_artifact(path)

    serve = cache.DiffusionPipeline(
        cfg, solvers.ddim(8), "adaptive:base=smoothcache(alpha=0.5),tau=0.3",
        cfg_scale=1.5)
    art = serve.load_artifact(path)
    # adaptive config + fitted mapping survive; serving never recalibrates
    assert art.adaptive == pipe.artifact.adaptive
    assert serve.proxy_map == pipe.proxy_map
    assert cache.from_config(art.policy) == pipe.policy
    x1, d1 = pipe.generate(params, jax.random.PRNGKey(9), 2, label=label,
                           return_decisions=True)
    x2, d2 = serve.generate(params, jax.random.PRNGKey(9), 2, label=label,
                            return_decisions=True)
    assert d1 == d2
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


def test_adaptive_artifact_tau_mismatch_rejected(small_dit, tmp_path):
    """The runtime rule must use the artifact's decision parameters — a
    serving pipeline constructed with a different tau/k_max must not
    silently generate under the artifact's provenance."""
    cfg, params = small_dit
    pipe, _ = _calibrated_adaptive(cfg, params, tau=0.3)
    path = str(tmp_path / "tau.cache.json")
    pipe.save_artifact(path)
    other = cache.DiffusionPipeline(
        cfg, solvers.ddim(8), "adaptive:base=smoothcache(alpha=0.5),tau=0.05",
        cfg_scale=1.5)
    with pytest.raises(ValueError, match="tau"):
        other.load_artifact(path)
    other.load_artifact(path, strict=False)       # explicit override works
    # a matching policy loads fine
    same = cache.DiffusionPipeline(
        cfg, solvers.ddim(8), "adaptive:base=smoothcache(alpha=0.5),tau=0.3",
        cfg_scale=1.5)
    same.load_artifact(path)


def test_adaptive_explicit_schedule_override_is_static(small_dit):
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0.3)
    sch = S.fora(cfg.layer_types(), 8, 2)
    x = pipe.generate(params, jax.random.PRNGKey(2), 2, label=label,
                      schedule=sch)
    ex = SmoothCacheExecutor(cfg, solvers.ddim(8), cfg_scale=1.5)
    x_st = ex.sample_compiled(params, jax.random.PRNGKey(2), 2, schedule=sch,
                              label=label)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(x_st))
    with pytest.raises(ValueError, match="return_decisions"):
        pipe.generate(params, jax.random.PRNGKey(2), 2, label=label,
                      schedule=sch, return_decisions=True)


# ---------------------------------------------------------------------------
# Fused adaptive sampling (decision + dispatch on device)
# ---------------------------------------------------------------------------

def test_fused_matches_host_loop_on_heterogeneous_inputs(small_dit):
    """Fused and host-dispatched adaptive runs share one decision rule
    (`calibration.runtime_rule`, float32, on device): identical per-step
    decision sequences and allclose latents across heterogeneous
    seeds/labels at tau > 0."""
    cfg, params = small_dit
    pipe, _ = _calibrated_adaptive(cfg, params, tau=0.3)
    ex = pipe.executor
    for seed in (2, 5, 11):
        lab = jnp.full((2,), seed % cfg.num_classes, jnp.int32)
        key = jax.random.PRNGKey(seed)
        x_host, d_host = ex.sample_adaptive(
            params, key, 2, schedule=pipe.schedule, tau=0.3,
            proxy_map=pipe.proxy_map, label=lab, return_decisions=True)
        x_fused, d_fused = ex.sample_adaptive_fused(
            params, key, 2, schedule=pipe.schedule, tau=0.3,
            proxy_map=pipe.proxy_map, label=lab, return_decisions=True)
        assert d_fused == d_host
        assert any(d for d in d_fused)            # the rule actually skips
        np.testing.assert_allclose(np.asarray(x_fused), np.asarray(x_host),
                                   rtol=1e-5, atol=1e-6)


def test_fused_tau0_bitwise_equals_sample_compiled(small_dit):
    """Acceptance: at tau=0 the fused program replays the static schedule
    bit-identically to the segmented sample_compiled path."""
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0)
    assert any(v.any() for v in pipe.schedule.skip.values())
    ex = SmoothCacheExecutor(cfg, solvers.ddim(8), cfg_scale=1.5)
    x_fused, dec = ex.sample_adaptive_fused(
        params, jax.random.PRNGKey(2), 2, schedule=pipe.schedule, tau=0.0,
        label=label, return_decisions=True)
    ex2 = SmoothCacheExecutor(cfg, solvers.ddim(8), cfg_scale=1.5)
    x_static = ex2.sample_compiled(params, jax.random.PRNGKey(2), 2,
                                   schedule=pipe.schedule, label=label)
    np.testing.assert_array_equal(np.asarray(x_fused), np.asarray(x_static))
    # and the decision trace is the schedule verbatim
    expect = tuple(tuple(sorted(t for t, sk in pipe.schedule.mask_key_at(s)
                                if sk)) for s in range(8))
    assert dec == expect


def test_fused_zero_per_step_host_syncs(small_dit, monkeypatch):
    """Acceptance: the fused loop performs no device→host sync per step —
    no device_get/float() between start and done (the decision trace is
    read back once, after the run)."""
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0.3)
    ex = pipe.executor
    # warm the program so compilation noise is out of the picture
    ex.sample_adaptive_fused(params, jax.random.PRNGKey(3), 2,
                             schedule=pipe.schedule, tau=0.3,
                             proxy_map=pipe.proxy_map, label=label)
    ex.host_sync_count = 0
    d2h = {"n": 0}
    real_device_get = jax.device_get

    def counting_device_get(x):
        d2h["n"] += 1
        return real_device_get(x)

    monkeypatch.setattr(jax, "device_get", counting_device_get)
    # transfer_guard is a no-op on CPU (zero-copy) but trips on real
    # accelerators — belt and braces with the device_get counter
    with jax.transfer_guard_device_to_host("disallow"):
        rs = ex.start_adaptive_fused_run(
            params, jax.random.PRNGKey(4), 2, schedule=pipe.schedule,
            tau=0.3, proxy_map=pipe.proxy_map, label=label)
        while not rs.done:
            rs = ex.advance_adaptive_fused(params, rs, n_steps=3)
    assert d2h["n"] == 0                      # zero per-step syncs
    assert ex.host_sync_count == 0
    # the decision readback is ONE transfer, outside the loop
    dec = rs.decisions
    assert len(dec) == 8 and d2h["n"] == 1
    # the host loop, by contrast, syncs the decision bits every step
    monkeypatch.undo()
    ex.host_sync_count = 0
    ex.sample_adaptive(params, jax.random.PRNGKey(4), 2,
                       schedule=pipe.schedule, tau=0.3,
                       proxy_map=pipe.proxy_map, label=label)
    assert ex.host_sync_count == 8 - 1        # every step but the first


def test_fused_chunked_advance_bitwise_matches_one_shot(small_dit):
    """advance_adaptive_fused(n_steps) timeslices through the SAME
    program (dynamic start/length): any chunking produces bit-identical
    latents, identical decisions, and compiles exactly one program."""
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0.3)
    ex = pipe.executor
    key = jax.random.PRNGKey(6)
    x_one, d_one = ex.sample_adaptive_fused(
        params, key, 2, schedule=pipe.schedule, tau=0.3,
        proxy_map=pipe.proxy_map, label=label, return_decisions=True)
    n_fused = ex.compiled_variant_count("fused")
    for chunk in (1, 3, 5):
        rs = ex.start_adaptive_fused_run(
            params, key, 2, schedule=pipe.schedule, tau=0.3,
            proxy_map=pipe.proxy_map, label=label)
        while not rs.done:
            rs = ex.advance_adaptive_fused(params, rs, n_steps=chunk)
        np.testing.assert_array_equal(np.asarray(rs.x), np.asarray(x_one))
        assert rs.decisions == d_one
    # chunk size is a dynamic trip count, never a new program
    assert ex.compiled_variant_count("fused") == n_fused == 1


def test_fused_requires_scannable_solver(small_dit):
    cfg, params = small_dit
    ex = SmoothCacheExecutor(cfg, solvers.dpmpp_3m_sde(6), cfg_scale=1.5)
    assert not ex.supports_fused_adaptive
    sch = S.fora(cfg.layer_types(), 6, 2)
    with pytest.raises(ValueError, match="not scannable"):
        ex.start_adaptive_fused_run(params, jax.random.PRNGKey(0), 1,
                                    schedule=sch, tau=0.0,
                                    label=jnp.zeros((1,), jnp.int32))


def test_generate_falls_back_to_host_loop_when_not_scannable(small_dit,
                                                             monkeypatch):
    """Pipelines route adaptive generate() through the fused path only
    when the executor supports it; otherwise the host-dispatched loop
    serves (same decisions, per-step dispatch)."""
    cfg, params = small_dit
    pipe, label = _calibrated_adaptive(cfg, params, tau=0.3)
    monkeypatch.setattr(SmoothCacheExecutor, "supports_fused_adaptive",
                        property(lambda self: False))
    called = {}
    orig = SmoothCacheExecutor.sample_adaptive

    def spy(self, *a, **kw):
        called["host"] = True
        return orig(self, *a, **kw)

    monkeypatch.setattr(SmoothCacheExecutor, "sample_adaptive", spy)
    x = pipe.generate(params, jax.random.PRNGKey(2), 2, label=label)
    assert called.get("host")
    assert bool(jnp.all(jnp.isfinite(x)))


# ---------------------------------------------------------------------------
# Bugfix regressions: pipeline plan routing
# ---------------------------------------------------------------------------

def _spy_sample_compiled(monkeypatch, captured):
    orig = SmoothCacheExecutor.sample_compiled

    def spy(self, *args, **kwargs):
        captured["plan"] = kwargs.get("plan")
        return orig(self, *args, **kwargs)

    monkeypatch.setattr(SmoothCacheExecutor, "sample_compiled", spy)


def test_generate_after_prepare_hands_plan_to_executor(small_dit,
                                                       monkeypatch):
    """prepare() resets _plan to None; generate() must route through the
    lazy .plan property instead of silently passing plan=None."""
    cfg, params = small_dit
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(5), "static:n=2",
                                   cfg_scale=1.5)
    pipe.prepare()
    captured = {}
    _spy_sample_compiled(monkeypatch, captured)
    pipe.generate(params, jax.random.PRNGKey(0), 1,
                  label=jnp.zeros((1,), jnp.int32))
    assert captured["plan"] is not None
    assert captured["plan"] is pipe.plan


def test_generate_hands_artifact_plan_to_executor(small_dit, monkeypatch,
                                                  tmp_path):
    """A serving pipeline must hand the artifact's pre-analyzed plan object
    to sample_compiled, not re-derive one."""
    cfg, params = small_dit
    label = jnp.zeros((2,), jnp.int32)
    calib = cache.DiffusionPipeline(cfg, solvers.ddim(6),
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    calib.calibrate(params, jax.random.PRNGKey(1), 2,
                    cond_args={"label": label})
    path = str(tmp_path / "p.cache.json")
    calib.save_artifact(path)
    serve = cache.DiffusionPipeline(cfg, solvers.ddim(6),
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    art = serve.load_artifact(path)
    captured = {}
    _spy_sample_compiled(monkeypatch, captured)
    serve.generate(params, jax.random.PRNGKey(2), 2, label=label)
    assert captured["plan"] is serve.plan
    assert captured["plan"] == art.execution_plan()


# ---------------------------------------------------------------------------
# Bugfix regressions: cfg_scale provenance + CFG calibration halves
# ---------------------------------------------------------------------------

def test_load_artifact_validates_cfg_scale(small_dit, tmp_path):
    cfg, params = small_dit
    label = jnp.zeros((2,), jnp.int32)
    calib = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                    "smoothcache:alpha=0.5", cfg_scale=1.5)
    calib.calibrate(params, jax.random.PRNGKey(1), 2,
                    cond_args={"label": label})
    path = str(tmp_path / "cfg.cache.json")
    calib.save_artifact(path)

    # guidance-free pipeline must not silently adopt guided curves
    plain = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                    "smoothcache:alpha=0.5")
    with pytest.raises(ValueError, match="cfg_scale"):
        plain.load_artifact(path)
    # ... nor a pipeline at a different guidance strength
    other = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                    "smoothcache:alpha=0.5", cfg_scale=4.0)
    with pytest.raises(ValueError, match="cfg_scale"):
        other.load_artifact(path)
    # matching scale loads; strict=False overrides
    same = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                   "smoothcache:alpha=0.5", cfg_scale=1.5)
    same.load_artifact(path)
    plain.load_artifact(path, strict=False)

    # legacy artifacts without the key are tolerated
    art = cache.CacheArtifact.load(path)
    del art.meta["cfg_scale"]
    legacy = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                     "smoothcache:alpha=0.5")
    legacy.load_artifact(art)


def test_cfg_calibration_keeps_cond_half(small_dit):
    """Under CFG the executor doubles the batch to [cond; uncond]; the
    per-sample curves must cover exactly the conditioned calib_batch
    samples, not the doubled batch."""
    cfg, params = small_dit
    batch = 2
    label = jnp.zeros((batch,), jnp.int32)
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                   "smoothcache:alpha=0.5", cfg_scale=1.5)
    art = pipe.calibrate(params, jax.random.PRNGKey(1), batch,
                         cond_args={"label": label})
    for t, arr in pipe.per_sample.items():
        assert arr.shape[0] == batch, (t, arr.shape)
    assert art.meta["calib_cfg_half"] == "cond"
    # the mean curves are the mean of the recorded per-sample curves
    for t in art.curves:
        np.testing.assert_allclose(
            np.nan_to_num(art.curves[t]),
            np.nan_to_num(np.mean(pipe.per_sample[t], axis=0)), atol=1e-12)

    # no CFG → no halving, and the meta records that
    plain = cache.DiffusionPipeline(cfg, solvers.ddim(5),
                                    "smoothcache:alpha=0.5")
    art2 = plain.calibrate(params, jax.random.PRNGKey(1), batch,
                           cond_args={"label": label})
    for t, arr in plain.per_sample.items():
        assert arr.shape[0] == batch
    assert art2.meta["calib_cfg_half"] is None
