"""Static SmoothCache vs input-adaptive runtime caching.

Calibrates one adaptive policy (SmoothCache base at a ~50% compute budget,
TeaCache-style accumulated-error threshold τ) on the smoke DiT, then runs
**heterogeneous inputs** (different seeds and class labels) through three
paths:

* ``reference`` — uncached sampling (quality anchor),
* ``static``    — ``sample_compiled`` under the offline schedule (the same
                  compute for every input),
* ``adaptive``  — ``sample_adaptive`` (per-input decisions dispatched over
                  the precompiled mask-lattice pool).

Per input it reports realized compute fraction, steady-state wall time,
and L1 distance to the uncached reference; the adaptive path's program
count is asserted against the pool size (compile count must be bounded by
the pool, never per step).  Writes ``BENCH_adaptive.json`` (results dir +
repo-root trajectory mirror).

    PYTHONPATH=src python -m benchmarks.run --only adaptive
    ADAPTIVE_BENCH_STEPS=20 PYTHONPATH=src python -m benchmarks.adaptive_bench
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import cache, configs
from repro.core import diffusion, plan as plan_lib, solvers
from repro.core.executor import SmoothCacheExecutor

STEPS = int(os.environ.get("ADAPTIVE_BENCH_STEPS", "30"))
TAU = float(os.environ.get("ADAPTIVE_BENCH_TAU", "0.5"))
BATCH = 1
CFG_SCALE = 1.5
CALIB_BATCH = 2
#: (seed, label) pairs — heterogeneous inputs for the per-input decisions
INPUTS = [(11, 0), (23, 3), (47, 7), (61, 1)]


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


def _rel_l1(a, b) -> float:
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.sum(np.abs(a - b)) / (np.sum(np.abs(b)) + 1e-12))


def run() -> None:
    cfg = configs.get("dit-xl-256", "smoke")
    solver = solvers.ddim(STEPS)
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7), a.shape),
        params)

    pipe = cache.DiffusionPipeline(
        cfg, solver, f"adaptive:base=budget(target=0.5),tau={TAU}",
        cfg_scale=CFG_SCALE)
    calib_label = jnp.zeros((CALIB_BATCH,), jnp.int32)
    t0 = time.perf_counter()
    pipe.calibrate(params, jax.random.PRNGKey(1), CALIB_BATCH,
                   cond_args={"label": calib_label})
    calib_s = time.perf_counter() - t0
    sch = pipe.schedule
    pool = plan_lib.mask_lattice(sch)
    static_fraction = float(np.mean([sch.compute_fraction(t)
                                     for t in sch.skip]))
    types = cfg.layer_types()

    ex_static = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
    ex_ref = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)

    inputs = []
    for seed, lab in INPUTS:
        label = jnp.full((BATCH,), lab % cfg.num_classes, jnp.int32)
        key = jax.random.PRNGKey(seed)
        x_ref, _ = _timed(lambda: ex_ref.sample(params, key, BATCH,
                                                label=label))

        # static: warm once for compile, then time steady state
        run_static = lambda: ex_static.sample_compiled(
            params, key, BATCH, schedule=sch, label=label)
        _, t_static_first = _timed(run_static)
        x_static, t_static = _timed(run_static)

        run_adaptive = lambda: pipe.generate(params, key, BATCH, label=label,
                                             return_decisions=True)
        _, t_adapt_first = _timed(run_adaptive)
        (x_adapt, decisions), t_adapt = _timed(run_adaptive)
        skipped = sum(len(d) for d in decisions)
        adapt_fraction = 1.0 - skipped / (STEPS * len(types))

        inputs.append({
            "seed": seed, "label": int(lab % cfg.num_classes),
            "static": {"compute_fraction": static_fraction,
                       "sample_s": t_static,
                       "l1_vs_reference": _rel_l1(x_static, x_ref)},
            "adaptive": {"compute_fraction": adapt_fraction,
                         "sample_s": t_adapt,
                         "l1_vs_reference": _rel_l1(x_adapt, x_ref),
                         "skips_per_step": [list(d) for d in decisions]},
        })

    programs = pipe.executor.compiled_variant_count("sigstep")
    assert programs <= len(pool), (programs, len(pool))

    result = {
        "config": cfg.name, "solver": solver.name, "steps": STEPS,
        "batch": BATCH, "cfg_scale": CFG_SCALE, "tau": TAU,
        "policy": pipe.policy.spec(),
        "calibrate_s": calib_s,
        "pool": {"size": len(pool),
                 "masks": [list(sig.live_in) for sig in pool],
                 "programs_compiled": programs},
        "static_schedule": {"name": sch.name, "alpha": sch.alpha,
                            "compute_fraction": static_fraction},
        "inputs": inputs,
        "mean": {
            "static_compute_fraction": static_fraction,
            "adaptive_compute_fraction": float(np.mean(
                [i["adaptive"]["compute_fraction"] for i in inputs])),
            "static_sample_s": float(np.mean(
                [i["static"]["sample_s"] for i in inputs])),
            "adaptive_sample_s": float(np.mean(
                [i["adaptive"]["sample_s"] for i in inputs])),
            "static_l1": float(np.mean(
                [i["static"]["l1_vs_reference"] for i in inputs])),
            "adaptive_l1": float(np.mean(
                [i["adaptive"]["l1_vs_reference"] for i in inputs])),
        },
    }
    common.write_bench_json("BENCH_adaptive.json", result)

    m = result["mean"]
    for name in ("static", "adaptive"):
        common.emit(
            f"adaptive/{name}_sample", m[f"{name}_sample_s"] * 1e6,
            f"compute_frac={m[f'{name}_compute_fraction']:.3f}"
            f";l1_vs_ref={m[f'{name}_l1']:.4f}")
    common.emit("adaptive/pool", len(pool),
                f"programs={programs};inputs={len(inputs)};tau={TAU}")


if __name__ == "__main__":
    run()
