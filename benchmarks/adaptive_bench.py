"""Static SmoothCache vs input-adaptive runtime caching, fused vs host.

Calibrates one adaptive policy (SmoothCache base at a ~50% compute budget,
TeaCache-style accumulated-error threshold τ) on the smoke DiT, then runs
**heterogeneous inputs** (different seeds and class labels) through four
paths:

* ``reference``      — uncached sampling (quality anchor),
* ``static``         — ``sample_compiled`` under the offline schedule (the
                       same compute for every input),
* ``adaptive_fused`` — ``sample_adaptive_fused``: the whole decision +
                       ``lax.switch`` dispatch loop in ONE donated device
                       program (zero per-step host syncs, one program per
                       pool),
* ``adaptive_host``  — ``sample_adaptive``: per-step host dispatch over
                       the precompiled pool (one decision sync + one
                       program dispatch per step).

Per input it reports realized compute fraction, steady-state wall time,
and L1 distance to the uncached reference; the fused-vs-host columns add
per-step dispatch overhead (host wall − fused wall, per step) and the
device→host decision-sync counts.  Program counts are asserted: fused
compiles exactly one program, host dispatch stays bounded by the pool —
never one per step.  Writes ``BENCH_adaptive.json`` (results dir +
repo-root trajectory mirror).

    PYTHONPATH=src python -m benchmarks.run --only adaptive
    ADAPTIVE_BENCH_STEPS=20 PYTHONPATH=src python -m benchmarks.adaptive_bench
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import cache, configs
from repro.core import diffusion, plan as plan_lib, solvers
from repro.core.executor import SmoothCacheExecutor

STEPS = int(os.environ.get("ADAPTIVE_BENCH_STEPS", "30"))
TAU = float(os.environ.get("ADAPTIVE_BENCH_TAU", "0.5"))
BATCH = 1
CFG_SCALE = 1.5
CALIB_BATCH = 2
#: (seed, label) pairs — heterogeneous inputs for the per-input decisions
INPUTS = [(11, 0), (23, 3), (47, 7), (61, 1)]


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


def _rel_l1(a, b) -> float:
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.sum(np.abs(a - b)) / (np.sum(np.abs(b)) + 1e-12))


def run() -> None:
    cfg = configs.get("dit-xl-256", "smoke")
    solver = solvers.ddim(STEPS)
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7), a.shape),
        params)

    pipe = cache.DiffusionPipeline(
        cfg, solver, f"adaptive:base=budget(target=0.5),tau={TAU}",
        cfg_scale=CFG_SCALE)
    calib_label = jnp.zeros((CALIB_BATCH,), jnp.int32)
    t0 = time.perf_counter()
    pipe.calibrate(params, jax.random.PRNGKey(1), CALIB_BATCH,
                   cond_args={"label": calib_label})
    calib_s = time.perf_counter() - t0
    sch = pipe.schedule
    pool = plan_lib.mask_lattice(sch)
    static_fraction = float(np.mean([sch.compute_fraction(t)
                                     for t in sch.skip]))
    types = cfg.layer_types()

    ex_static = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
    ex_ref = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
    ex_host = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
    proxy_map, k_max = pipe.proxy_map, pipe.policy.k_max

    inputs = []
    for seed, lab in INPUTS:
        label = jnp.full((BATCH,), lab % cfg.num_classes, jnp.int32)
        key = jax.random.PRNGKey(seed)
        x_ref, _ = _timed(lambda: ex_ref.sample(params, key, BATCH,
                                                label=label))

        # static: warm once for compile, then time steady state
        run_static = lambda: ex_static.sample_compiled(
            params, key, BATCH, schedule=sch, label=label)
        _, t_static_first = _timed(run_static)
        x_static, t_static = _timed(run_static)

        # fused: pipe.generate routes to sample_adaptive_fused (ddim is
        # scannable) — one donated program, decisions on device
        run_fused = lambda: pipe.generate(params, key, BATCH, label=label,
                                          return_decisions=True)
        _, t_fused_first = _timed(run_fused)
        (x_fused, decisions), t_fused = _timed(run_fused)
        skipped = sum(len(d) for d in decisions)
        adapt_fraction = 1.0 - skipped / (STEPS * len(types))

        # host loop: per-step decision sync + program dispatch
        run_host = lambda: ex_host.sample_adaptive(
            params, key, BATCH, schedule=sch, tau=TAU, proxy_map=proxy_map,
            k_max=k_max, label=label, return_decisions=True)
        _, _ = _timed(run_host)
        syncs_before = ex_host.host_sync_count
        (x_host, dec_host), t_host = _timed(run_host)
        host_syncs = ex_host.host_sync_count - syncs_before
        assert dec_host == decisions, (
            "fused and host decision sequences diverged")

        inputs.append({
            "seed": seed, "label": int(lab % cfg.num_classes),
            "static": {"compute_fraction": static_fraction,
                       "sample_s": t_static,
                       "l1_vs_reference": _rel_l1(x_static, x_ref)},
            "adaptive_fused": {
                "compute_fraction": adapt_fraction,
                "sample_s": t_fused,
                "l1_vs_reference": _rel_l1(x_fused, x_ref),
                "device_syncs": 0,       # decisions stay on device
                "skips_per_step": [list(d) for d in decisions]},
            "adaptive_host": {
                "compute_fraction": adapt_fraction,
                "sample_s": t_host,
                "l1_vs_reference": _rel_l1(x_host, x_ref),
                "device_syncs": host_syncs},
        })

    fused_programs = pipe.executor.compiled_variant_count("fused")
    host_programs = ex_host.compiled_variant_count("sigstep")
    assert fused_programs == 1, fused_programs
    assert pipe.executor.host_sync_count == 0
    assert 0 < host_programs <= len(pool), (host_programs, len(pool))

    mean = lambda path, key_: float(np.mean([i[path][key_]
                                             for i in inputs]))
    t_fused_mean = mean("adaptive_fused", "sample_s")
    t_host_mean = mean("adaptive_host", "sample_s")
    result = {
        "config": cfg.name, "solver": solver.name, "steps": STEPS,
        "batch": BATCH, "cfg_scale": CFG_SCALE, "tau": TAU,
        "policy": pipe.policy.spec(),
        "calibrate_s": calib_s,
        "pool": {"size": len(pool),
                 "masks": [list(sig.live_in) for sig in pool],
                 "fused_programs_compiled": fused_programs,
                 "host_programs_compiled": host_programs},
        "static_schedule": {"name": sch.name, "alpha": sch.alpha,
                            "compute_fraction": static_fraction},
        "inputs": inputs,
        "mean": {
            "static_compute_fraction": static_fraction,
            "adaptive_compute_fraction": mean("adaptive_fused",
                                              "compute_fraction"),
            "static_sample_s": mean("static", "sample_s"),
            "adaptive_fused_sample_s": t_fused_mean,
            "adaptive_host_sample_s": t_host_mean,
            "per_step_dispatch_overhead_s": (t_host_mean - t_fused_mean)
                                            / STEPS,
            "fused_device_syncs_per_run": 0,
            "host_device_syncs_per_run": mean("adaptive_host",
                                              "device_syncs"),
            "static_l1": mean("static", "l1_vs_reference"),
            "adaptive_l1": mean("adaptive_fused", "l1_vs_reference"),
        },
    }
    common.write_bench_json("BENCH_adaptive.json", result)

    m = result["mean"]
    common.emit("adaptive/static_sample", m["static_sample_s"] * 1e6,
                f"compute_frac={m['static_compute_fraction']:.3f}"
                f";l1_vs_ref={m['static_l1']:.4f}")
    for name in ("fused", "host"):
        common.emit(
            f"adaptive/{name}_sample",
            m[f"adaptive_{name}_sample_s"] * 1e6,
            f"compute_frac={m['adaptive_compute_fraction']:.3f}"
            f";l1_vs_ref={m['adaptive_l1']:.4f}"
            f";syncs={m[f'{name}_device_syncs_per_run']:g}")
    common.emit("adaptive/dispatch_overhead",
                m["per_step_dispatch_overhead_s"] * 1e6,
                f"per_step_us;steps={STEPS}")
    common.emit("adaptive/pool", len(pool),
                f"fused_programs={fused_programs}"
                f";host_programs={host_programs}"
                f";inputs={len(inputs)};tau={TAU}")


if __name__ == "__main__":
    run()
