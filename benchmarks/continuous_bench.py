"""Continuous in-flight batching benchmark: boundary joins vs a
join-disabled engine on one fixed arrival trace.

Two sections, both hard-asserted in-run:

* **virtual** — a deterministic virtual-clock scenario with a fake
  split-capable executor charging per computed layer eval: the same
  staggered arrival trace drains through a join-enabled and a
  join-disabled ``ServeEngine``.  Asserts the join engine's p95 queue
  wait strictly beats the baseline, that joins actually happened, that
  every served row is bit-identical to that request's own-key reference
  payload, and that every compiled shape stays on an admissible
  power-of-two bucket within the program budget.
* **real** — the smoke DiT under joining (static entry and a τ=0 fused
  adaptive entry): late arrivals join an in-flight run at a segment
  boundary, and every served latent must be **bit-identical** to a direct
  ``DiffusionPipeline.generate`` of that request's own key.  Asserts the
  fused path never syncs (``host_sync_count == 0``) and programs stay
  within budget.

Writes ``BENCH_continuous.json`` (results dir + repo-root mirror).

    PYTHONPATH=src python -m benchmarks.run --only continuous
    CONTINUOUS_BENCH_STEPS=8 PYTHONPATH=src python -m benchmarks.continuous_bench
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from benchmarks import common
from repro import serve
from repro.serve.batcher import bucket_sizes

STEPS = int(os.environ.get("CONTINUOUS_BENCH_STEPS", "6"))
FAKE_STEPS = int(os.environ.get("CONTINUOUS_BENCH_FAKE_STEPS", "8"))
PAIRS = int(os.environ.get("CONTINUOUS_BENCH_PAIRS", "8"))
MAX_BATCH = 4
CFG_SCALE = 1.5


# ---------------------------------------------------------------------------
# Virtual section: deterministic fake executor (mirrors the test fakes —
# benchmarks are standalone modules, tests/ is not importable here)
# ---------------------------------------------------------------------------

class _FakeCfg:
    name = "fake-arch"

    def layer_types(self):
        return ("attn", "ffn")


class _FakeSolver:
    name = "ddim"

    def __init__(self, num_steps):
        self.num_steps = num_steps


@dataclasses.dataclass
class _SplitRunState:
    plan: object
    batch: int
    run_index: int = 0
    x: object = None
    keys: tuple = ()
    decisions = None

    @property
    def done(self):
        return self.run_index >= len(self.plan.runs)

    @property
    def step(self):
        if self.done:
            return self.plan.num_steps
        return self.plan.runs[self.run_index].start

    @property
    def num_steps(self):
        return self.plan.num_steps


def _payload(keys, batch):
    """Row j's 'latent' identifies its PRNG key — the same function of
    the same key no matter which batch the row rode in (the per-row
    determinism contract split/merge must preserve)."""
    if keys:
        return np.asarray([np.asarray(k, np.uint32).astype(np.float64)
                           for k in keys])
    return np.arange(batch, dtype=np.float64)[:, None]


class _SplitFakeExecutor:
    """Split-capable resumable-run fake charging the virtual clock per
    *computed* layer evaluation, so scheduling quality becomes exact
    virtual-latency numbers."""

    supports_split = True

    def __init__(self, clock, step_cost=1.0):
        self.clock = clock
        self.step_cost = step_cost
        self._programs = set()               # (kind, sig-ish, batch shape)

    def _charge(self, skip, length):
        computed = sum(1 for sk in skip.values() if not sk)
        self.clock.advance(self.step_cost * length
                           * computed / max(len(skip), 1))

    def start_run(self, params, key, batch, *, plan, schedule=None,
                  label=None, memory=None, row_keys=None):
        return _SplitRunState(plan=plan, batch=batch,
                              keys=tuple(row_keys or ()))

    def advance_run(self, params, rs, *, check=False):
        run = rs.plan.runs[rs.run_index]
        self._programs.add(("seg", run.sig, rs.batch))
        self._charge(run.sig.skip, run.length)
        rs = dataclasses.replace(rs, run_index=rs.run_index + 1)
        if rs.done:
            rs.x = _payload(rs.keys, rs.batch)
        return rs

    def split_run(self, rs, groups):
        return [dataclasses.replace(
            rs, batch=len(g), keys=tuple(rs.keys[j] for j in g))
            for g in groups]

    def merge_runs(self, runs):
        r0 = runs[0]
        return dataclasses.replace(
            r0, batch=sum(r.batch for r in runs),
            keys=tuple(k for r in runs for k in r.keys))

    def compiled_variant_count(self, kind=None):
        if kind is None:
            return len(self._programs)
        return len({p for p in self._programs if p[0] == kind})

    def xla_program_count(self, kind=None):
        return self.compiled_variant_count(kind)


def _virtual_trace():
    """Fixed trace: request pairs arriving one virtual second apart while
    each batch takes several virtual seconds — late pairs land mid-flight,
    which is exactly when a boundary join pays."""
    return [serve.Request(rid=2 * i + j, seed=2 * i + j, policy="static2",
                          arrival=float(i))
            for i in range(PAIRS) for j in (0, 1)]


def _virtual_drain(continuous: bool):
    clock = serve.VirtualClock()
    store = serve.ArtifactStore(_FakeCfg(), _FakeSolver(FAKE_STEPS))
    store.add_policy("static2", "static:n=2")
    ex = _SplitFakeExecutor(clock)
    eng = serve.ServeEngine(ex, params=None, store=store, clock=clock,
                            max_batch=MAX_BATCH, max_inflight=1,
                            continuous=continuous)
    eng.submit(*_virtual_trace())
    res = eng.run_until_drained()
    return eng, ex, res


def _run_virtual():
    eng_c, ex_c, res_c = _virtual_drain(True)
    eng_b, ex_b, res_b = _virtual_drain(False)
    p95 = lambda e: serve.percentile(e.metrics.queue_waits, 95)
    p95_c, p95_b = p95(eng_c), p95(eng_b)
    assert eng_c.metrics.joins > 0, "join engine never joined"
    assert eng_b.metrics.joins == 0
    assert p95_c < p95_b, (
        f"joining did not improve p95 queue wait: {p95_c} vs {p95_b}")
    # bit-equal outputs: every row the join engine served matches its
    # own-key reference payload — joins moved requests between batches
    # without touching any request's bits (the baseline engine runs
    # un-keyed, so it only asserts routing)
    assert sorted(res_c) == list(range(2 * PAIRS))
    assert sorted(res_b) == list(range(2 * PAIRS))
    for rid in res_c:
        np.testing.assert_array_equal(
            res_c[rid], _payload([serve.batch_key([rid])], 1)[0])
    for eng, ex in ((eng_c, ex_c), (eng_b, ex_b)):
        rep = eng.report()
        assert rep["compiles"]["xla_programs"] <= rep["program_budget"]
        assert {p[2] for p in ex._programs} <= set(bucket_sizes(MAX_BATCH))
    rep_c, rep_b = eng_c.report(), eng_b.report()
    common.emit("continuous/virtual/p95_wait", p95_c * 1e6,
                f"baseline={p95_b:.3f}s;joins={eng_c.metrics.joins};"
                f"joined={eng_c.metrics.joined_requests}")
    return {"continuous": rep_c, "baseline": rep_b,
            "p95_wait_s": {"continuous": p95_c, "baseline": p95_b}}


# ---------------------------------------------------------------------------
# Real section: smoke DiT, joins at real segment boundaries
# ---------------------------------------------------------------------------

def _real_drain(executor, params, store, cfg, policy):
    """Force a deterministic mid-flight join: submit a pair, advance one
    boundary, submit a second pair — with one in-flight slot the late
    pair can only run by joining."""
    eng = serve.ServeEngine(executor, params, store, max_batch=MAX_BATCH,
                            max_inflight=1, clock=serve.VirtualClock(),
                            continuous=True, adaptive_chunk=2)

    def rq(i):
        return serve.Request(rid=i, seed=100 + i, policy=policy,
                             label=i % cfg.num_classes)

    eng.submit(rq(0), rq(1))
    assert eng.step()
    eng.submit(rq(2), rq(3))
    res = eng.run_until_drained()
    assert sorted(res) == [0, 1, 2, 3]
    assert eng.metrics.joins == 1 and eng.metrics.joined_requests == 2
    rep = eng.report()
    assert rep["compiles"]["xla_programs"] <= rep["program_budget"]
    return eng, res, rep


def _run_real():
    import jax
    import jax.numpy as jnp
    import time
    from repro import cache, configs
    from repro.core import diffusion, solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg = configs.get("dit-xl-256", "smoke")
    solver = solvers.ddim(STEPS)
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape),
        params)

    # τ=0 fused adaptive artifact: the fused on-device loop with a
    # data-independent realized mask, so per-request bit-identity holds
    t0 = time.perf_counter()
    fused_pipe = cache.DiffusionPipeline(
        cfg, solver, "adaptive:base=budget(target=0.5),tau=0",
        cfg_scale=CFG_SCALE)
    fused_pipe.calibrate(params, jax.random.PRNGKey(1), 2,
                         cond_args={"label": jnp.zeros((2,), jnp.int32)})
    calib_s = time.perf_counter() - t0

    store = serve.ArtifactStore(cfg, solver, cfg_scale=CFG_SCALE)
    store.add_policy("static2", "static:n=2")
    store.add_artifact("fused0", fused_pipe.artifact)

    static_pipe = cache.DiffusionPipeline(cfg, solver, "static:n=2",
                                          cfg_scale=CFG_SCALE)
    static_pipe.prepare()

    results = {"meta": {"steps": STEPS, "arch": cfg.name,
                        "max_batch": MAX_BATCH, "calibration_s": calib_s}}
    for policy, pipe in (("static2", static_pipe), ("fused0", fused_pipe)):
        ex = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
        eng, res, rep = _real_drain(ex, params, store, cfg, policy)
        # the fused path never syncs the host for decisions, joined or not
        assert ex.host_sync_count == 0, (
            f"{policy}: {ex.host_sync_count} host syncs")
        # per-request replay contract: each served latent is bit-identical
        # to a direct generate of that request's own key
        for i in range(4):
            x = pipe.generate(params, serve.batch_key([100 + i]), 1,
                              label=jnp.asarray([i % cfg.num_classes],
                                                jnp.int32))
            np.testing.assert_array_equal(np.asarray(x[0]), res[i])
        results[policy] = rep
        common.emit(f"continuous/real/{policy}/throughput_rps",
                    rep["throughput_rps"] * 1e6,
                    f"joins={eng.metrics.joins};"
                    f"programs={rep['compiles']['xla_programs']}/"
                    f"{rep['program_budget']};bit_identical=1")
    return results


def _finite(obj):
    """Strict-JSON sanitizer: the virtual clock charges the real executor
    zero seconds, so its throughput is ∞ — which ``json.dumps`` would
    emit as the non-standard ``Infinity`` literal."""
    if isinstance(obj, dict):
        return {k: _finite(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_finite(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def run() -> None:
    virtual = _run_virtual()
    real = _run_real()
    path = common.write_bench_json("BENCH_continuous.json", _finite({
        "meta": {"fake_steps": FAKE_STEPS, "pairs": PAIRS,
                 "max_batch": MAX_BATCH},
        "virtual": virtual,
        "real": real,
    }))
    print(f"# wrote {path}")


if __name__ == "__main__":
    run()
