"""Observability benchmark: tracing overhead, trace validity, and the
zero-sync telemetry contract.

Two sections, both hard-asserted in-run:

* **virtual** — one fixed arrival trace drains through a fake-executor
  ``ServeEngine`` twice, tracer off vs tracer on (full lifecycle spans +
  instants + registry metrics).  Asserts the traced drain's best-of-N
  wall time stays within ``OBS_BENCH_MAX_OVERHEAD`` (default 5%) of the
  untraced one, that the served results and virtual makespan are
  identical (observation changes nothing observable), and that the
  exported Chrome trace JSON structurally validates (monotonic
  timestamps per track, every B matched by its E).  The trace is written
  to ``results/obs.trace.json`` — load it in Perfetto.
* **real** — the smoke DiT serving a calibrated τ>0 adaptive entry with
  tracer + step telemetry on vs both off: asserts the fused path's
  ``host_sync_count`` stays 0 with telemetry on, served latents are
  bit-identical on vs off, every request got a CacheReport whose
  realized decisions match the batch record, and the trace validates.

Writes ``BENCH_obs.json`` (results dir + repo-root mirror).

    PYTHONPATH=src python -m benchmarks.run --only obs
    OBS_BENCH_REAL_STEPS=4 PYTHONPATH=src python -m benchmarks.obs_bench
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from benchmarks import common
from repro import serve
from repro.obs import Tracer, validate_chrome_trace

MAX_OVERHEAD = float(os.environ.get("OBS_BENCH_MAX_OVERHEAD", "0.05"))
VIRT_REQUESTS = int(os.environ.get("OBS_BENCH_VIRT_REQUESTS", "256"))
VIRT_REPEATS = int(os.environ.get("OBS_BENCH_VIRT_REPEATS", "7"))
REAL_STEPS = int(os.environ.get("OBS_BENCH_REAL_STEPS", "6"))
REAL_REQUESTS = int(os.environ.get("OBS_BENCH_REAL_REQUESTS", "4"))
CFG_SCALE = 1.5


# ---------------------------------------------------------------------------
# Virtual section (fake executor mirrors the test fakes — benchmarks are
# standalone modules, tests/ is not importable here)
# ---------------------------------------------------------------------------

class _FakeCfg:
    name = "fake-arch"

    def layer_types(self):
        return ("attn", "ffn")


class _FakeSolver:
    name = "ddim"

    def __init__(self, num_steps):
        self.num_steps = num_steps


@dataclasses.dataclass
class _FakeRunState:
    plan: object
    batch: int
    run_index: int = 0
    x: object = None
    decisions = None

    @property
    def done(self):
        return self.run_index >= len(self.plan.runs)


class _FakeExecutor:
    def __init__(self, clock, step_cost=1.0):
        self.clock = clock
        self.step_cost = step_cost
        self._programs = set()

    def start_run(self, params, key, batch, *, plan, schedule=None,
                  label=None, memory=None):
        return _FakeRunState(plan=plan, batch=batch)

    def advance_run(self, params, rs, *, check=False):
        run = rs.plan.runs[rs.run_index]
        self._programs.add(("seg", run.sig, rs.batch))
        computed = sum(1 for sk in run.sig.skip.values() if not sk)
        self.clock.advance(self.step_cost * run.length
                           * computed / max(len(run.sig.skip), 1))
        rs = dataclasses.replace(rs, run_index=rs.run_index + 1)
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def compiled_variant_count(self, kind=None):
        if kind is None:
            return len(self._programs)
        return len({p for p in self._programs if p[0] == kind})

    def xla_program_count(self, kind=None):
        return self.compiled_variant_count(kind)


def _drain_virtual(traced: bool):
    """One full drain of the fixed virtual trace; returns (wall seconds,
    results, makespan, engine)."""
    clock = serve.VirtualClock()
    store = serve.ArtifactStore(_FakeCfg(), _FakeSolver(8))
    store.add_policy("static2", "static:n=2")
    store.add_policy("no_cache", "none")
    kw = {"tracer": Tracer(clock)} if traced else {}
    eng = serve.ServeEngine(_FakeExecutor(clock), params=None, store=store,
                            clock=clock, max_batch=4, max_inflight=2, **kw)
    eng.submit(*[serve.Request(
        rid=i, seed=i, policy="static2" if i % 3 else "no_cache",
        arrival=0.05 * i) for i in range(VIRT_REQUESTS)])
    t0 = time.perf_counter()
    res = eng.run_until_drained()
    wall = time.perf_counter() - t0
    return wall, res, clock.now(), eng


def _virtual_section() -> dict:
    best_off, best_on = float("inf"), float("inf")
    ref = None
    for _ in range(VIRT_REPEATS):
        w_off, res_off, mk_off, _ = _drain_virtual(False)
        w_on, res_on, mk_on, eng_on = _drain_virtual(True)
        best_off, best_on = min(best_off, w_off), min(best_on, w_on)
        # observation changes nothing observable: identical rows, same
        # virtual makespan, same batch shapes
        assert sorted(res_on) == sorted(res_off)
        for rid in res_on:
            np.testing.assert_array_equal(res_on[rid], res_off[rid])
        assert mk_on == mk_off
        ref = eng_on
    overhead = best_on / best_off - 1.0
    assert overhead < MAX_OVERHEAD, (
        f"tracing overhead {overhead:.1%} exceeds the "
        f"{MAX_OVERHEAD:.0%} budget (off {best_off * 1e3:.2f} ms, "
        f"on {best_on * 1e3:.2f} ms)")
    # the exported trace validates and lands on disk for Perfetto
    tracer = ref.tracer
    assert not tracer.open_spans()
    obj = tracer.to_chrome_trace()
    n_events = validate_chrome_trace(obj)
    os.makedirs(common.RESULTS_DIR, exist_ok=True)
    trace_path = os.path.join(common.RESULTS_DIR, "obs.trace.json")
    tracer.save(trace_path)
    with open(trace_path) as f:
        validate_chrome_trace(json.load(f))
    # metrics surface: same registry serves snapshot + exposition
    snap = ref.registry.snapshot()
    json.dumps(snap)
    expo = ref.registry.exposition()
    assert "# TYPE serve.batches counter" in expo
    assert snap["counters"]["serve.batches"] == len(ref.records)
    common.emit("obs/virtual_drain_off", best_off * 1e6,
                f"requests={VIRT_REQUESTS}")
    common.emit("obs/virtual_drain_on", best_on * 1e6,
                f"overhead={overhead:.2%}")
    common.emit("obs/trace_events", float(n_events), f"path={trace_path}")
    return {
        "requests": VIRT_REQUESTS,
        "repeats": VIRT_REPEATS,
        "drain_off_us": best_off * 1e6,
        "drain_on_us": best_on * 1e6,
        "overhead_fraction": overhead,
        "overhead_budget": MAX_OVERHEAD,
        "trace_events": n_events,
        "trace_path": trace_path,
        "results_bit_identical": True,
    }


# ---------------------------------------------------------------------------
# Real section: smoke DiT, tracer + telemetry on vs off
# ---------------------------------------------------------------------------

def _real_section() -> dict:
    import jax
    import jax.numpy as jnp
    from repro import cache, configs
    from repro.core import diffusion, solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape), params)
    tau = 0.3
    pipe = cache.DiffusionPipeline(
        cfg, solvers.ddim(REAL_STEPS),
        f"adaptive:base=smoothcache(alpha=0.5),tau={tau}",
        cfg_scale=CFG_SCALE)
    pipe.calibrate(params, jax.random.PRNGKey(1), 2,
                   cond_args={"label": jnp.zeros((2,), jnp.int32)})
    art = pipe.artifact

    def serve_once(obs: bool):
        clock = serve.VirtualClock()
        solver = solvers.ddim(REAL_STEPS)
        ex = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
        store = serve.ArtifactStore(cfg, solver, cfg_scale=CFG_SCALE)
        store.add_artifact("gen", art)
        kw = {"tracer": Tracer(clock), "telemetry": True} if obs else {}
        eng = serve.ServeEngine(ex, params, store, clock=clock,
                                max_batch=2, adaptive_chunk=3, **kw)
        eng.submit(*[serve.Request(rid=i, seed=100 + i, policy="gen",
                                   label=i % cfg.num_classes,
                                   arrival=0.0)
                     for i in range(REAL_REQUESTS)])
        res = eng.run_until_drained()
        return eng, res, ex

    eng_on, res_on, ex_on = serve_once(True)
    eng_off, res_off, _ = serve_once(False)
    # telemetry + tracing never change the served bits
    assert sorted(res_on) == sorted(res_off)
    for rid in res_on:
        np.testing.assert_array_equal(res_on[rid], res_off[rid])
    # the fused path stayed sync-free with the decision-trace carry on
    assert ex_on.host_sync_count == 0, ex_on.host_sync_count
    # every served request has an explainer consistent with its batch
    assert sorted(eng_on.cache_reports) == sorted(res_on)
    for rec in eng_on.records:
        for rid in rec.rids:
            rep = eng_on.cache_reports[rid]
            assert rep.realized == rec.decisions
            assert rep.tau == tau and rep.proxy is not None
            assert rep.proxy[0] is None
    assert not eng_off.cache_reports
    assert validate_chrome_trace(eng_on.tracer.to_chrome_trace()) > 0
    frac = eng_on.cache_reports[0].realized_compute_fraction()
    common.emit("obs/real_requests", float(REAL_REQUESTS),
                f"steps={REAL_STEPS} sync=0")
    common.emit("obs/real_compute_fraction", frac * 100, "percent")
    return {
        "steps": REAL_STEPS,
        "requests": REAL_REQUESTS,
        "tau": tau,
        "host_sync_count": int(ex_on.host_sync_count),
        "latents_bit_identical": True,
        "cache_reports": len(eng_on.cache_reports),
        "realized_compute_fraction": frac,
    }


def run() -> None:
    virtual = _virtual_section()
    real = _real_section()
    common.write_bench_json("BENCH_obs.json",
                            {"virtual": virtual, "real": real})


if __name__ == "__main__":
    run()
