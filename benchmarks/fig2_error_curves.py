"""Paper Fig. 2 — L1 relative error curves per layer type with 95% CIs
from 10 calibration samples, across the three modality models.

Emits per-type curve summaries + the cross-sample CI width (the paper's
key observation: curves are nearly input-independent, CI ≪ mean).  The
curves come straight out of `DiffusionPipeline.calibrate`'s artifact, and
the full artifact (curves + provenance) is what gets dumped to disk."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import cache, configs
from repro.core import solvers
from repro.data import BlobLatents, CondLatents

SETUPS = [
    ("dit-xl-256", "ddim", 50, 1.5, "eps"),
    ("opensora-v12", "rectified_flow", 30, None, "rf"),
    ("stable-audio-open", "dpmpp_3m_sde", 25, 7.0, "eps"),
]


def run():
    os.makedirs(os.path.join(common.RESULTS_DIR, "fig2"), exist_ok=True)
    for arch, solver_name, steps, cfg_scale, kind in SETUPS:
        cfg = configs.get(arch, "smoke")
        key = jax.random.PRNGKey(0)
        if cfg.num_classes:
            data = BlobLatents(cfg.latent_shape, cfg.num_classes, 10)
            params, _, _ = common.train_small_dit(cfg, key, steps=80,
                                                  data=data, loss_kind=kind)
            x0, label = data.batch_at(0)
            cond = {"label": label}
        else:
            data = CondLatents(cfg.latent_shape, cfg.cond_dim, 8, 10)
            params, _, _ = common.train_small_dit(cfg, key, steps=80,
                                                  data=data, loss_kind=kind)
            _, memory = data.batch_at(0)
            cond = {"memory": memory}
        solver = solvers.SOLVERS[solver_name](steps)
        pipe = cache.DiffusionPipeline(cfg, solver, "smoothcache:alpha=0.18",
                                       cfg_scale=cfg_scale)
        artifact = pipe.calibrate(params, jax.random.PRNGKey(1), 10,
                                  cond_args=cond)
        artifact.save(os.path.join(common.RESULTS_DIR, "fig2",
                                   f"{arch}.cache.json"))
        per_sample = pipe.per_sample
        dump = {}
        for t, c in artifact.curves.items():
            ps = per_sample[t][:, :, 1]                 # lag-1, (B, S)
            mean = np.nanmean(ps, axis=0)
            ci = 1.96 * np.nanstd(ps, axis=0) / np.sqrt(ps.shape[0])
            rel_ci = float(np.nanmean(ci[1:] / (mean[1:] + 1e-9)))
            common.emit(f"fig2/{arch}/{t}", 0.0,
                        f"mean_err_lag1={np.nanmean(mean[1:]):.4f};"
                        f"rel_ci95={rel_ci:.3f}")
            dump[t] = {"mean": mean.tolist(), "ci95": ci.tolist(),
                       "curves": np.nan_to_num(c).tolist()}
        with open(os.path.join(common.RESULTS_DIR, "fig2",
                               f"{arch}.json"), "w") as f:
            json.dump(dump, f)


if __name__ == "__main__":
    run()
