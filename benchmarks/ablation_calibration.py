"""Paper §3.3 + supplement ablations, on the `repro.cache` policy API.

1. **Calibration sample size**: the paper observes ~10 samples suffice and
   more samples only tighten the CI, not the mean — we regenerate the
   α-schedule from 2/5/10/20 calibration samples and report schedule
   agreement vs the 20-sample reference (paper: 'reliably regenerate the
   same caching schedule given the same α').
2. **Caching/sample-step Pareto front**: schedules at multiple DDIM step
   counts (30/50/70, paper Table 1 rows) — SmoothCache tracks or beats the
   FORA front at matched compute on the quality proxy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import cache, configs
from repro.core import solvers
from repro.data import BlobLatents


def run():
    cfg = configs.get("dit-xl-256", "smoke")
    params, _, _ = common.train_small_dit(cfg, jax.random.PRNGKey(0),
                                          steps=120)
    nclass = cfg.num_classes

    # ---- 1. calibration sample size ----
    policy = cache.SmoothCache(alpha=0.15, k_max=3)
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(50), policy,
                                   cfg_scale=1.5)
    ref_sched = None
    for n in (20, 10, 5, 2):
        label = jnp.arange(n) % nclass
        art = pipe.calibrate(params, jax.random.PRNGKey(7), n,
                             cond_args={"label": label})
        sch = art.schedule
        if ref_sched is None:
            ref_sched = sch
            agree = 1.0
        else:
            bits = np.concatenate([sch.skip[t] for t in sorted(sch.skip)])
            ref = np.concatenate([ref_sched.skip[t]
                                  for t in sorted(ref_sched.skip)])
            agree = float(np.mean(bits == ref))
        per_sample = pipe.per_sample
        ci = np.nanmean([1.96 * np.nanstd(per_sample[t][:, 1:, 1], axis=0)
                         / max(np.sqrt(n), 1) for t in per_sample])
        common.emit(f"ablation/calib_n{n}", 0.0,
                    f"schedule_agreement_vs_n20={agree:.3f};mean_ci95={ci:.4f}")

    # ---- 2. Pareto front across sampling steps ----
    data = BlobLatents(cfg.latent_shape, nclass, 32, seed=5)
    ref_x0, ref_label = data.batch_at(0)
    for steps in (30, 50, 70):
        pipe = cache.DiffusionPipeline(cfg, solvers.ddim(steps),
                                       "smoothcache:alpha=0.15",
                                       cfg_scale=1.5)
        label = jnp.arange(8) % nclass
        pipe.calibrate(params, jax.random.PRNGKey(8), 8,
                       cond_args={"label": label})

        def fd_of(sch):
            x = pipe.generate(params, jax.random.PRNGKey(9), 32,
                              schedule=sch, label=ref_label)
            return common.frechet_distance(np.asarray(x), np.asarray(ref_x0))

        fd0 = fd_of(None)
        for n in (2, 3):
            fora = pipe.schedule_for(f"static:n={n}")
            fd_f = fd_of(fora)
            frac = np.mean([fora.compute_fraction(t) for t in fora.skip])
            sc = pipe.schedule_for(f"budget:target={frac}")
            fd_s = fd_of(sc)
            common.emit(
                f"ablation/pareto_s{steps}_frac{frac:.2f}", 0.0,
                f"fd_nocache={fd0:.3f};fd_fora_n{n}={fd_f:.3f};"
                f"fd_smoothcache={fd_s:.3f};"
                f"smoothcache_wins={int(fd_s <= fd_f)}")


if __name__ == "__main__":
    run()
