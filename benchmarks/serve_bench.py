"""Serving-engine benchmark: no_cache vs smoothcache vs adaptive under
one arrival trace.

Calibrates a budgeted adaptive policy once on the smoke DiT, loads the
artifact into an `ArtifactStore` three ways (uncached baseline, the static
base schedule, the adaptive runtime rule), then drains the **same Poisson
arrival trace** through the continuous-batching `ServeEngine` under each
entry plus a heterogeneous mix.  Reports throughput, p50/p95 queue wait
and service time, realized compute fraction, and compiled-program counts
against the |buckets| × |signature pool| budget.  A warmup drain on a
separate engine (same executor → same program table) absorbs compile time
so the measured trace reflects steady-state serving.

Writes ``BENCH_serve.json`` (results dir + repo-root trajectory mirror).

Caveat for reading the numbers: on the CPU smoke model, per-segment
dispatch overhead rivals the (tiny) model compute, so cached schedules
need not beat ``no_cache`` on wall time here — the benchmark tracks the
*serving layer* (queue wait vs service split, bucket formation, compile
counts vs budget, realized compute fraction), which is scale-independent.

    PYTHONPATH=src python -m benchmarks.run --only serve
    SERVE_BENCH_STEPS=20 PYTHONPATH=src python -m benchmarks.serve_bench
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import cache, configs, serve
from repro.core import diffusion, solvers
from repro.core.executor import SmoothCacheExecutor

STEPS = int(os.environ.get("SERVE_BENCH_STEPS", "20"))
TAU = float(os.environ.get("SERVE_BENCH_TAU", "0.5"))
REQUESTS = int(os.environ.get("SERVE_BENCH_REQUESTS", "8"))
RATE = float(os.environ.get("SERVE_BENCH_RATE", "4.0"))
MAX_BATCH = 4
CFG_SCALE = 1.5
CALIB_BATCH = 2


def _trace(policies, cfg, start: float):
    """The shared arrival trace: same seeds/labels/arrival offsets for
    every scenario; only the policy assignment changes."""
    rng = np.random.RandomState(0)
    arrivals = serve.poisson_arrivals(RATE, REQUESTS, rng)
    return [serve.Request(
        rid=i, seed=int(rng.randint(1 << 30)),
        policy=policies[i % len(policies)],
        label=int(rng.randint(cfg.num_classes)),
        arrival=start + a) for i, a in enumerate(arrivals)]


def _drain(executor, params, store, policies, cfg):
    eng = serve.ServeEngine(executor, params, store, max_batch=MAX_BATCH,
                            max_wait=0.2, max_inflight=2)
    eng.submit(*_trace(policies, cfg, eng.clock.now()))
    eng.run_until_drained()
    return eng.report()


def run() -> None:
    cfg = configs.get("dit-xl-256", "smoke")
    solver = solvers.ddim(STEPS)
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape),
        params)

    # offline: one calibration pass → one artifact reused by both entries
    pipe = cache.DiffusionPipeline(
        cfg, solver, f"adaptive:base=budget(target=0.5),tau={TAU}",
        cfg_scale=CFG_SCALE)
    t0 = time.perf_counter()
    pipe.calibrate(params, jax.random.PRNGKey(1), CALIB_BATCH,
                   cond_args={"label": jnp.zeros((CALIB_BATCH,), jnp.int32)})
    calib_s = time.perf_counter() - t0
    art = pipe.artifact

    # serving: store with the uncached baseline, the artifact's static base
    # schedule, and the adaptive runtime rule over the same artifact
    store = serve.ArtifactStore(cfg, solver, cfg_scale=CFG_SCALE)
    store.add_policy("no_cache", "none")
    store.add_artifact("smoothcache", art, policy="budget:target=0.5")
    store.add_artifact("adaptive", art)

    scenarios = {
        "no_cache": ["no_cache"],
        "smoothcache": ["smoothcache"],
        "adaptive": ["adaptive"],
        "mixed": ["no_cache", "smoothcache", "adaptive"],
    }
    results = {}
    for name, policies in scenarios.items():
        # fresh executor per scenario: program counts are attributable
        # (warmup and measured drains share it, so compiles are absorbed)
        executor = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
        _drain(executor, params, store, policies, cfg)      # warmup compile
        rep = _drain(executor, params, store, policies, cfg)
        results[name] = rep
        common.emit(f"serve/{name}/throughput_rps",
                    rep["throughput_rps"] * 1e6,
                    f"q_p95={rep['queue_wait_s']['p95']:.3f}s;"
                    f"s_p95={rep['service_s']['p95']:.3f}s;"
                    f"compute={rep['compute_fraction']:.2f}")
        assert (rep["compiles"]["xla_programs"]
                <= rep["program_budget"]), (
            f"{name}: compiled {rep['compiles']['xla_programs']} programs, "
            f"budget {rep['program_budget']}")

    path = common.write_bench_json("BENCH_serve.json", {
        "meta": {"steps": STEPS, "tau": TAU, "requests": REQUESTS,
                 "rate_rps": RATE, "max_batch": MAX_BATCH,
                 "calibration_s": calib_s, "arch": cfg.name},
        "scenarios": results,
    })
    print(f"# wrote {path}")


if __name__ == "__main__":
    run()
