"""Paper Table 2 — OpenSora, Rectified Flow 30 steps.

TMACs ratios on the full OpenSora-like STDiT config (paper: α=0.02 →
1388.5/1612.1 = 0.861; α=0.03 → 1321.1/1612.1 = 0.819) plus measured
speedup / PSNR-vs-no-cache proxies (the paper's LPIPS/PSNR/SSIM are
computed relative to non-cached videos) on a small trained model.  Caching
is driven by `repro.cache` policies resolved against one calibration
artifact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import cache, configs
from repro.core import solvers
from repro.data import CondLatents
from repro.utils import flops

PAPER = [("a0.02", 0.861), ("a0.03", 0.819)]


def run():
    full = configs.get("opensora-v12")
    t_, s_ = 16, (32 // full.patch) ** 2
    steps = 30

    cfg = configs.get("opensora-v12", "smoke")
    key = jax.random.PRNGKey(0)
    data = CondLatents(cfg.latent_shape, cfg.cond_dim, 8, 8)
    params, _, losses = common.train_small_dit(cfg, key, steps=100,
                                               data=data, loss_kind="rf")
    pipe = cache.DiffusionPipeline(cfg, solvers.rectified_flow(steps),
                                   "smoothcache:alpha=0.1,k_max=5")
    x0, memory = data.batch_at(0)
    artifact = pipe.calibrate(params, jax.random.PRNGKey(1), 8,
                              cond_args={"memory": memory})
    assert set(artifact.curves) == {"s_attn", "s_xattn", "s_ffn",
                                    "t_attn", "t_xattn", "t_ffn"}, \
        sorted(artifact.curves)

    ntok = t_ * s_
    base = flops.sampler_tmacs(full, pipe.schedule_for("none"), ntok, 1,
                               video_shape=(t_, s_))
    common.emit("table2/no_cache/tmacs", 0.0,
                f"tmacs={base:.1f};paper=1612.1_unit_note")
    for name, paper_ratio in PAPER:
        sch = pipe.schedule_for(f"budget:target={paper_ratio},k_max=5")
        t = flops.sampler_tmacs(full, sch, ntok, 1, video_shape=(t_, s_))
        common.emit(f"table2/smoothcache_{name}/tmacs", 0.0,
                    f"tmacs={t:.1f};ratio={t/base:.3f};paper_ratio={paper_ratio:.3f}")

    # e2e on the small model: PSNR relative to non-cached output
    def sample_with(schedule):
        return pipe.generate(params, jax.random.PRNGKey(2), 4,
                             schedule=schedule, memory=memory[:4])

    ref = sample_with(None)
    t_base = common.time_call(lambda: sample_with(None), iters=2)
    common.emit("table2/no_cache/e2e", t_base, "psnr=inf")
    for alpha in (0.1, 0.3):
        sch = pipe.schedule_for(f"smoothcache:alpha={alpha},k_max=5")
        x = sample_with(sch)
        t = common.time_call(lambda: sample_with(sch), iters=2)
        mse = float(jnp.mean((x - ref) ** 2))
        rng = float(jnp.max(ref) - jnp.min(ref))
        psnr = 10 * np.log10(rng ** 2 / max(mse, 1e-12))
        frac = np.mean([sch.compute_fraction(ty) for ty in sch.skip])
        common.emit(f"table2/smoothcache_a{alpha}/e2e", t,
                    f"psnr={psnr:.1f};speedup={t_base/t:.2f};compute_frac={frac:.3f}")


if __name__ == "__main__":
    run()
