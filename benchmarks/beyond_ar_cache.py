"""§Beyond — the SmoothCache criterion applied to AR decoding.

The paper's observation is about adjacent diffusion timesteps; here we
probe the same layer-output-similarity criterion across adjacent DECODE
POSITIONS of an AR LM (the assigned-architecture serving path):  measure
per-type L1 relative errors between branch outputs at consecutive decode
steps, then skip FFN branches on alternating positions (reusing the
previous position's output) and report the logit divergence.

This is reported separately from the reproduction (DESIGN.md §4.2): it
re-uses the framework's branch-cache plumbing unchanged, demonstrating
the technique's machinery generalizes beyond its original setting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import configs
from repro.core import calibration
from repro.models import layers as L
from repro.models import transformer as T


def run():
    for arch in ("qwen3-14b", "mamba2-1.3b"):
        cfg = configs.get(arch, "smoke")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        b, plen, gen = 2, 16, 12
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, plen), 0,
                                  cfg.vocab_size)
        _, caches = T.prefill(cfg, params, toks, cache_len=plen + gen + 1,
                              cache_dtype=jnp.float32, moe_strategy="dense")

        # decode greedily, collecting branch outputs per position
        tok = jnp.argmax(T.forward(cfg, params, toks,
                                   moe_strategy="dense")[0][:, -1:], -1)
        per_pos = []
        for i in range(gen):
            x = T.embed_tokens(cfg, params, tok)
            x, branch, new_caches, _ = T.apply_stages(
                cfg, params, x, mode="decode", pos=plen + i, caches=caches,
                collect_branches=True)
            x = T.logits_from_hidden(
                cfg, params,
                L.apply_norm(cfg.norm, params["final_norm"], x))
            caches = new_caches
            per_pos.append(calibration.branch_outputs_by_type(cfg, branch))
            tok = jnp.argmax(x, -1)
        curves, _ = calibration.error_curves_from_trajectory(cfg, per_pos,
                                                             k_max=2)
        for t, c in curves.items():
            m = float(np.nanmean(c[1:, 1]))
            common.emit(f"beyond_ar/{arch}/{t}", 0.0,
                        f"mean_lag1_err={m:.3f}")


if __name__ == "__main__":
    run()
