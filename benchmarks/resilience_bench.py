"""Resilience benchmark: crash-free goodput under a deterministic fault
ramp, and the healthy-path cost of the fault net.

Three sections:

**Virtual fault ramp (deterministic).**  A fake executor on a
:class:`~repro.serve.request.VirtualClock` serves one mixed
static/adaptive trace while a seeded :class:`~repro.resilience.FaultPlan`
ramps the per-batch fault probability (NaN rows, stalled advances,
injected executor exceptions — split 50/30/20) across
``RESILIENCE_BENCH_RATES``.  At every rate the bench asserts **in-run**
that the engine is crash-free: every submitted rid resolves to a result
or an explicit reasoned shed (``resolved == offered``), the fault ledger
is internally consistent, and at rate 0 goodput is exactly 1.  Goodput,
shed taxonomy, retries/re-queues/degradations, and the virtual makespan
are recorded per rate.

**Healthy-path overhead.**  The same clean trace is drained with the
resilience layer on and off.  The *scheduling* cost is asserted exactly:
identical results, identical batch composition, bit-equal virtual
makespan — the fault net changes nothing about a healthy run.  The wall
ratio of the two drains is also measured and reported; on the fake
executor an advance is nearly free, so the Python-level guard code is
maximally amplified and the assertion is deliberately loose (< 2×) —
the honest number for real deployments is the real section's ratio,
where device compute amortizes the per-advance flag read.

**Real smoke-DiT section.**  Serves a short static trace twice (clean,
resilience on/off) for the wall ratio, then once more with a NaN
injected into one row of the first batch (``mark_flags=False`` — only
the executor's carry sentinels can catch it): the engine must finish
with zero crashes, deliver the healthy rows, recover the poisoned one
through the no_cache fallback, and keep ``host_sync_count`` at 0.

Writes ``BENCH_resilience.json`` (results dir + repo-root mirror).

    PYTHONPATH=src python -m benchmarks.run --only resilience
    RESILIENCE_BENCH_N=24 PYTHONPATH=src python -m benchmarks.resilience_bench
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from benchmarks import common
from repro import serve
from repro.cache.artifact import CacheArtifact
from repro.core import plan as plan_lib
from repro.core import schedule as S
from repro.resilience import (ChaosExecutor, FaultPlan, FaultSpec,
                              ResiliencePolicy, RetryPolicy, corrupt_artifact,
                              faults, payload_checksum)
from repro.slo.admission import ServiceCostModel

N = int(os.environ.get("RESILIENCE_BENCH_N", "48"))
RATES = [float(r) for r in
         os.environ.get("RESILIENCE_BENCH_RATES", "0,0.1,0.3").split(",")]
STEPS = 8
MAX_BATCH = 4
ARRIVAL_GAP = 0.25                    # virtual s between arrivals
SEED = int(os.environ.get("RESILIENCE_BENCH_SEED", "1"))

REAL_STEPS = int(os.environ.get("RESILIENCE_BENCH_REAL_STEPS", "6"))
REAL_REQUESTS = int(os.environ.get("RESILIENCE_BENCH_REAL_REQUESTS", "4"))


# ---------------------------------------------------------------------------
# Virtual-clock deployment (same fake shape as tests/test_serve.py)
# ---------------------------------------------------------------------------

class _Cfg:
    name = "fake-arch"

    def layer_types(self):
        return ("attn", "ffn")


class _Solver:
    name = "ddim"

    def __init__(self, num_steps):
        self.num_steps = num_steps


@dataclasses.dataclass
class _RunState:
    plan: plan_lib.ExecutionPlan
    batch: int
    run_index: int = 0
    x: object = None
    decisions = None

    @property
    def done(self):
        return self.run_index >= len(self.plan.runs)


@dataclasses.dataclass
class _AdaptiveState:
    schedule: object
    batch: int
    step: int = 0
    x: object = None
    decisions: tuple = ()

    @property
    def done(self):
        return self.step >= self.schedule.num_steps


class _FakeExecutor:
    """Charges the virtual clock per computed layer evaluation."""

    def __init__(self, clock, step_cost=1.0):
        self.clock = clock
        self.step_cost = step_cost
        self._programs = set()

    def _charge(self, skip, length):
        computed = sum(1 for sk in skip.values() if not sk)
        self.clock.advance(self.step_cost * length
                           * computed / max(len(skip), 1))

    def start_run(self, params, key, batch, *, plan, schedule=None,
                  label=None, memory=None):
        return _RunState(plan=plan, batch=batch)

    def advance_run(self, params, rs, *, check=False):
        run = rs.plan.runs[rs.run_index]
        self._programs.add(("seg", run.sig, rs.batch))
        self._charge(run.sig.skip, run.length)
        rs = dataclasses.replace(rs, run_index=rs.run_index + 1)
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def start_adaptive_run(self, params, key, batch, *, schedule, tau,
                           proxy_map=None, pool=None, k_max=3, label=None,
                           memory=None):
        return _AdaptiveState(schedule=schedule, batch=batch)

    def advance_adaptive_run(self, params, rs):
        mask = {t: bool(v[rs.step]) for t, v in rs.schedule.skip.items()}
        skipset = tuple(sorted(t for t, sk in mask.items() if sk))
        self._programs.add(("sigstep", skipset, rs.batch))
        self._charge(mask, 1)
        rs = dataclasses.replace(rs, step=rs.step + 1,
                                 decisions=rs.decisions + (skipset,))
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def compiled_variant_count(self, kind=None):
        if kind is None:
            return len(self._programs)
        return len({p for p in self._programs if p[0] == kind})

    def xla_program_count(self, kind=None):
        return self.compiled_variant_count(kind)


def _artifact(num_steps: int) -> CacheArtifact:
    types = ("attn", "ffn")
    sch = S.fora(types, num_steps, 2)
    pool = [list(sig.live_in) for sig in plan_lib.mask_lattice(sch)]
    return CacheArtifact(
        arch="fake-arch", solver="ddim", num_steps=num_steps,
        policy={"name": "adaptive", "base": {"name": "static", "n": 2},
                "tau": 0.1},
        curves={}, schedule=sch,
        plan=plan_lib.analyze(sch).to_jsonable(),
        adaptive={"tau": 0.1, "k_max": 1,
                  "proxy_map": {"coeffs": {"attn": [0.0, 0.01],
                                           "ffn": [0.0, 0.01]},
                                "mean_proxy": None},
                  "pool": pool},
        meta={})


def _store():
    store = serve.ArtifactStore(_Cfg(), _Solver(STEPS))
    store.add_policy("static2", "static:n=2")
    store.add_artifact("adaptive", _artifact(STEPS))
    return store


def _trace():
    return [serve.Request(rid=i, seed=i,
                          policy="adaptive" if i % 2 else "static2",
                          arrival=ARRIVAL_GAP * i) for i in range(N)]


def _drain(fault_rate: float, *, resilient: bool = True):
    """One engine over one chaos-wrapped fake drain; returns (summary,
    engine).  Asserts crash-free goodput in-run: every rid resolves."""
    clock = serve.VirtualClock()
    plan = FaultPlan(seed=SEED, nan_rate=0.5 * fault_rate,
                     stuck_rate=0.3 * fault_rate,
                     error_rate=0.2 * fault_rate, stall_s=30.0, max_chunk=2)
    ex = ChaosExecutor(_FakeExecutor(clock), plan, clock)
    pol = None
    if resilient:
        pol = ResiliencePolicy(
            retry=RetryPolicy(max_retries=2, backoff_base=0.05, seed=SEED),
            watchdog_factor=4.0, watchdog_floor_s=1.0)
    eng = serve.ServeEngine(
        ex, params=None, store=_store(), clock=clock, max_batch=MAX_BATCH,
        resilience=pol,
        cost_model=ServiceCostModel(default_step_cost=1.0))
    eng.submit(*_trace())
    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    # the crash-free contract, asserted in-run at every fault rate:
    # offered = served + explicitly shed, nothing lost, nothing raised
    resolved = len(eng.results) + len(eng.shed)
    assert resolved == N, f"{N - resolved} requests vanished"
    m = eng.metrics
    assert m.faults_total == sum(m.fault_kinds.values())
    summary = {
        "goodput_fraction": len(eng.results) / N,
        "shed": {"total": len(eng.shed),
                 "reasons": dict(sorted(m.shed_reasons.items()))},
        "faults": dict(sorted(m.fault_kinds.items())),
        "injected": dict(sorted(ex.injected.items())),
        "retries": m.retries,
        "requeued": m.requeued,
        "degraded": m.degraded,
        "makespan_virtual_s": clock.now(),
        "wall_s": wall,
    }
    return summary, eng


def _fault_ramp():
    out = {}
    for rate in RATES:
        summary, _ = _drain(rate)
        if rate == 0:
            assert summary["goodput_fraction"] == 1.0
            assert summary["faults"] == {}
        else:
            assert summary["goodput_fraction"] > 0.5, (
                f"fault rate {rate} starved goodput to "
                f"{summary['goodput_fraction']:.2f}")
        if rate == max(RATES) and rate > 0:
            assert sum(summary["faults"].values()) > 0, (
                "top-rate ramp struck no faults — the bench exercised "
                "nothing; pick a different RESILIENCE_BENCH_SEED")
        out[f"{rate:g}"] = summary
        common.emit(
            f"resilience/ramp@{rate:g}",
            summary["makespan_virtual_s"] * 1e6,
            f"goodput={summary['goodput_fraction']:.3f};"
            f"faults={sum(summary['faults'].values())};"
            f"retries={summary['retries']};shed={summary['shed']['total']}")
    return out


def _overhead():
    """Clean trace, resilience on vs off: exact scheduling equality plus
    a measured (loose, fake-amplified) wall ratio."""
    on_wall, off_wall = [], []
    on_eng = off_eng = None
    for _ in range(3):                        # min-of-3: tame timer noise
        s_on, on_eng = _drain(0.0, resilient=True)
        s_off, off_eng = _drain(0.0, resilient=False)
        on_wall.append(s_on["wall_s"])
        off_wall.append(s_off["wall_s"])
    # the fault net must not change a single healthy-path decision: same
    # results, same batches, bit-equal virtual makespan
    assert sorted(on_eng.results) == sorted(off_eng.results)
    assert all(np.array_equal(on_eng.results[r], off_eng.results[r])
               for r in on_eng.results)
    assert ([r.rids for r in on_eng.records]
            == [r.rids for r in off_eng.records])
    assert (on_eng.records[-1].finished_at
            == off_eng.records[-1].finished_at)
    ratio = min(on_wall) / max(min(off_wall), 1e-9)
    # fake advances are ~free, so this ratio is the guard code's Python
    # overhead amplified by orders of magnitude vs real serving — gate it
    # loosely here; the real section reports the deployable number
    assert ratio < 2.0, f"healthy-path guard overhead ratio {ratio:.2f}"
    common.emit("resilience/healthy_overhead", ratio * 1e6,
                f"wall_on={min(on_wall):.4f}s;wall_off={min(off_wall):.4f}s")
    return {"wall_ratio_fake": ratio,
            "wall_on_s": min(on_wall), "wall_off_s": min(off_wall),
            "virtual_makespan_equal": True}


def _integrity():
    """Checksum cost + corruption detection on a real artifact file."""
    import json
    import tempfile
    art = _artifact(STEPS)
    payload = json.loads(art.to_json())
    t0 = time.perf_counter()
    reps = 50
    for _ in range(reps):
        payload_checksum(payload)
    checksum_us = (time.perf_counter() - t0) / reps * 1e6
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "a.cache.json")
        art.save(path)
        corrupt_artifact(path, seed=SEED)
        try:
            CacheArtifact.load(path)
            raise AssertionError("corrupted artifact loaded silently")
        except ValueError as e:
            assert "checksum" in str(e)
    common.emit("resilience/checksum", checksum_us, "corruption=detected")
    return {"checksum_us": checksum_us, "corruption_detected": True}


# ---------------------------------------------------------------------------
# Real smoke-DiT section
# ---------------------------------------------------------------------------

def _real_section():
    import jax
    from repro import configs
    from repro.core import diffusion, solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape),
        params)

    def drain(fault: bool, resilient: bool):
        solver = solvers.ddim(REAL_STEPS)
        inner = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
        store = serve.ArtifactStore(cfg, solver, cfg_scale=1.5)
        store.add_policy("static2", "static:n=2")
        plan = FaultPlan(faults={0: FaultSpec(faults.NAN_LATENT, row=1,
                                              chunk=1)} if fault else {})
        ex = ChaosExecutor(inner, plan, mutate_latent=True,
                           mark_flags=False)
        eng = serve.ServeEngine(
            ex, params, store, max_batch=2, clock=serve.VirtualClock(),
            resilience=ResiliencePolicy() if resilient else None)
        eng.submit(*[serve.Request(rid=i, seed=100 + i, policy="static2",
                                   label=i % cfg.num_classes, arrival=0.0)
                     for i in range(REAL_REQUESTS)])
        t0 = time.perf_counter()
        eng.run_until_drained()
        return eng, inner, time.perf_counter() - t0

    # warm the program cache once, then time clean drains on/off
    drain(fault=False, resilient=True)
    _, _, wall_on = drain(fault=False, resilient=True)
    _, _, wall_off = drain(fault=False, resilient=False)
    ratio = wall_on / max(wall_off, 1e-9)

    eng, inner, _ = drain(fault=True, resilient=True)
    resolved = len(eng.results) + len(eng.shed)
    assert resolved == REAL_REQUESTS
    assert eng.metrics.fault_kinds.get(faults.NAN_LATENT, 0) >= 1, (
        "the executor sentinels missed an injected NaN")
    # sentinel reads ride the existing chunk boundaries: zero decision
    # syncs on the real path, with the fault net on and a fault struck
    assert inner.host_sync_count == 0
    common.emit("resilience/real", ratio * 1e6,
                f"wall_on={wall_on:.3f}s;wall_off={wall_off:.3f}s;"
                f"goodput={len(eng.results)}/{REAL_REQUESTS};"
                f"host_syncs={inner.host_sync_count}")
    return {
        "steps": REAL_STEPS,
        "requests": REAL_REQUESTS,
        "wall_ratio_clean": ratio,
        "wall_on_s": wall_on,
        "wall_off_s": wall_off,
        "faulted_goodput": len(eng.results) / REAL_REQUESTS,
        "fault_kinds": dict(sorted(eng.metrics.fault_kinds.items())),
        "host_sync_count": inner.host_sync_count,
    }


def run() -> None:
    ramp = _fault_ramp()
    overhead = _overhead()
    integrity = _integrity()
    real = _real_section()
    path = common.write_bench_json("BENCH_resilience.json", {
        "meta": {"requests": N, "fault_rates": RATES, "seed": SEED,
                 "virtual_steps": STEPS, "max_batch": MAX_BATCH,
                 "fault_split": {"nan_latent": 0.5, "stuck_batch": 0.3,
                                 "injected": 0.2},
                 "retry": {"max_retries": 2, "backoff_base": 0.05},
                 "watchdog": {"factor": 4.0, "floor_s": 1.0}},
        "ramp": ramp,
        "healthy_overhead": overhead,
        "integrity": integrity,
        "real": real,
    })
    print(f"# wrote {path}")


if __name__ == "__main__":
    run()
