"""SLO benchmark: fixed-τ deployments vs the elastic τ controller.

Two sections:

**Virtual ramp (deterministic).**  A fake executor on a
:class:`~repro.serve.request.VirtualClock` charges service seconds that
shrink with the serving rung's τ (the SmoothCache quality↔compute
trade-off, abstracted to its scheduling-relevant shape), and one seeded
two-class trace — 87.5 % "bulk" (deadline only) / 12.5 % "strict"
(deadline plus a ``max_tau=0.05`` quality floor) — ramps its arrival
rate across phases (2 → 4 → 10 req/s by default) through one engine per
deployment:

* ``fixed:tau=0``    — one rung at full quality: overloads first
  (queueing + admission sheds turn into deadline misses);
* ``fixed:tau=0.05`` — one mid rung: serves everyone until the ramp's
  top rate exceeds its capacity;
* ``fixed:tau=0.2``  — one fast rung: never queues, but every *strict*
  request is shed at its quality floor, capping attainment at the bulk
  share;
* ``elastic``        — the full τ ladder + ``ElasticPolicy``: the
  controller degrades bulk traffic to the fast rung under load while
  capped requests keep their ``tau<=0.05`` rung.

The bench asserts that in the **highest-rate phase** the elastic
deployment's SLO attainment is *strictly* higher than every fixed-τ
baseline's, that shed/deferred requests are accounted in goodput (offered
= finished + shed in every report), and that the fake's fused-program
table stays within the τ-ladder budget (all τ>0 rungs share one program
per bucket).  The per-scenario mean predicted quality cost is recorded
alongside attainment — the quality↔attainment Pareto the elastic
controller trades along.

**Real smoke-DiT section.**  Calibrates one adaptive artifact, registers
a two-rung ladder, serves a small elastic trace, and asserts the compiled
XLA program count stays within the engine's reported budget — rung
membership adds zero programs beyond it.

Writes ``BENCH_slo.json`` (results dir + repo-root trajectory mirror).

    PYTHONPATH=src python -m benchmarks.run --only slo
    SLO_BENCH_N=24 PYTHONPATH=src python -m benchmarks.slo_bench
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from benchmarks import common
from repro import serve, slo
from repro.cache.artifact import CacheArtifact
from repro.core import plan as plan_lib
from repro.core import schedule as S

#: requests per ramp phase (virtual section)
N = int(os.environ.get("SLO_BENCH_N", "64"))
#: arrival-rate ramp, req/s of virtual time (one continuous trace —
#: phase i runs at RATES[i]; the controller adapts *during* the ramp)
RATES = [float(r) for r in
         os.environ.get("SLO_BENCH_RATES", "2,4,10").split(",")]
STEPS = 8                                     # virtual sampling steps
STEP_COST = 0.25                              # virtual s per computed step
MAX_BATCH = 4
LADDER = (0.0, 0.05, 0.2)
#: fraction of steps actually computed at each rung (τ=0 realizes the
#: static fora schedule; higher rungs reuse more layer outputs) —
#: per-batch service is STEPS × STEP_COST × FRAC[τ] = 1.0/0.5/0.2 s, so
#: full-bucket capacity is 4/8/20 req/s across the ladder
FRAC = {0.0: 0.5, 0.05: 0.25, 0.2: 0.1}

REAL_STEPS = int(os.environ.get("SLO_BENCH_REAL_STEPS", "6"))
REAL_REQUESTS = int(os.environ.get("SLO_BENCH_REAL_REQUESTS", "5"))


# ---------------------------------------------------------------------------
# Virtual-clock deployment (same shape as tests/test_slo.py's fakes)
# ---------------------------------------------------------------------------

class _Cfg:
    name = "fake-arch"

    def layer_types(self):
        return ("attn", "ffn")


class _Solver:
    name = "ddim"

    def __init__(self, num_steps):
        self.num_steps = num_steps


def _computed_steps(num_steps: int, tau: float):
    """Evenly spread compute steps realizing FRAC[tau]."""
    k = max(1, round(FRAC[round(tau, 6)] * num_steps))
    return {round(i * num_steps / k) for i in range(k)}


@dataclasses.dataclass
class _FusedState:
    schedule: object
    tau: float
    batch: int
    step: int = 0
    x: object = None

    @property
    def done(self):
        return self.step >= self.schedule.num_steps

    @property
    def num_steps(self):
        return self.schedule.num_steps

    @property
    def decisions(self):
        types = tuple(sorted(self.schedule.skip))
        if self.tau <= 0:
            return tuple(
                tuple(t for t in types if self.schedule.skip[t][s])
                for s in range(self.step))
        comp = _computed_steps(self.schedule.num_steps, self.tau)
        return tuple(() if s in comp else types
                     for s in range(self.step))


class _TauExecutor:
    """Charges ``STEP_COST`` virtual seconds per computed step; reuse
    steps are free.  Fused program keying mirrors the real executor: τ is
    a traced argument, so all τ>0 rungs of one pool share ONE program per
    batch bucket (τ=0 compiles its skip-table variant)."""

    supports_fused_adaptive = True

    def __init__(self, clock):
        self.clock = clock
        self._programs = set()

    def start_adaptive_fused_run(self, params, key, batch, *, schedule,
                                 tau, proxy_map=None, pool=None, k_max=3,
                                 label=None, memory=None):
        pool_key = tuple(sorted(tuple(s.live_in) for s in pool))
        self._programs.add(("fused", pool_key, tau > 0, batch))
        return _FusedState(schedule=schedule, tau=tau, batch=batch)

    def advance_adaptive_fused(self, params, rs, n_steps=None):
        remaining = rs.schedule.num_steps - rs.step
        length = remaining if n_steps is None else min(n_steps, remaining)
        if rs.tau <= 0:
            comp = {s for s in range(rs.schedule.num_steps)
                    if not all(v[s] for v in rs.schedule.skip.values())}
        else:
            comp = _computed_steps(rs.schedule.num_steps, rs.tau)
        cost = sum(STEP_COST for s in range(rs.step, rs.step + length)
                   if s in comp)
        self.clock.advance(cost)
        rs = dataclasses.replace(rs, step=rs.step + length)
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def compiled_variant_count(self, kind=None):
        if kind is None:
            return len(self._programs)
        return len({p for p in self._programs if p[0] == kind})

    def xla_program_count(self, kind=None):
        return self.compiled_variant_count(kind)


def _artifact(num_steps: int) -> CacheArtifact:
    types = ("attn", "ffn")
    sch = S.fora(types, num_steps, 2)
    pool = [list(sig.live_in) for sig in plan_lib.mask_lattice(sch)]
    return CacheArtifact(
        arch="fake-arch", solver="ddim", num_steps=num_steps,
        policy={"name": "adaptive", "base": {"name": "static", "n": 2},
                "tau": 0.05},
        curves={}, schedule=sch,
        plan=plan_lib.analyze(sch).to_jsonable(),
        adaptive={"tau": 0.05, "k_max": 1,
                  "proxy_map": {"coeffs": {"attn": [0.01, 0.02],
                                           "ffn": [0.01, 0.02]},
                                "mean_proxy": 1.0},
                  "pool": pool},
        meta={})


def _trace(seed: int):
    """One continuous ramp: N arrivals at each rate in RATES."""
    classes = [
        slo.RequestClass("bulk", "gen", weight=7.0,
                         deadline_budget=(2.0, 4.0)),
        slo.RequestClass("strict", "gen", weight=1.0, priority=1,
                         deadline_budget=3.0, max_tau=0.05),
    ]
    return slo.overload_trace(classes, [(r, N) for r in RATES],
                              np.random.RandomState(seed))


def _drain(taus, policy, trace):
    clock = serve.VirtualClock()
    store = serve.ArtifactStore(_Cfg(), _Solver(STEPS))
    store.add_ladder("gen", _artifact(STEPS), taus=list(taus))
    ex = _TauExecutor(clock)
    # headroom < 1: the cost model observes wall service time, which under
    # max_inflight=2 interleaving includes the co-scheduled run (~2x the
    # true cost), and EDF serves urgent requests ahead of the serially
    # priced backlog — without the discount admission sheds requests that
    # still have seconds of feasible slack
    # max_wait > 0 is load-bearing: immediate formation fragments the
    # queue into bucket-1 batches (one request per 0.2 s rung-2 run ≈
    # 5 req/s realized), which no rung can save; 0.2 s of coalescing
    # restores full-bucket capacity for every scenario alike
    eng = serve.ServeEngine(
        ex, None, store, clock=clock, max_batch=MAX_BATCH,
        max_inflight=2, max_wait=0.2, scheduler=policy,
        admission=slo.AdmissionController(headroom=0.3))
    eng.submit(*trace)
    eng.run_until_drained()
    rep = eng.report()
    # nothing vanishes: offered traffic = finished + shed, exactly
    assert rep["slo"]["offered"] == rep["requests"] + rep["shed"]["total"]
    assert rep["slo"]["offered"] == len(trace)
    # τ is a traced argument: ≤ 2 fused programs (τ=0 variant + shared
    # τ>0 variant) per batch bucket, regardless of ladder size
    buckets = {p[3] for p in ex._programs}
    assert ex.compiled_variant_count("fused") <= 2 * max(len(buckets), 1)
    assert rep["compiles"]["xla_programs"] <= rep["program_budget"]
    # per-phase attainment from the request outcomes (phase i = rids
    # [i*N, (i+1)*N)); a shed request never attains
    by_rate = {}
    for i, rate in enumerate(RATES):
        phase = trace[i * N:(i + 1) * N]
        by_rate[f"{rate:g}"] = sum(r.attained() for r in phase) / len(phase)
    return rep, by_rate


def _summarize(rep):
    qc = rep["predicted_quality_cost"]
    waits = rep.get("queue_wait_s") or {}
    return {
        "attainment": rep["slo"]["attainment"],
        "goodput_fraction": rep["slo"]["goodput_fraction"],
        "requests": rep["requests"],
        "shed": rep["shed"],
        "deferrals": rep["deferrals"],
        "realized_tau": rep["realized_tau"],
        "mean_quality_cost": qc["mean"],
        "p95_wait_s": waits.get("p95"),
    }


def _virtual_sweep():
    def elastic_policy():
        # tight target + short interval/cooldown: under a ramp the
        # controller must outrun admission's infeasibility shedding
        # (sheds remove the very requests whose waits would have pushed
        # p95 over the threshold)
        return slo.ElasticPolicy(slo.ElasticTauController(
            len(LADDER), target_p95_wait_s=0.25, window=32,
            min_samples=2, interval_s=0.1, band=0.25, cooldown_s=0.2,
            settle=4))

    scenarios = {
        "fixed:tau=0": lambda: ((LADDER[0],), "edf"),
        "fixed:tau=0.05": lambda: ((LADDER[1],), "edf"),
        "fixed:tau=0.2": lambda: ((LADDER[2],), "edf"),
        "elastic": lambda: (LADDER, elastic_policy()),
    }
    out = {}
    for name, make in scenarios.items():
        taus, policy = make()
        rep, by_rate = _drain(taus, policy, _trace(1000))
        summary = _summarize(rep)
        summary["attainment_by_rate"] = by_rate
        out[name] = summary
        common.emit(
            f"slo/{name}", (summary["p95_wait_s"] or 0.0) * 1e6,
            ";".join(f"attain@{r}={a:.3f}" for r, a in by_rate.items())
            + f";shed={summary['shed']['total']}"
            + f";qcost={summary['mean_quality_cost'] or 0:.3f}")

    top = f"{max(RATES):g}"
    elastic_at = out["elastic"]["attainment_by_rate"][top]
    for name in scenarios:
        if name == "elastic":
            continue
        fixed_at = out[name]["attainment_by_rate"][top]
        assert elastic_at > fixed_at, (
            f"at {top} req/s elastic attainment {elastic_at:.3f} must "
            f"strictly beat {name} ({fixed_at:.3f})")
    return out


# ---------------------------------------------------------------------------
# Real smoke-DiT ladder: zero programs beyond the budget
# ---------------------------------------------------------------------------

def _real_section():
    import jax
    import jax.numpy as jnp
    from repro import cache, configs
    from repro.core import diffusion, solvers
    from repro.core.executor import SmoothCacheExecutor

    cfg = configs.get("dit-xl-256", "smoke")
    solver = solvers.ddim(REAL_STEPS)
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape),
        params)
    pipe = cache.DiffusionPipeline(
        cfg, solver, "adaptive:base=smoothcache(alpha=0.5),tau=0.3",
        cfg_scale=1.5)
    pipe.calibrate(params, jax.random.PRNGKey(1), 2,
                   cond_args={"label": jnp.zeros((2,), jnp.int32)})

    store = serve.ArtifactStore(cfg, solver, cfg_scale=1.5)
    ladder = store.add_ladder("gen", pipe.artifact, taus=[0.0, 0.3])
    ctrl = slo.ElasticTauController(len(ladder.taus),
                                    target_p95_wait_s=0.05,
                                    min_samples=2, interval_s=0.0,
                                    cooldown_s=0.0)
    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
    eng = serve.ServeEngine(ex, params, store, max_batch=2,
                            max_inflight=2,
                            scheduler=slo.ElasticPolicy(ctrl),
                            admission=slo.AdmissionController())
    eng.submit(*[serve.Request(rid=i, seed=100 + i, policy="gen",
                               label=i % cfg.num_classes,
                               slo=slo.SLO(deadline=1e9))
                 for i in range(REAL_REQUESTS)])
    eng.run_until_drained()
    rep = eng.report()
    assert rep["requests"] == REAL_REQUESTS
    assert rep["compiles"]["xla_programs"] <= rep["program_budget"], (
        f"τ-ladder serving compiled {rep['compiles']['xla_programs']} "
        f"programs, budget {rep['program_budget']}")
    common.emit("slo/real/xla_programs",
                float(rep["compiles"]["xla_programs"]),
                f"budget={rep['program_budget']};"
                f"rungs={len(ladder.taus)};"
                f"attain={rep['slo']['attainment']:.2f}")
    return {
        "steps": REAL_STEPS,
        "taus": list(ladder.taus),
        "xla_programs": rep["compiles"]["xla_programs"],
        "program_budget": rep["program_budget"],
        "attainment": rep["slo"]["attainment"],
        "realized_tau": rep["realized_tau"],
        "controller_changes": len(ctrl.history),
    }


def run() -> None:
    virtual = _virtual_sweep()
    real = _real_section()
    path = common.write_bench_json("BENCH_slo.json", {
        "meta": {"requests_per_rate": N, "rates_rps": RATES,
                 "virtual_steps": STEPS, "ladder_taus": list(LADDER),
                 "compute_fraction_per_rung": {f"{t:g}": FRAC[t]
                                               for t in LADDER},
                 "classes": {"bulk": {"share": 0.875,
                                      "deadline_s": [2.0, 4.0]},
                             "strict": {"share": 0.125,
                                        "deadline_s": 3.0,
                                        "max_tau": 0.05}}},
        "virtual": virtual,
        "real": real,
    })
    print(f"# wrote {path}")


if __name__ == "__main__":
    run()
