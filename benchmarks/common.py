"""Shared benchmark helpers: timing, CSV emission, small trained models."""
from __future__ import annotations

import os
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")

#: repo root — BENCH_*.json files are mirrored here so the perf trajectory
#: is tracked per PR in-tree (results/ holds the working copies)
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def write_bench_json(filename: str, obj) -> str:
    """Write a benchmark result JSON to RESULTS_DIR and mirror the
    ``BENCH_*.json`` trajectory files at the repo root.  The mirror fires
    only when the resolved results dir *is* the repo's canonical
    ``results/`` — scratch runs that redirect REPRO_RESULTS (or write
    into some other cwd's results dir) never touch the tracked copies."""
    import json
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, filename)
    payload = json.dumps(obj, indent=2, sort_keys=True)
    with open(path, "w") as f:
        f.write(payload)
    if (filename.startswith("BENCH_") and os.path.abspath(RESULTS_DIR)
            == os.path.join(REPO_ROOT, "results")):
        with open(os.path.join(REPO_ROOT, filename), "w") as f:
            f.write(payload)
    return path


def time_call(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds (CPU; jit-warmed)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def frechet_distance(a: np.ndarray, b: np.ndarray) -> float:
    """Fréchet distance between two sample sets on flattened features —
    the offline FID proxy (no Inception network is available)."""
    a = a.reshape(a.shape[0], -1).astype(np.float64)
    b = b.reshape(b.shape[0], -1).astype(np.float64)
    mu_a, mu_b = a.mean(0), b.mean(0)
    # diagonal-covariance Fréchet (stable for small sample counts)
    va, vb = a.var(0) + 1e-8, b.var(0) + 1e-8
    return float(np.sum((mu_a - mu_b) ** 2)
                 + np.sum(va + vb - 2.0 * np.sqrt(va * vb)))


def train_small_dit(cfg, key, steps: int = 150, batch: int = 16,
                    lr: float = 2e-3, data=None, loss_kind: str = "eps"):
    """Train the smoke DiT on synthetic latents so caching quality deltas
    are measurable.  Returns (params, sched)."""
    from repro.core import diffusion
    from repro.data import BlobLatents, CondLatents
    from repro import optim

    params = diffusion.init_params(key, cfg)
    sched = diffusion.vp_schedule()
    if data is None:
        data = BlobLatents(cfg.latent_shape, max(cfg.num_classes, 1), batch)
    ocfg = optim.AdamWConfig(lr=lr, weight_decay=0.0,
                             schedule=optim.cosine_schedule(10, steps))
    ostate = optim.init_state(params)

    def loss_fn(p, k, x0, label=None, memory=None):
        if loss_kind == "rf":
            return diffusion.rf_loss(cfg, p, k, x0, label=label, memory=memory)
        return diffusion.eps_loss(cfg, p, k, x0, sched=sched, label=label,
                                  memory=memory)

    @jax.jit
    def step(p, s, k, x0, label, memory):
        l, g = jax.value_and_grad(loss_fn)(p, k, x0, label, memory)
        p, s, _ = optim.apply_updates(ocfg, p, g, s)
        return p, s, l

    losses = []
    for i in range(steps):
        out = data.batch_at(i)
        if isinstance(data, BlobLatents):
            x0, label = out
            memory = None
        else:
            x0, memory = out
            label = None
        params, ostate, l = step(params, ostate,
                                 jax.random.fold_in(key, i), x0, label, memory)
        losses.append(float(l))
    return params, sched, losses
