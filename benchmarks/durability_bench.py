"""Durability benchmark: crash-safe serving under a seeded kill ramp,
and the checkpoint cost of earning it.

Four sections, every contract asserted in-run:

**Virtual kill ramp (deterministic).**  A fake executor implementing the
run-state snapshot seam serves one mixed static/adaptive/eager trace on
a :class:`~repro.serve.request.VirtualClock` while a seeded
:class:`~repro.durable.KillPlan` kills the engine at scheduler-tick
boundaries across ``DURABILITY_BENCH_RATES`` × the kill-seed matrix.
Every incarnation rebuilds over the same write-ahead journal + snapshot
dir and calls ``recover()``.  At every (rate, seed) the bench asserts
**zero lost requests**: offered == finished + shed, and a post-run
journal replay shows nothing pending.  At rate 0 there are no restarts
and goodput is exactly 1.

**Checkpoint overhead.**  The same ``DURABILITY_BENCH_N``-request
(default 256) virtual trace drains with durability off and on.  The on
drain must produce bit-identical results and a bit-equal virtual
makespan (checkpointing never perturbs scheduling), and the traced time
spent writing boundary snapshots must stay under
``DURABILITY_BENCH_MAX_OVERHEAD`` (default 5%) of the drain wall.

**Real restore path.**  The smoke DiT serves a static + fused-adaptive
mix with checkpointing on; the process is killed mid-flight at a
boundary; the restarted engine restores both batches from snapshots and
finishes.  Asserted: every latent bit-identical to an uninterrupted
engine, zero host syncs on the fused path with checkpointing on.

**Real replay path.**  Same setup, but every snapshot is tampered before
recovery: each must be quarantined with a reason, and the replayed-from-
start requests must still land bit-identical to a solo generate of each
request's own key (the row-keys determinism contract).

Writes ``BENCH_durability.json`` (results dir + repo-root mirror).

    PYTHONPATH=src python -m benchmarks.run --only durability
    DURABILITY_BENCH_N=64 PYTHONPATH=src python -m benchmarks.durability_bench
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time

import numpy as np

from benchmarks import common
from repro import serve
from repro.cache.artifact import CacheArtifact
from repro.core import plan as plan_lib
from repro.core import schedule as S
from repro.durable import (JournalState, KillPlan, SnapshotStore, crash,
                           drain_with_kills)

N = int(os.environ.get("DURABILITY_BENCH_N", "256"))
RAMP_N = int(os.environ.get("DURABILITY_BENCH_RAMP_N", "48"))
RATES = [float(r) for r in
         os.environ.get("DURABILITY_BENCH_RATES", "0,0.1,0.3").split(",")]
SEEDS = [int(s) for s in
         os.environ.get("DURABILITY_BENCH_SEEDS", "0,7,1234").split(",")]
EVERY = int(os.environ.get("DURABILITY_BENCH_EVERY", "4"))
MAX_OVERHEAD = float(os.environ.get("DURABILITY_BENCH_MAX_OVERHEAD",
                                    "0.05"))
STEPS = 8
MAX_BATCH = 8
ARRIVAL_GAP = 0.25                    # virtual s between arrivals

REAL_STEPS = int(os.environ.get("DURABILITY_BENCH_REAL_STEPS", "6"))
REAL_REQUESTS = int(os.environ.get("DURABILITY_BENCH_REAL_REQUESTS", "4"))


# ---------------------------------------------------------------------------
# Virtual-clock deployment with the snapshot seam (same fake shape as
# tests/test_durable.py)
# ---------------------------------------------------------------------------

class _Cfg:
    name = "fake-arch"

    def layer_types(self):
        return ("attn", "ffn")


class _Solver:
    name = "ddim"

    def __init__(self, num_steps):
        self.num_steps = num_steps


@dataclasses.dataclass
class _RunState:
    plan: plan_lib.ExecutionPlan
    batch: int
    run_index: int = 0
    x: object = None
    decisions = None

    @property
    def done(self):
        return self.run_index >= len(self.plan.runs)


@dataclasses.dataclass
class _AdaptiveState:
    schedule: object
    batch: int
    step: int = 0
    x: object = None
    decisions: tuple = ()

    @property
    def done(self):
        return self.step >= self.schedule.num_steps


class _FakeExecutor:
    """Virtual-clock fake with export/import — the protocol the real
    SmoothCacheExecutor implements for boundary snapshots."""

    supports_export = True

    def __init__(self, clock, step_cost=1.0):
        self.clock = clock
        self.step_cost = step_cost
        self._programs = set()

    def _charge(self, skip, length):
        computed = sum(1 for sk in skip.values() if not sk)
        self.clock.advance(self.step_cost * length
                           * computed / max(len(skip), 1))

    def start_run(self, params, key, batch, *, plan, schedule=None,
                  label=None, memory=None):
        return _RunState(plan=plan, batch=batch)

    def advance_run(self, params, rs, *, check=False):
        run = rs.plan.runs[rs.run_index]
        self._charge(run.sig.skip, run.length)
        rs = dataclasses.replace(rs, run_index=rs.run_index + 1)
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def start_adaptive_run(self, params, key, batch, *, schedule, tau,
                           proxy_map=None, pool=None, k_max=3, label=None,
                           memory=None):
        return _AdaptiveState(schedule=schedule, batch=batch)

    def advance_adaptive_run(self, params, rs):
        mask = {t: bool(v[rs.step]) for t, v in rs.schedule.skip.items()}
        self._charge(mask, 1)
        skipset = tuple(sorted(t for t, sk in mask.items() if sk))
        rs = dataclasses.replace(rs, step=rs.step + 1,
                                 decisions=rs.decisions + (skipset,))
        if rs.done:
            rs.x = np.arange(rs.batch, dtype=np.float64)[:, None]
        return rs

    def sample(self, params, key, batch, *, schedule=None, label=None,
               memory=None):
        for s in range(schedule.num_steps):
            self._charge({t: bool(v[s])
                          for t, v in schedule.skip.items()}, 1)
        return np.arange(batch, dtype=np.float64)[:, None]

    def compiled_variant_count(self, kind=None):
        return len(self._programs)

    def xla_program_count(self, kind=None):
        return len(self._programs)

    def export_run(self, rs):
        if isinstance(rs, _RunState):
            return "plan", {}, {"batch": rs.batch,
                                "run_index": rs.run_index}
        return "adaptive", {}, {
            "batch": rs.batch, "step": rs.step,
            "decisions": [list(d) for d in rs.decisions]}

    def import_run(self, params, kind, arrays, static, *, plan=None,
                   schedule=None, tau=0.0, proxy_map=None, pool=None,
                   k_max=3):
        if kind == "plan":
            return _RunState(plan=plan, batch=int(static["batch"]),
                             run_index=int(static["run_index"]))
        return _AdaptiveState(
            schedule=schedule, batch=int(static["batch"]),
            step=int(static["step"]),
            decisions=tuple(tuple(d)
                            for d in static.get("decisions", ())))


def _artifact(num_steps, arch="fake-arch", types=("attn", "ffn"),
              k_max=1):
    sch = S.fora(types, num_steps, 2)
    pool = [list(sig.live_in) for sig in plan_lib.mask_lattice(sch)]
    return CacheArtifact(
        arch=arch, solver="ddim", num_steps=num_steps,
        policy={"name": "adaptive", "base": {"name": "static", "n": 2},
                "tau": 0.1, "k_max": k_max},
        curves={}, schedule=sch,
        plan=plan_lib.analyze(sch).to_jsonable(),
        adaptive={"tau": 0.1, "k_max": k_max,
                  "proxy_map": {"coeffs": {t: [0.0, 0.01] for t in types},
                                "mean_proxy": None},
                  "pool": pool},
        meta={})


def _store():
    store = serve.ArtifactStore(_Cfg(), _Solver(STEPS))
    store.add_policy("static2", "static:n=2")
    store.add_policy("no_cache", "none")
    store.add_artifact("adaptive", _artifact(STEPS))
    return store


def _trace(n):
    policies = ("static2", "adaptive", "no_cache")
    return [serve.Request(rid=i, seed=i, policy=policies[i % 3],
                          arrival=ARRIVAL_GAP * i) for i in range(n)]


def _factory(tmpdir, **kw):
    jpath = os.path.join(tmpdir, "journal.jsonl")
    sdir = os.path.join(tmpdir, "snapshots")

    def make():
        clock = serve.VirtualClock()
        return serve.ServeEngine(
            _FakeExecutor(clock), params=None, store=_store(),
            clock=clock, max_batch=MAX_BATCH, journal=jpath,
            snapshot_dir=sdir, checkpoint_every=EVERY, **kw)
    return make, jpath


# ---------------------------------------------------------------------------
# Section 1: kill ramp — zero lost requests at every (rate, seed)
# ---------------------------------------------------------------------------

def _kill_ramp():
    out = {}
    for rate in RATES:
        per_seed = {}
        for seed in SEEDS:
            with tempfile.TemporaryDirectory() as td:
                make, jpath = _factory(td)
                eng0 = make()
                eng0.submit(*_trace(RAMP_N))
                crash(eng0)
                plan = KillPlan(seed=seed, kill_rate=rate, max_kills=25)
                t0 = time.perf_counter()
                report = drain_with_kills(make, plan, max_restarts=100)
                wall = time.perf_counter() - t0
                # the durability contract, asserted at every ramp point:
                # offered == finished + shed — nothing vanishes in a kill
                resolved = (set(report.delivered)
                            | set(report.engine.shed))
                assert resolved == set(range(RAMP_N)), (
                    f"rate={rate} seed={seed}: "
                    f"{RAMP_N - len(resolved)} requests lost")
                st = JournalState.replay(jpath)
                assert st.pending() == {}, "journal still shows pending"
                if rate == 0:
                    assert report.restarts == 0
                    assert len(report.delivered) == RAMP_N
                per_seed[str(seed)] = {
                    "restarts": report.restarts,
                    "ticks": report.ticks,
                    "delivered": len(report.delivered),
                    "shed": len(report.engine.shed),
                    "journal_events": len(st.events),
                    "wall_s": wall,
                }
        agg = sum(v["restarts"] for v in per_seed.values())
        common.emit(f"durability/ramp@{rate:g}", agg * 1e6,
                    f"seeds={len(SEEDS)};restarts={agg};lost=0")
        out[f"{rate:g}"] = per_seed
    return out


# ---------------------------------------------------------------------------
# Section 2: checkpoint overhead on the N-request virtual drain
# ---------------------------------------------------------------------------

class _TimedSnapshots(SnapshotStore):
    """SnapshotStore that accumulates wall time spent writing — the
    traced checkpoint cost, separated from scheduling."""

    def __init__(self, dirpath):
        super().__init__(dirpath)
        self.seconds = 0.0

    def save(self, serial, arrays, meta):
        t0 = time.perf_counter()
        out = super().save(serial, arrays, meta)
        self.seconds += time.perf_counter() - t0
        return out


def _overhead():
    trace = _trace(N)

    clock = serve.VirtualClock()
    eng_off = serve.ServeEngine(_FakeExecutor(clock), params=None,
                                store=_store(), clock=clock,
                                max_batch=MAX_BATCH)
    eng_off.submit(*[dataclasses.replace(r) for r in trace])
    t0 = time.perf_counter()
    eng_off.run_until_drained()
    wall_off = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as td:
        make, _ = _factory(td)
        eng_on = make()
        timed = _TimedSnapshots(os.path.join(td, "snapshots"))
        eng_on._snapshots = timed
        eng_on.submit(*[dataclasses.replace(r) for r in trace])
        t0 = time.perf_counter()
        eng_on.run_until_drained()
        wall_on = time.perf_counter() - t0

    # checkpointing must not change a single scheduling decision or bit
    assert sorted(eng_on.results) == sorted(eng_off.results)
    assert all(np.array_equal(eng_on.results[r], eng_off.results[r])
               for r in eng_on.results)
    assert eng_on.clock.now() == eng_off.clock.now(), (
        "checkpointing perturbed the virtual makespan")
    assert eng_on.metrics.checkpoints > 0
    overhead = timed.seconds / max(wall_on, 1e-9)
    assert overhead < MAX_OVERHEAD, (
        f"checkpoint overhead {overhead:.1%} exceeds "
        f"{MAX_OVERHEAD:.0%} on the {N}-request drain "
        f"(cadence every={EVERY})")
    common.emit("durability/overhead", overhead * 1e6,
                f"ckpt_s={timed.seconds:.4f};wall_s={wall_on:.3f};"
                f"checkpoints={eng_on.metrics.checkpoints};"
                f"bytes={eng_on.metrics.checkpoint_bytes}")
    return {
        "requests": N,
        "checkpoint_every": EVERY,
        "checkpoints": eng_on.metrics.checkpoints,
        "checkpoint_bytes": eng_on.metrics.checkpoint_bytes,
        "checkpoint_s": timed.seconds,
        "wall_on_s": wall_on,
        "wall_off_s": wall_off,
        "overhead_fraction": overhead,
        "max_overhead": MAX_OVERHEAD,
        "results_bit_identical": True,
        "virtual_makespan_equal": True,
    }


# ---------------------------------------------------------------------------
# Sections 3 + 4: real smoke DiT — restore and replay, both bit-identical
# ---------------------------------------------------------------------------

def _small_dit():
    import jax
    from repro import configs
    from repro.core import diffusion
    cfg = configs.get("dit-xl-256", "smoke")
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape),
        params)
    return cfg, params


def _step_until(eng, cond, limit):
    for _ in range(limit):
        if cond():
            return
        assert eng.step(), "engine drained before the kill condition"
    raise AssertionError("kill condition never reached")


def _real_restore(cfg, params):
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    def build(journal=None, snapshot_dir=None):
        ex = SmoothCacheExecutor(cfg, solvers.ddim(REAL_STEPS),
                                 cfg_scale=1.5)
        store = serve.ArtifactStore(cfg, solvers.ddim(REAL_STEPS),
                                    cfg_scale=1.5)
        store.add_policy("static2", "static:n=2")
        store.add_artifact("adaptive", _artifact(
            REAL_STEPS, arch=cfg.name, types=cfg.layer_types(), k_max=2))
        eng = serve.ServeEngine(
            ex, params, store, max_batch=2, max_inflight=2,
            clock=serve.VirtualClock(), check=True, adaptive_chunk=2,
            journal=journal, snapshot_dir=snapshot_dir)
        return eng, ex

    def reqs():
        return [serve.Request(
            rid=i, seed=100 + i,
            policy="adaptive" if i >= REAL_REQUESTS // 2 else "static2",
            label=i % cfg.num_classes, arrival=0.0)
            for i in range(REAL_REQUESTS)]

    base_eng, _ = build()
    base_eng.submit(*reqs())
    base = base_eng.run_until_drained()

    with tempfile.TemporaryDirectory() as td:
        jpath = os.path.join(td, "journal.jsonl")
        sdir = os.path.join(td, "snapshots")
        eng, _ = build(jpath, sdir)
        eng.submit(*reqs())
        _step_until(eng, lambda: len(eng._snapshots.live()) >= 2
                    and all(not fl.rs.done for fl in eng._inflight),
                    limit=8)
        crash(eng)

        eng2, ex2 = build(jpath, sdir)
        t0 = time.perf_counter()
        summary = eng2.recover()
        wall_recover = time.perf_counter() - t0
        assert summary["restored_runs"] >= 1, "nothing restored"
        assert summary["refused"] == []
        res = eng2.run_until_drained()
    assert sorted(res) == sorted(base)
    for rid in base:
        np.testing.assert_array_equal(res[rid], base[rid])
    # the fused adaptive path stays sync-free with checkpointing on
    assert ex2.host_sync_count == 0, (
        f"{ex2.host_sync_count} host syncs with durability enabled")
    common.emit("durability/real_restore", wall_recover * 1e6,
                f"restored_runs={summary['restored_runs']};"
                f"restored={summary['restored_requests']};"
                f"replayed={summary['replayed']};bit_identical=True;"
                f"host_syncs={ex2.host_sync_count}")
    return {
        "steps": REAL_STEPS, "requests": REAL_REQUESTS,
        "restored_runs": summary["restored_runs"],
        "restored_requests": summary["restored_requests"],
        "replayed": summary["replayed"],
        "recover_wall_s": wall_recover,
        "latents_bit_identical": True,
        "host_sync_count": ex2.host_sync_count,
    }


def _real_replay(cfg, params):
    import jax.numpy as jnp
    from repro import cache
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    n = max(2, REAL_REQUESTS // 2)

    def build(jpath, sdir):
        ex = SmoothCacheExecutor(cfg, solvers.ddim(REAL_STEPS),
                                 cfg_scale=1.5)
        store = serve.ArtifactStore(cfg, solvers.ddim(REAL_STEPS),
                                    cfg_scale=1.5)
        store.add_policy("static2", "static:n=2")
        return serve.ServeEngine(
            ex, params, store, max_batch=2, max_inflight=1,
            clock=serve.VirtualClock(), check=True, continuous=True,
            journal=jpath, snapshot_dir=sdir)

    with tempfile.TemporaryDirectory() as td:
        jpath = os.path.join(td, "journal.jsonl")
        sdir = os.path.join(td, "snapshots")
        eng = build(jpath, sdir)
        eng.submit(*[serve.Request(rid=i, seed=100 + i, policy="static2",
                                   label=i % cfg.num_classes, arrival=0.0)
                     for i in range(n)])
        _step_until(eng, lambda: bool(os.listdir(sdir))
                    and eng._inflight and not eng._inflight[0].rs.done,
                    limit=8)
        crash(eng)
        for name in os.listdir(sdir):         # tamper every snapshot
            p = os.path.join(sdir, name)
            raw = open(p, "rb").read()
            with open(p, "wb") as f:
                f.write(raw[:-1] + bytes([raw[-1] ^ 0xFF]))

        eng2 = build(jpath, sdir)
        summary = eng2.recover()
        assert summary["restored_runs"] == 0
        assert len(summary["refused"]) >= 1, "tampering went unnoticed"
        for qname, reason in summary["refused"]:
            assert eng2.store.health.quarantine_reason(
                f"snapshot:{qname}") == reason
        assert summary["replayed"] == n
        res = eng2.run_until_drained()
    assert sorted(res) == list(range(n))

    # replay-from-start lands on the row-keys contract: each latent is a
    # bit-identical solo generate of the request's own key
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(REAL_STEPS),
                                   "static:n=2", cfg_scale=1.5)
    pipe.prepare()
    for i in range(n):
        x = pipe.generate(params, serve.batch_key([100 + i]), 1,
                          label=jnp.asarray([i % cfg.num_classes],
                                            jnp.int32))
        np.testing.assert_array_equal(np.asarray(x[0]), res[i])
    common.emit("durability/real_replay", len(summary["refused"]) * 1e6,
                f"quarantined={len(summary['refused'])};replayed={n};"
                "bit_identical=True")
    return {
        "requests": n,
        "quarantined": len(summary["refused"]),
        "replayed": summary["replayed"],
        "latents_bit_identical": True,
    }


def run() -> None:
    ramp = _kill_ramp()
    overhead = _overhead()
    cfg, params = _small_dit()
    restore = _real_restore(cfg, params)
    replay = _real_replay(cfg, params)
    path = common.write_bench_json("BENCH_durability.json", {
        "meta": {"ramp_requests": RAMP_N, "overhead_requests": N,
                 "kill_rates": RATES, "seeds": SEEDS,
                 "checkpoint_every": EVERY,
                 "max_overhead": MAX_OVERHEAD,
                 "virtual_steps": STEPS, "max_batch": MAX_BATCH,
                 "real_steps": REAL_STEPS,
                 "real_requests": REAL_REQUESTS},
        "kill_ramp": ramp,
        "checkpoint_overhead": overhead,
        "real_restore": restore,
        "real_replay": replay,
    })
    print(f"# wrote {path}")


if __name__ == "__main__":
    run()
