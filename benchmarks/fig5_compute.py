"""Paper Fig. 5 — layer compute composition (MACs of the default model
configs, no SmoothCache).  Validates the claim that SmoothCache-eligible
layers comprise ≥90% of generation compute in all candidate models."""
from __future__ import annotations

from benchmarks import common
from repro import configs
from repro.utils import flops

SETUPS = [
    ("dit-xl-256", 256, None),
    ("opensora-v12", 16 * 256, (16, 256)),
    ("stable-audio-open", 216, None),
]


def run():
    for arch, ntok, video in SETUPS:
        cfg = configs.get(arch)
        per = flops.model_macs_by_type(cfg, ntok, video_shape=video)
        other = flops.non_block_macs(cfg, ntok)
        total = sum(per.values()) + other
        eligible = sum(per.values()) / total
        comp = ";".join(f"{k}={v/total*100:.1f}%" for k, v in sorted(per.items()))
        common.emit(f"fig5/{arch}", 0.0,
                    f"eligible={eligible*100:.1f}%;{comp}")
        assert eligible > 0.9, f"{arch}: paper claims >=90%, got {eligible}"


if __name__ == "__main__":
    run()
