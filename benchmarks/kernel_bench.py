"""Kernel micro-benchmarks: jnp reference path timings on CPU (the Pallas
kernels are TPU targets validated in interpret mode — interpret execution
is Python-speed, so wall-clock here times the XLA reference path) plus
derived TPU-roofline estimates for the kernel shapes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels.ref import flash_attention_ref, ssd_ref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def run():
    key = jax.random.PRNGKey(0)
    # attention shapes: (B, L, H, KV, D) — DiT-XL block & a GQA LM block
    for name, (b, l, h, kv, d) in [
        ("dit_xl_attn", (2, 256, 16, 16, 72)),
        ("gqa_4k", (1, 4096, 8, 2, 128)),
    ]:
        ks = jax.random.split(jax.random.fold_in(key, l), 3)
        q = jax.random.normal(ks[0], (b, l, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, l, kv, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, l, kv, d), jnp.float32)
        f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
        us = common.time_call(f, q, k, v)
        flops = 4.0 * b * h * l * l * d
        tpu_us = flops / PEAK_FLOPS_BF16 * 1e6
        common.emit(f"kernels/{name}", us,
                    f"flops={flops:.3g};tpu_compute_bound_us={tpu_us:.1f}")

    # SSD shape: mamba2-1.3b block
    b, l, h, p, g, n = 1, 1024, 64, 64, 1, 128
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = jnp.exp(jax.random.uniform(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, l, g, n))
    cc = jax.random.normal(ks[4], (b, l, g, n))
    f = jax.jit(lambda *args: ssd_ref(*args, chunk=128)[0])
    us = common.time_call(f, x, dt, a, bb, cc)
    flops = 2 * b * l * 128 * h * (n + p) + 4 * b * l * h * p * n
    common.emit("kernels/ssd_mamba2", us, f"flops={flops:.3g}")


if __name__ == "__main__":
    run()
