"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage:

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig5]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("fig5", "benchmarks.fig5_compute"),        # fast, analytic
    ("fig2", "benchmarks.fig2_error_curves"),
    ("table1", "benchmarks.table1_dit"),
    ("executor", "benchmarks.executor_bench"),
    ("adaptive", "benchmarks.adaptive_bench"),
    ("serve", "benchmarks.serve_bench"),
    ("slo", "benchmarks.slo_bench"),
    ("resilience", "benchmarks.resilience_bench"),
    ("continuous", "benchmarks.continuous_bench"),
    ("obs", "benchmarks.obs_bench"),
    ("durability", "benchmarks.durability_bench"),
    ("table2", "benchmarks.table2_video"),
    ("table3", "benchmarks.table3_audio"),
    ("kernels", "benchmarks.kernel_bench"),
    ("ablation", "benchmarks.ablation_calibration"),
    ("beyond_ar", "benchmarks.beyond_ar_cache"),
    ("roofline", "benchmarks.roofline_table"),
]
# benchmarks.beyond_mesh_cache needs 512 placeholder devices — run it
# standalone: PYTHONPATH=src python -m benchmarks.beyond_mesh_cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(k for k, _ in MODULES))
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run()
            print(f"{key}/_elapsed,{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # keep the harness going; report at the end
            failures.append((key, e))
            traceback.print_exc()
            print(f"{key}/_elapsed,{(time.time()-t0)*1e6:.0f},FAIL:{type(e).__name__}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
