"""Paper Table 3 — Stable Audio Open, DPM-Solver++(3M) SDE, 100 steps.

TMACs ratios on the full config (paper: α=0.15 → 170.75/209.82 = 0.814;
α=0.30 → 136.16/209.82 = 0.649) + e2e speedup and spectro-proxy quality
(Fréchet on latent features vs the data distribution) on a small trained
audio DiT.  Caching is driven by `repro.cache` policies resolved against
one calibration artifact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import cache, configs
from repro.core import solvers
from repro.data import CondLatents
from repro.utils import flops

PAPER = [("a0.15", 0.814), ("a0.30", 0.649)]


def run():
    full = configs.get("stable-audio-open")
    steps = 100
    ntok = full.latent_shape[0]

    cfg = configs.get("stable-audio-open", "smoke")
    key = jax.random.PRNGKey(0)
    data = CondLatents(cfg.latent_shape, cfg.cond_dim, 8, 8)
    params, _, _ = common.train_small_dit(cfg, key, steps=100, data=data)
    pipe = cache.DiffusionPipeline(cfg, solvers.dpmpp_3m_sde(steps),
                                   "smoothcache:alpha=0.15", cfg_scale=7.0)
    x0, memory = data.batch_at(0)
    artifact = pipe.calibrate(params, jax.random.PRNGKey(1), 8,
                              cond_args={"memory": memory})
    assert set(artifact.curves) == {"attn", "xattn", "ffn"}

    base = flops.sampler_tmacs(full, pipe.schedule_for("none"), ntok, 1,
                               cfg_scale=7.0)
    common.emit("table3/no_cache/tmacs", 0.0, f"tmacs={base:.1f};paper=209.82_unit_note")
    for name, paper_ratio in PAPER:
        sch = pipe.schedule_for(f"budget:target={paper_ratio}")
        t = flops.sampler_tmacs(full, sch, ntok, 1, cfg_scale=7.0)
        common.emit(f"table3/smoothcache_{name}/tmacs", 0.0,
                    f"tmacs={t:.1f};ratio={t/base:.3f};paper_ratio={paper_ratio:.3f}")

    def sample_with(schedule):
        return pipe.generate(params, jax.random.PRNGKey(2), 8,
                             schedule=schedule, memory=memory)

    ref = sample_with(None)
    t_base = common.time_call(lambda: sample_with(None), iters=2)
    fd0 = common.frechet_distance(np.asarray(ref), np.asarray(x0))
    common.emit("table3/no_cache/e2e", t_base, f"frechet={fd0:.4f}")
    for alpha in (0.15, 0.30):
        sch = pipe.schedule_for(f"smoothcache:alpha={alpha}")
        x = sample_with(sch)
        t = common.time_call(lambda: sample_with(sch), iters=2)
        fd = common.frechet_distance(np.asarray(x), np.asarray(x0))
        frac = np.mean([sch.compute_fraction(ty) for ty in sch.skip])
        common.emit(f"table3/smoothcache_a{alpha}/e2e", t,
                    f"frechet={fd:.4f};speedup={t_base/t:.2f};compute_frac={frac:.3f}")


if __name__ == "__main__":
    run()
