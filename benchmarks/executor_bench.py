"""Executor-path benchmark: eager vs segmented vs monolith.

Runs the paper's DiT-XL protocol (smoke config, DDIM, CFG 1.5) under a
calibrated SmoothCache schedule through all three execution paths and
reports, per path: programs compiled, compile wall time (first call),
steady-state per-sample wall time, total (compile + one sample) time, and
the peak resident branch-cache bytes (liveness-pruned for the segmented
path, full-structure for eager/monolith).

Emits CSV rows and writes ``BENCH_executor.json`` into the results dir so
CI can track the perf trajectory per PR.

    PYTHONPATH=src python -m benchmarks.run --only executor
    EXECUTOR_BENCH_STEPS=20 PYTHONPATH=src python -m benchmarks.executor_bench
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import configs
from repro.core import calibration, diffusion, plan as plan_lib
from repro.core import schedule as S, solvers
from repro.core.executor import SmoothCacheExecutor

STEPS = int(os.environ.get("EXECUTOR_BENCH_STEPS", "50"))
BATCH = 1
CFG_SCALE = 1.5
SAMPLE_ITERS = 3


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


def _bench_path(sample_fn):
    """(first-call seconds, median steady seconds, first output)."""
    x0, t_first = _timed(sample_fn)
    steady = []
    for _ in range(SAMPLE_ITERS):
        _, dt = _timed(sample_fn)
        steady.append(dt)
    return t_first, float(np.median(steady)), x0


def run() -> None:
    cfg = configs.get("dit-xl-256", "smoke")
    solver = solvers.ddim(STEPS)
    params = diffusion.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(
        lambda a: a + 0.05 * jax.random.normal(jax.random.PRNGKey(7), a.shape),
        params)
    label = jnp.zeros((BATCH,), jnp.int32)
    key = jax.random.PRNGKey(42)

    # calibrate a SmoothCache schedule targeting ~50% layer compute
    ex_cal = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
    curves, _, _ = calibration.calibrate(
        ex_cal, params, jax.random.PRNGKey(1), BATCH,
        cond_args={"label": label})
    alpha = S.alpha_for_budget(curves, target_compute_fraction=0.5)
    sch = S.smoothcache(curves, alpha, k_max=3)
    if not any(v.any() for v in sch.skip.values()):
        sch = S.fora(cfg.layer_types(), STEPS, 2)     # degenerate calibration
    plan = plan_lib.analyze(sch)
    type_bytes = plan_lib.branch_cache_type_bytes(cfg, BATCH,
                                                  cfg_doubled=True)
    full_bytes = sum(type_bytes.values())

    paths = {}

    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
    t_first, t_steady, x_eager = _bench_path(
        lambda: ex.sample(params, key, BATCH, schedule=sch, label=label))
    paths["eager"] = {
        "programs": ex.compiled_variant_count("eager"),
        "compile_s": t_first - t_steady, "sample_s": t_steady,
        "total_s": t_first, "peak_live_cache_bytes": full_bytes}

    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
    t_first, t_steady, x_seg = _bench_path(
        lambda: ex.sample_compiled(params, key, BATCH, schedule=sch,
                                   label=label))
    paths["segmented"] = {
        "programs": ex.compiled_variant_count("seg"),
        "compile_s": t_first - t_steady, "sample_s": t_steady,
        "total_s": t_first,
        "peak_live_cache_bytes": plan.peak_live_bytes(type_bytes)}

    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=CFG_SCALE)
    mono = jax.jit(ex.build_sampler_fn(sch))

    def run_mono():
        knoise, kloop = jax.random.split(key)
        x = jax.random.normal(knoise, ex.latent_batch_shape(BATCH))
        return mono(params, x, label, None, None)

    t_first, t_steady, x_mono = _bench_path(run_mono)
    paths["monolith"] = {
        "programs": 1,
        "compile_s": t_first - t_steady, "sample_s": t_steady,
        "total_s": t_first, "peak_live_cache_bytes": full_bytes}

    bitwise = bool(jnp.all(x_eager == x_seg))
    result = {
        "config": cfg.name, "solver": solver.name, "steps": STEPS,
        "batch": BATCH, "cfg_scale": CFG_SCALE,
        "schedule": {"name": sch.name, "alpha": sch.alpha,
                     "compute_fraction": float(np.mean(
                         [sch.compute_fraction(t) for t in sch.skip]))},
        "plan": {"segments": len(plan.runs),
                 "unique_signatures": plan.num_unique_signatures},
        "segmented_bitwise_equals_eager": bitwise,
        "paths": paths,
    }
    common.write_bench_json("BENCH_executor.json", result)

    for name, p in paths.items():
        common.emit(f"executor/{name}_sample", p["sample_s"] * 1e6,
                    f"programs={p['programs']}"
                    f";compile_s={p['compile_s']:.2f}"
                    f";total_s={p['total_s']:.2f}"
                    f";peak_cache_MB={p['peak_live_cache_bytes'] / 1e6:.1f}")
    common.emit("executor/plan", plan.num_unique_signatures,
                f"segments={len(plan.runs)};steps={STEPS}"
                f";bitwise={bitwise}")


if __name__ == "__main__":
    run()
