"""§Beyond — SmoothCache on the production mesh.

The paper measures single-GPU latency.  Under tensor parallelism a cache
hit removes not only the layer's FLOPs but also its collectives (the
row-parallel all-reduces of attn/FFN outputs) — the cache pytree inherits
the activation sharding, so reuse costs zero ICI traffic.  This benchmark
lowers the FULL DiT-XL/2 sampler on the 16×16 TPU-v5e mesh with and
without caching and reports compiled FLOPs + ICI-byte reductions next to
the schedule's compute fraction.

Run separately (needs 512 placeholder devices, so not part of the default
CPU bench run):  PYTHONPATH=src python -m benchmarks.beyond_mesh_cache
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs, shardctx                       # noqa: E402
from repro.core import diffusion, schedule as S, solvers  # noqa: E402
from repro.core.executor import SmoothCacheExecutor       # noqa: E402
from repro.launch import hlo_analysis, sharding           # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402

STEPS = 8            # accounting window; ratios are step-count invariant
BATCH = 64


def lower_sampler(cfg, mesh, schedule):
    solver = solvers.ddim(STEPS)
    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5, jit=False)
    fn = ex.build_sampler_fn(schedule)
    p_struct = jax.eval_shape(
        lambda: diffusion.init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    p_specs = sharding.to_named(mesh, sharding.param_specs(mesh, p_struct, cfg))
    x_struct = jax.ShapeDtypeStruct((BATCH,) + tuple(cfg.latent_shape),
                                    jnp.float32)
    lab_struct = jax.ShapeDtypeStruct((BATCH,), jnp.int32)
    bsh = sharding.to_named(mesh, sharding.batch_spec(mesh, BATCH,
                                                      len(cfg.latent_shape)))
    lsh = sharding.to_named(mesh, sharding.batch_spec(mesh, BATCH, 0))
    jfn = jax.jit(fn, in_shardings=(p_specs, bsh, lsh))
    with shardctx.use(mesh):
        compiled = jfn.lower(p_struct, x_struct, lab_struct).compile()
    return hlo_analysis.analyze(compiled.as_text())


def run():
    cfg = configs.get("dit-xl-256").replace(dtype="bfloat16")
    mesh = make_production_mesh()
    types = cfg.layer_types()

    # SmoothCache-shaped schedule (attn/ffn skipped on different steps,
    # the Eq.-4 pattern) + FORA + no-cache
    sc = S.Schedule({
        "attn": np.array([0, 1, 1, 0, 1, 1, 0, 1], bool),
        "ffn":  np.array([0, 1, 0, 1, 1, 0, 1, 1], bool)}, STEPS,
        alpha=0.18, name="smoothcache_like")
    rows = {}
    for name, sch in [("no_cache", S.no_cache(types, STEPS)),
                      ("fora_n2", S.fora(types, STEPS, 2)),
                      ("smoothcache", sc)]:
        t = lower_sampler(cfg, mesh, sch)
        frac = np.mean([sch.compute_fraction(ty) for ty in sch.skip])
        rows[name] = (t, frac)
        print(f"{name},0.0,flops_per_chip={t.flops:.4g};"
              f"coll_bytes={t.coll.get('total', 0):.4g};compute_frac={frac:.3f}")
    base = rows["no_cache"][0]
    for name in ("fora_n2", "smoothcache"):
        t, frac = rows[name]
        print(f"beyond/{name}/reduction,0.0,"
              f"flops_ratio={t.flops/base.flops:.3f};"
              f"coll_ratio={t.coll.get('total',1)/max(base.coll.get('total',1),1):.3f};"
              f"compute_frac={frac:.3f}")


if __name__ == "__main__":
    run()
