"""§Roofline — assemble the per-(arch × shape × mesh) roofline table from
the dry-run JSON results (results/dryrun/*.json)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common
from repro.launch.roofline import fmt_seconds


def load_records(pattern: str = "results/dryrun/*.json"):
    recs = []
    for path in sorted(glob.glob(pattern)):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs) -> str:
    hdr = ("| arch | shape | mesh | t_compute | t_memory | t_collective | "
           "bottleneck | MODEL/HLO | peak GB/chip |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"FAIL: {r.get('error','')[:60]} | | | | | |")
            continue
        rf = r["roofline"]
        peak = (r.get("memory") or {}).get("peak_bytes")
        peak_s = f"{peak/1e9:.1f}" if peak else "?"
        ratio = rf.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_seconds(rf['t_compute'])} | {fmt_seconds(rf['t_memory'])} "
            f"| {fmt_seconds(rf['t_collective'])} | {rf['bottleneck']} "
            f"| {ratio:.2f} | {peak_s} |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ? | ? | ? | ? | ? | {peak_s} |")
    return "\n".join(rows)


def run():
    recs = load_records()
    ok = [r for r in recs if r.get("ok")]
    fail = [r for r in recs if not r.get("ok")]
    common.emit("roofline/combos_ok", 0.0, f"count={len(ok)};fail={len(fail)}")
    for r in ok:
        rf = r["roofline"]
        common.emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
            f"tc={rf['t_compute']:.4g};tm={rf['t_memory']:.4g};"
            f"tcoll={rf['t_collective']:.4g};bn={rf['bottleneck']};"
            f"useful={rf.get('useful_flops_ratio') or 0:.3f}")
    os.makedirs("results", exist_ok=True)
    with open("results/roofline_table.md", "w") as f:
        f.write(markdown_table(recs) + "\n")


if __name__ == "__main__":
    run()
