"""Paper Table 1 — DiT-XL/2-256×256, DDIM: SmoothCache vs FORA vs No-Cache.

Reproduces the TMACs column analytically on the FULL DiT-XL config (our
MACs calculator matches the DiT paper's 118.6 G/forward exactly) and
validates the paper's headline ratios:

    α=0.08 → 0.920× No-Cache   (336.37/365.59)
    α=0.18 → 0.480×            (175.65/365.59, ≈ FORA n=2 with fewer MACs)
    α=0.22 → 0.361×            (131.81/365.59, = FORA n=3 TMACs)

Quality + wall-time speedup are measured end-to-end on a small DiT trained
on synthetic class-conditional latents (no ImageNet weights offline):
Fréchet-proxy of cached vs uncached samples at matched compute.

All caching flows through the `repro.cache` policy API: one
`DiffusionPipeline.calibrate` pass, then every Table-1 row is a
`CachePolicy` resolved against the same calibration artifact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import cache, configs
from repro.core import solvers
from repro.data import BlobLatents
from repro.utils import flops

PAPER_ROWS_50 = [
    # (name, policy spec, paper TMACs, paper ratio to No-Cache)
    ("no_cache", "none", 365.59, 1.000),
    ("smoothcache_a0.08", None, 336.37, 0.920),
    ("fora_n2", "static:n=2", 190.25, 0.520),
    ("smoothcache_a0.18", None, 175.65, 0.480),
    ("fora_n3", "static:n=3", 131.81, 0.361),
    ("smoothcache_a0.22", None, 131.81, 0.361),
]


def full_config_tmacs(pipe: cache.DiffusionPipeline):
    """Analytic TMACs of each Table-1 schedule on the full DiT-XL config."""
    cfg = configs.get("dit-xl-256")
    n_tok = 256
    rows = []
    base_sch = pipe.schedule_for("none")
    base = flops.sampler_tmacs(cfg, base_sch, n_tok, 1, cfg_scale=1.5)
    for name, spec, paper_tmacs, paper_ratio in PAPER_ROWS_50:
        if spec is None:
            # paper α values are on DiT-XL's own error curves; we target the
            # paper's compute fraction via the α search on OUR curves, which
            # validates Eq. 4 + the MACs accounting end to end
            spec = f"budget:target={paper_ratio}"
        sch = pipe.schedule_for(spec)
        t = flops.sampler_tmacs(cfg, sch, n_tok, 1, cfg_scale=1.5)
        rows.append((name, t, t / base, paper_ratio))
    return rows


def run():
    cfg = configs.get("dit-xl-256", "smoke")
    key = jax.random.PRNGKey(0)
    params, sched, losses = common.train_small_dit(cfg, key, steps=120)
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(50),
                                   "smoothcache:alpha=0.18", cfg_scale=1.5)
    nclass = max(cfg.num_classes, 1)
    label = jnp.arange(8) % nclass

    pipe.calibrate(params, jax.random.PRNGKey(1), 8,
                   cond_args={"label": label})
    # --- TMACs ratios on the FULL config ---
    for name, t, ratio, paper in full_config_tmacs(pipe):
        common.emit(f"table1/{name}/tmacs", 0.0,
                    f"tmacs={t:.2f};ratio={ratio:.3f};paper_ratio={paper:.3f}")

    # --- measured speedup + quality proxy on the trained small model ---
    data = BlobLatents(cfg.latent_shape, nclass, 64, seed=99)
    ref_x0, ref_label = data.batch_at(0)

    def sample_with(schedule):
        return pipe.generate(params, jax.random.PRNGKey(2), 64,
                             schedule=schedule, label=ref_label)

    base = sample_with(None)
    t_base = common.time_call(lambda: sample_with(None), iters=2)
    fd_base = common.frechet_distance(np.asarray(base), np.asarray(ref_x0))
    common.emit("table1/no_cache/e2e", t_base, f"frechet={fd_base:.4f}")

    for alpha in (0.08, 0.18, 0.35):
        sch = pipe.schedule_for(f"smoothcache:alpha={alpha}")
        x = sample_with(sch)
        t = common.time_call(lambda: sample_with(sch), iters=2)
        fd = common.frechet_distance(np.asarray(x), np.asarray(ref_x0))
        frac = np.mean([sch.compute_fraction(ty) for ty in sch.skip])
        common.emit(f"table1/smoothcache_a{alpha}/e2e", t,
                    f"frechet={fd:.4f};speedup={t_base/t:.2f};compute_frac={frac:.3f}")
    for n in (2, 3):
        sch = pipe.schedule_for(f"static:n={n}")
        x = sample_with(sch)
        t = common.time_call(lambda: sample_with(sch), iters=2)
        fd = common.frechet_distance(np.asarray(x), np.asarray(ref_x0))
        common.emit(f"table1/fora_n{n}/e2e", t,
                    f"frechet={fd:.4f};speedup={t_base/t:.2f}")


if __name__ == "__main__":
    run()
