"""Mixture-of-Experts FFN.

Two dispatch strategies:

* ``gshard`` (default) — GShard/GSPMD-canonical one-hot einsum dispatch with
  per-group expert capacity and token dropping.  Tokens are processed in
  groups of ``group_size`` so the (group, tokens, experts, capacity) dispatch
  tensor stays small; under the production mesh the group dim shards over
  (``pod``, ``data``) and the expert dim over ``model``, which GSPMD lowers
  to the classic all-to-all schedule.
* ``dense`` — every expert computes every token (exact, no dropping); used as
  the oracle in tests and for tiny smoke configs.

DeepSeek-V3-style details supported: sigmoid router with top-k renorm and
routed scaling factor, shared experts, Switch-style load-balance aux loss.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro import shardctx
from repro.config import MoESpec
from repro.models import layers as L


def init(key, spec: MoESpec, d_model: int, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    e, f = spec.num_experts, spec.d_ff
    std = 1.0 / math.sqrt(d_model)

    def ew(k, shape, fan_in):
        w = jax.random.truncated_normal(k, -2.0, 2.0, shape, jnp.float32) / math.sqrt(fan_in)
        return w.astype(dtype)

    p = {
        "router": L.dense_init(ks[0], d_model, e, jnp.float32),  # router in fp32
        "w_up": ew(ks[1], (e, d_model, f), d_model),
        "w_down": ew(ks[2], (e, f, d_model), f),
    }
    if spec.gated:
        p["w_gate"] = ew(ks[3], (e, d_model, f), d_model)
    if spec.router == "sigmoid":
        p["router_bias"] = jnp.zeros((e,), jnp.float32)  # dsv3 aux-free bias
    if spec.num_shared:
        fs = spec.d_ff_shared or spec.d_ff * spec.num_shared
        p["shared"] = {
            "w_up": L.dense_init(ks[4], d_model, fs, dtype),
            "w_down": L.dense_init(ks[5], fs, d_model, dtype),
        }
        if spec.gated:
            p["shared"]["w_gate"] = L.dense_init(ks[6], d_model, fs, dtype)
    return p


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------

def route(spec: MoESpec, params, x):
    """x: (..., d) → (weights (..., k), idx (..., k), probs (..., E))."""
    logits = x.astype(jnp.float32) @ params["router"]
    if spec.router == "sigmoid":
        probs = jax.nn.sigmoid(logits)
        sel = probs + params["router_bias"]          # bias affects selection only
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        sel = probs
    top_vals, top_idx = jax.lax.top_k(sel, spec.top_k)
    # weights come from probs at the selected experts (dsv3: bias-free weights)
    w = jnp.take_along_axis(probs, top_idx, axis=-1)
    if spec.norm_topk:
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-20)
    w = w * spec.router_scale
    return w, top_idx, probs


def load_balance_loss(spec: MoESpec, probs, top_idx):
    """Switch-Transformer aux loss: E · Σ_e f_e · P_e."""
    e = spec.num_experts
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)       # (..., k, E)
    f = jnp.mean(jnp.sum(onehot, axis=-2).reshape(-1, e), axis=0) / spec.top_k
    p = jnp.mean(probs.reshape(-1, e), axis=0)
    return e * jnp.sum(f * p)


# ---------------------------------------------------------------------------
# Expert FFN application (batched over expert dim)
# ---------------------------------------------------------------------------

def _expert_ffn(spec: MoESpec, params, xe):
    """xe: (..., E, C, d) → (..., E, C, d); expert dim batched einsum."""
    act = L.activation(spec.activation)
    up = jnp.einsum("...ecd,edf->...ecf", xe, params["w_up"])
    if spec.gated:
        up = act(jnp.einsum("...ecd,edf->...ecf", xe, params["w_gate"])) * up
    else:
        up = act(up)
    return jnp.einsum("...ecf,efd->...ecd", up, params["w_down"])


def _shared_ffn(spec: MoESpec, params, x):
    act = L.activation(spec.activation)
    sp = params["shared"]
    up = x @ sp["w_up"]
    if spec.gated:
        up = act(x @ sp["w_gate"]) * up
    else:
        up = act(up)
    return up @ sp["w_down"]


# ---------------------------------------------------------------------------
# Dispatch strategies
# ---------------------------------------------------------------------------

def apply_dense(spec: MoESpec, params, x):
    """Oracle: all experts on all tokens, top-k combined. (B, L, d)."""
    w, idx, probs = route(spec, params, x)
    mask = jax.nn.one_hot(idx, spec.num_experts, dtype=x.dtype)  # (...,k,E)
    comb = jnp.einsum("...ke,...k->...e", mask, w.astype(x.dtype))
    xe = jnp.broadcast_to(x[..., None, None, :],
                          x.shape[:-1] + (spec.num_experts, 1, x.shape[-1]))
    ye = _expert_ffn(spec, params, xe)[..., 0, :]                # (...,E,d)
    out = jnp.einsum("...ed,...e->...d", ye, comb)
    if spec.num_shared:
        out = out + _shared_ffn(spec, params, x)
    aux = load_balance_loss(spec, probs, idx)
    return out, aux


def capacity(spec: MoESpec, group_tokens: int) -> int:
    cf = spec.capacity_factor or 1.25
    c = int(math.ceil(group_tokens * spec.top_k * cf / spec.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 (lane-friendly)


def apply_gshard(spec: MoESpec, params, x, group_size: int = 2048):
    """Capacity-based one-hot einsum dispatch. x: (B, L, d)."""
    b, l, d = x.shape
    t = b * l
    g_sz = min(group_size, t)
    assert t % g_sz == 0, f"tokens {t} not divisible by group size {g_sz}"
    g = t // g_sz
    xg = x.reshape(g, g_sz, d)
    w, idx, probs = route(spec, params, xg)                       # (g,t,k)
    c = capacity(spec, g_sz)
    e = spec.num_experts

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)            # (g,t,k,E)
    # position of each (token, slot) within its expert queue, in (t, k) order
    flat = onehot.reshape(g, g_sz * spec.top_k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(g, g_sz, spec.top_k, e)
    pos = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)        # (g,t,k)
    keep = (pos < c).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos, c, dtype=jnp.float32) * keep[..., None]
    # dispatch (g,t,E,C) / combine with routing weights
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot, pos_oh)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, pos_oh, w.astype(jnp.float32))

    # pin the all-to-all layout: token groups stay on the batch axes while
    # the expert dim lives on the model axis (GShard schedule)
    xg = shardctx.constrain(xg, "batch", None, None)
    dispatch = shardctx.constrain(dispatch, "batch", None, "model", None)
    combine = shardctx.constrain(combine, "batch", None, "model", None)
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)
    xe = shardctx.constrain(xe, "batch", "model", None, None)
    ye = _expert_ffn(spec, params, xe)                            # (g,E,C,d)
    ye = shardctx.constrain(ye, "batch", "model", None, None)
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    out = out.reshape(b, l, d)
    if spec.num_shared:
        out = out + _shared_ffn(spec, params, x)
    aux = load_balance_loss(spec, probs, idx)
    return out, aux


def apply(spec: MoESpec, params, x, *, strategy: str = "gshard",
          group_size: int = 2048):
    if strategy == "dense":
        return apply_dense(spec, params, x)
    return apply_gshard(spec, params, x, group_size=group_size)
