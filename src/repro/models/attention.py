"""Attention mixers: GQA (with RoPE / sliding-window / softcap / qk-norm /
bias / cross-attention) and MLA (DeepSeek latent-KV attention) with an
absorbed-matmul decode path.

All functions are pure; params are plain dict pytrees.  Three execution
modes share one implementation:

  * ``full``    — (B, L, D) self-attention over the whole sequence
                  (training / prefill; prefill additionally returns a cache)
  * ``decode``  — (B, 1, D) one new token against a fixed-size KV cache

Caches are functional: ``(out, new_cache) = attend(...)``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro import shardctx
from repro.config import AttentionSpec
from repro.models import layers as L

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(key, spec: AttentionSpec, d_model: int, dtype=jnp.float32, cond_dim: int = 0):
    ks = jax.random.split(key, 8)
    p = {}
    if spec.kind == "mla":
        qr = spec.q_lora_rank
        h = spec.num_heads
        qd = h * (spec.nope_head_dim + spec.rope_head_dim)
        if qr:
            p["wq_a"] = L.dense_init(ks[0], d_model, qr, dtype)
            p["q_norm"] = L.rmsnorm_init(qr, dtype)
            p["wq_b"] = L.dense_init(ks[1], qr, qd, dtype)
        else:
            p["wq"] = L.dense_init(ks[0], d_model, qd, dtype)
        p["wkv_a"] = L.dense_init(ks[2], d_model, spec.kv_lora_rank + spec.rope_head_dim, dtype)
        p["kv_norm"] = L.rmsnorm_init(spec.kv_lora_rank, dtype)
        p["wkv_b"] = L.dense_init(
            ks[3], spec.kv_lora_rank, h * (spec.nope_head_dim + spec.v_head_dim), dtype)
        p["wo"] = L.dense_init(ks[4], h * spec.v_head_dim, d_model, dtype)
        return p
    # --- GQA ---
    h, kv, dh = spec.num_heads, spec.num_kv_heads, spec.head_dim
    kv_in = cond_dim if (spec.cross and cond_dim) else d_model
    p["wq"] = L.dense_init(ks[0], d_model, h * dh, dtype)
    p["wk"] = L.dense_init(ks[1], kv_in, kv * dh, dtype)
    p["wv"] = L.dense_init(ks[2], kv_in, kv * dh, dtype)
    p["wo"] = L.dense_init(ks[3], h * dh, d_model, dtype)
    if spec.qkv_bias:
        p["bq"] = L.zeros((h * dh,), dtype)
        p["bk"] = L.zeros((kv * dh,), dtype)
        p["bv"] = L.zeros((kv * dh,), dtype)
    if spec.qk_norm:
        p["q_norm"] = L.rmsnorm_init(dh, dtype)
        p["k_norm"] = L.rmsnorm_init(dh, dtype)
    return p


def init_cache(spec: AttentionSpec, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """Decode-time KV cache for one layer."""
    if spec.cross:
        return None  # cross-attn memory is static; no growing cache
    if spec.kind == "mla":
        return {
            "ckv": jnp.zeros((batch, cache_len, spec.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, cache_len, spec.rope_head_dim), dtype),
        }
    kv, dh = spec.num_kv_heads, spec.head_dim
    # decode-GEMM layouts (§Perf-3): k is (B, KV, dh, S) and v is
    # (B, KV, S, dh) so the per-step score/AV dots read the cache directly
    # instead of materializing transposed copies every token
    return {
        "k": jnp.zeros((batch, kv, dh, cache_len), dtype),
        "v": jnp.zeros((batch, kv, cache_len, dh), dtype),
    }


# ---------------------------------------------------------------------------
# Masking helpers
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int], k_valid=None):
    """Additive bias (..., Lq, Lk) in fp32. Entries violating causality /
    window / validity get NEG_INF."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    if k_valid is not None:
        ok &= k_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias, *, softcap: Optional[float], scale: float):
    """q: (B,Lq,H,dh) k/v: (B,Lk,KV,dh); GQA attention; fp32 softmax.

    Score-matrix sharding (§Perf-2): the grouped (B,KV,G,Lq,Lk) layout is
    only used when KV divides the model axis; when the MERGED head count
    H = KV·G divides it, k/v are broadcast to H heads so the score einsum
    carries a single head dim GSPMD can shard — the grouped layout with a
    row constraint made XLA reshard (all-gather) full L² score matrices on
    gemma2 (kv=8, g=2, mesh model=16).  Otherwise fall back to row
    sharding."""
    b, lq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    mm = shardctx.mesh().shape.get("model", 1) if shardctx.active() else 1
    if mm > 1 and kvh % mm != 0 and h % mm == 0 and g > 1 and lq > 1:
        kh = jnp.repeat(k, g, axis=2)
        vh = jnp.repeat(v, g, axis=2)
        scores = jnp.einsum("bqhd,bshd->bhqs", q, kh).astype(jnp.float32) * scale
        if softcap is not None:
            scores = L.softcap(scores, softcap)
        scores = scores + (bias[:, None, :, :] if bias.ndim == 3 else bias)
        scores = shardctx.constrain(scores, "batch", "model", None, None)
        p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", p, vh)
        return out
    q = q.reshape(b, lq, kvh, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        scores = L.softcap(scores, softcap)
    scores = scores + bias[:, None, None, :, :] if bias.ndim == 3 else scores + bias
    # shard the score matrix: KV heads over model when divisible; decode
    # (Lq=1) along the key/cache axis (matches the S-sharded KV cache —
    # GSPMD partial-softmax reduces); otherwise along query rows
    if shardctx.active():
        if kvh % mm == 0:
            scores = shardctx.constrain(scores, "batch", "model", None, None, None)
        elif lq == 1:
            scores = shardctx.constrain(scores, "batch", None, None, None, "model")
        else:
            scores = shardctx.constrain(scores, "batch", None, None, "model", None)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(b, lq, h, dh)


# chunk the query axis of full-sequence attention above this length: keeps
# the materialized score block bounded (XLA-level flash; the Pallas kernel
# is the on-TPU equivalent with VMEM-resident accumulators)
CHUNK_THRESHOLD = 4096
CHUNK_Q = 2048


def _sdpa_chunked(q, k, v, positions, *, causal, window, softcap, scale,
                  k_positions=None, chunk=CHUNK_Q):
    """Query-chunked attention via lax.scan — scores never exceed
    (B, KV, G, chunk, Lk)."""
    b, lq, h, dh = q.shape
    nc = lq // chunk
    rem = lq - nc * chunk
    kpos = positions if k_positions is None else k_positions
    if kpos.shape[0] == 1 and b > 1:
        kpos = jnp.broadcast_to(kpos, (b, kpos.shape[1]))
    qpos = positions if positions.shape[0] == b else \
        jnp.broadcast_to(positions, (b, positions.shape[1]))

    def one(qc, pc):
        bias = _mask_bias(pc, kpos, causal=causal, window=window)
        return _sdpa(qc, k, v, bias, softcap=softcap, scale=scale)

    out_main = None
    if nc:
        qm = q[:, : nc * chunk].reshape(b, nc, chunk, h, dh).transpose(1, 0, 2, 3, 4)
        pm = qpos[:, : nc * chunk].reshape(b, nc, chunk).transpose(1, 0, 2)
        _, om = jax.lax.scan(lambda c, xs: (c, one(*xs)), None, (qm, pm))
        out_main = om.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, dh)
    if rem:
        ot = one(q[:, nc * chunk:], qpos[:, nc * chunk:])
        return ot if out_main is None else jnp.concatenate([out_main, ot], 1)
    return out_main


def _decode_sdpa(spec, q, k, v, bias, *, scale: float):
    """One-token attention on the decode cache layouts.
    q: (B,1,H,dh); k: (B,KV,dh,S); v: (B,KV,S,dh); bias: (B,1,S)."""
    b, _, h, dh = q.shape
    kvh = k.shape[1]
    g = h // kvh
    qr = q.reshape(b, kvh, g, dh)
    scores = jnp.einsum("bkgd,bkds->bkgs", qr, k).astype(jnp.float32) * scale
    if spec.logit_softcap is not None:
        scores = L.softcap(scores, spec.logit_softcap)
    scores = scores + bias[:, :, None, :]
    scores = shardctx.constrain(scores, "batch", None, None, "model")
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v)
    return out.reshape(b, 1, h, dh)


# ---------------------------------------------------------------------------
# GQA forward
# ---------------------------------------------------------------------------

def _gqa_qkv(spec: AttentionSpec, params, x, memory=None):
    b = x.shape[0]
    src = memory if spec.cross else x
    q = x @ params["wq"]
    k = src @ params["wk"]
    v = src @ params["wv"]
    if spec.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, x.shape[1], spec.num_heads, spec.head_dim)
    k = k.reshape(b, src.shape[1], spec.num_kv_heads, spec.head_dim)
    v = v.reshape(b, src.shape[1], spec.num_kv_heads, spec.head_dim)
    if spec.qk_norm:
        q = L.rmsnorm(params["q_norm"], q)
        k = L.rmsnorm(params["k_norm"], k)
    return q, k, v


def _gqa_full(spec: AttentionSpec, params, x, positions, memory=None, use_flash=False):
    q, k, v = _gqa_qkv(spec, params, x, memory)
    if spec.pos_emb == "rope" and not spec.cross:
        q = L.apply_rope(q, positions, spec.rope_theta)
        k = L.apply_rope(k, positions, spec.rope_theta)
    q = shardctx.constrain(q, "batch", None, "model", None)
    k = shardctx.constrain(k, "batch", None, "model", None)
    v = shardctx.constrain(v, "batch", None, "model", None)
    scale = 1.0 / math.sqrt(spec.head_dim)
    if use_flash and not spec.cross:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=spec.causal, window=spec.window,
                                   softcap=spec.logit_softcap, scale=scale)
    elif not spec.cross and x.shape[1] > CHUNK_THRESHOLD:
        out = _sdpa_chunked(q, k, v, positions, causal=spec.causal,
                            window=spec.window, softcap=spec.logit_softcap,
                            scale=scale)
    else:
        if spec.cross:
            bias = jnp.zeros((x.shape[0], x.shape[1], memory.shape[1]),
                             jnp.float32)
        else:
            bias = _mask_bias(positions, positions, causal=spec.causal,
                              window=spec.window)
            if bias.ndim == 2:
                bias = bias[None]
        out = _sdpa(q, k, v, bias, softcap=spec.logit_softcap, scale=scale)
    out = out.reshape(x.shape[0], x.shape[1], -1) @ params["wo"]
    return out, (k, v)


def _gqa_decode(spec: AttentionSpec, params, x, pos, cache, slot_pos, memory=None):
    """x: (B, 1, D). cache k/v: (B, S, KV, dh). slot_pos: (S,) token position
    held by each cache slot (-1 = empty).  Returns (out, new_cache)."""
    if spec.cross:
        out, _ = _gqa_full(spec, params, x,
                           jnp.full((x.shape[0], 1), pos), memory=memory)
        return out, cache
    q, k_new, v_new = _gqa_qkv(spec, params, x)
    posb = jnp.full((x.shape[0], 1), pos)
    if spec.pos_emb == "rope":
        q = L.apply_rope(q, posb, spec.rope_theta)
        k_new = L.apply_rope(k_new, posb, spec.rope_theta)
    s = cache["k"].shape[-1]
    slot = pos % s if spec.window is not None and spec.window <= s else jnp.minimum(pos, s - 1)
    # k_new/v_new: (B, 1, KV, dh) → column/row writes in the cache layouts
    kcol = k_new.astype(cache["k"].dtype).transpose(0, 2, 3, 1)  # (B,KV,dh,1)
    vrow = v_new.astype(cache["v"].dtype).transpose(0, 2, 1, 3)  # (B,KV,1,dh)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], kcol, slot, 3)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], vrow, slot, 2)
    new_slot_pos = jax.lax.dynamic_update_slice_in_dim(
        slot_pos, jnp.array([pos], slot_pos.dtype), slot, 0)
    bias = _mask_bias(posb, new_slot_pos[None, :], causal=spec.causal,
                      window=spec.window,
                      k_valid=(new_slot_pos >= 0)[None, :])
    scale = 1.0 / math.sqrt(spec.head_dim)
    out = _decode_sdpa(spec, q, k, v, bias, scale=scale)
    out = out.reshape(x.shape[0], 1, -1) @ params["wo"]
    return out, {"k": k, "v": v, "slots": new_slot_pos}


# ---------------------------------------------------------------------------
# MLA forward
# ---------------------------------------------------------------------------

def _mla_q(spec: AttentionSpec, params, x):
    b, l, _ = x.shape
    h = spec.num_heads
    if spec.q_lora_rank:
        q = L.rmsnorm(params["q_norm"], x @ params["wq_a"]) @ params["wq_b"]
    else:
        q = x @ params["wq"]
    q = q.reshape(b, l, h, spec.nope_head_dim + spec.rope_head_dim)
    return q[..., : spec.nope_head_dim], q[..., spec.nope_head_dim:]


def _mla_latent(spec: AttentionSpec, params, x, positions):
    kv = x @ params["wkv_a"]
    ckv, krope = kv[..., : spec.kv_lora_rank], kv[..., spec.kv_lora_rank:]
    ckv = L.rmsnorm(params["kv_norm"], ckv)
    krope = L.apply_rope(krope[..., None, :], positions, spec.rope_theta)[..., 0, :]
    return ckv, krope


def _mla_full(spec: AttentionSpec, params, x, positions):
    """Training / prefill: expand the latent and run standard attention
    (query-chunked above CHUNK_THRESHOLD)."""
    b, l, _ = x.shape
    h = spec.num_heads
    qn, qr = _mla_q(spec, params, x)
    qr = L.apply_rope(qr, positions, spec.rope_theta)
    ckv, krope = _mla_latent(spec, params, x, positions)
    kvb = (ckv @ params["wkv_b"]).reshape(b, l, h, spec.nope_head_dim + spec.v_head_dim)
    kn, v = kvb[..., : spec.nope_head_dim], kvb[..., spec.nope_head_dim:]
    qn = shardctx.constrain(qn, "batch", None, "model", None)
    qr = shardctx.constrain(qr, "batch", None, "model", None)
    kn = shardctx.constrain(kn, "batch", None, "model", None)
    v = shardctx.constrain(v, "batch", None, "model", None)
    scale = 1.0 / math.sqrt(spec.nope_head_dim + spec.rope_head_dim)
    if positions.shape[0] == 1 and b > 1:
        positions = jnp.broadcast_to(positions, (b, positions.shape[1]))

    def attend(qn_c, qr_c, pos_c):
        bias = _mask_bias(pos_c, positions, causal=True, window=spec.window)
        scores = (jnp.einsum("bqhd,bshd->bhqs", qn_c, kn)
                  + jnp.einsum("bqhr,bsr->bhqs", qr_c, krope)
                  ).astype(jnp.float32) * scale
        # heads over model when divisible, else query rows — a non-fitting
        # head constraint silently degrades to REPLICATED score compute
        # (observed: 16× memory-term blowup on minicpm3 prefill, §Perf-1)
        if shardctx.active() and h % shardctx.mesh().shape.get("model", 1) == 0:
            scores = shardctx.constrain(scores, "batch", "model", None, None)
        else:
            scores = shardctx.constrain(scores, "batch", None, "model", None)
        scores = scores + bias[:, None, :, :]
        p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        # (b,h,q,d) output order: keeps the AV contraction layout-aligned
        # with p so XLA does not materialize a score-sized transpose copy
        return jnp.einsum("bhqs,bshd->bhqd", p, v).transpose(0, 2, 1, 3)

    if l > CHUNK_THRESHOLD:
        c = CHUNK_Q
        nc = l // c
        qnm = qn[:, : nc * c].reshape(b, nc, c, h, -1).transpose(1, 0, 2, 3, 4)
        qrm = qr[:, : nc * c].reshape(b, nc, c, h, -1).transpose(1, 0, 2, 3, 4)
        pm = positions[:, : nc * c].reshape(b, nc, c).transpose(1, 0, 2)
        _, om = jax.lax.scan(lambda cr, xs: (cr, attend(*xs)), None,
                             (qnm, qrm, pm))
        out = om.transpose(1, 0, 2, 3, 4).reshape(b, nc * c, h, spec.v_head_dim)
        if l > nc * c:
            tail = attend(qn[:, nc * c:], qr[:, nc * c:], positions[:, nc * c:])
            out = jnp.concatenate([out, tail], axis=1)
    else:
        out = attend(qn, qr, positions)
    out = out.reshape(b, l, h * spec.v_head_dim)
    return out @ params["wo"], (ckv, krope)


def _mla_decode(spec: AttentionSpec, params, x, pos, cache, slot_pos):
    """Absorbed decode: attention runs in the latent space — the per-token
    cache is (kv_lora + rope_dim) wide, and W_kv_b is folded into q and out."""
    b = x.shape[0]
    h = spec.num_heads
    qn, qr = _mla_q(spec, params, x)                  # (B,1,H,*)
    posb = jnp.full((b, 1), pos)
    qr = L.apply_rope(qr, posb, spec.rope_theta)
    ckv_new, kr_new = _mla_latent(spec, params, x, posb)
    s = cache["ckv"].shape[1]
    slot = jnp.minimum(pos, s - 1)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), slot, 1)
    krope = jax.lax.dynamic_update_slice_in_dim(cache["krope"], kr_new.astype(cache["krope"].dtype), slot, 1)
    new_slot_pos = jax.lax.dynamic_update_slice_in_dim(
        slot_pos, jnp.array([pos], slot_pos.dtype), slot, 0)
    wkv_b = params["wkv_b"].reshape(spec.kv_lora_rank, h, spec.nope_head_dim + spec.v_head_dim)
    wk_b, wv_b = wkv_b[..., : spec.nope_head_dim], wkv_b[..., spec.nope_head_dim:]
    # absorb: q_eff (B,1,H,C) = q_nope · W_kb
    q_eff = jnp.einsum("bqhd,chd->bqhc", qn, wk_b)
    scores = (jnp.einsum("bqhc,bsc->bhqs", q_eff, ckv.astype(q_eff.dtype))
              + jnp.einsum("bqhr,bsr->bhqs", qr, krope.astype(qr.dtype))).astype(jnp.float32)
    scores = scores / math.sqrt(spec.nope_head_dim + spec.rope_head_dim)
    bias = _mask_bias(posb, new_slot_pos[None, :], causal=True, window=spec.window,
                      k_valid=(new_slot_pos >= 0)[None, :])
    scores = scores + bias[:, None, :, :]
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsc->bqhc", p.astype(ckv.dtype), ckv)   # latent ctx
    out = jnp.einsum("bqhc,chv->bqhv", ctx.astype(qn.dtype), wv_b)
    out = out.reshape(b, 1, h * spec.v_head_dim) @ params["wo"]
    return out, {"ckv": ckv, "krope": krope, "slots": new_slot_pos}


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------

def apply(spec: AttentionSpec, params, x, *, positions=None, mode: str = "full",
          pos=None, cache=None, slot_pos=None, memory=None,
          use_flash: bool = False, video_shape=None):
    """Returns (out, aux) where aux is a prefill (k, v)/(ckv, krope) tuple in
    full mode and the updated cache dict in decode mode.

    ``video_shape=(T, S)`` + ``spec.pattern`` enables factorized video
    attention: "spatial" attends within each frame (B·T, S), "temporal"
    within each spatial location (B·S, T) — the OpenSora STDiT layout.
    """
    if mode == "full":
        if spec.pattern and not spec.cross:
            t, s = video_shape
            b, l, d = x.shape
            assert l == t * s, f"L={l} != T*S={t*s}"
            if spec.pattern == "spatial":
                xr = x.reshape(b * t, s, d)
                posr = jnp.arange(s)[None, :]
            else:
                xr = x.reshape(b, t, s, d).transpose(0, 2, 1, 3).reshape(b * s, t, d)
                posr = jnp.arange(t)[None, :]
            import dataclasses
            out, aux = apply(dataclasses.replace(spec, pattern=None),
                             params, xr, positions=posr, mode="full",
                             use_flash=use_flash)
            if spec.pattern == "spatial":
                out = out.reshape(b, t * s, d)
            else:
                out = out.reshape(b, s, t, d).transpose(0, 2, 1, 3).reshape(b, l, d)
            return out, aux
        if positions is None:
            positions = jnp.arange(x.shape[1])[None, :]
        if spec.kind == "mla":
            return _mla_full(spec, params, x, positions)
        return _gqa_full(spec, params, x, positions, memory=memory, use_flash=use_flash)
    assert mode == "decode"
    if spec.kind == "mla":
        return _mla_decode(spec, params, x, pos, cache, slot_pos)
    return _gqa_decode(spec, params, x, pos, cache, slot_pos, memory=memory)
