"""Residual blocks: norm → mixer → +res [→ norm → cross → +res]
[→ norm → ffn → +res], with optional adaLN-zero (DiT) conditioning and
SmoothCache branch caching hooks.

The SmoothCache contract: every cacheable *branch* (mixer / cross / ffn)
produces its output **before** the residual add (and before the adaLN gate,
which is recomputed cheaply on cache hits).  `apply` takes a static
``skip: dict[type → bool]`` — when a branch's type is skipped, its cached
output is used and the branch computation is absent from the traced graph.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import AttentionSpec, BlockSpec, MLPSpec, MoESpec, RGLRUSpec, SSMSpec
from repro.models import attention, layers as L, mlp, moe, rglru, ssm


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(key, spec: BlockSpec, d_model: int, dtype=jnp.float32,
         cond_dim: int = 0, adaln_dim: int = 0):
    ks = jax.random.split(key, 12)
    p = {}
    if spec.mixer is not None:
        p["norm1"] = L.norm_init(spec.norm, d_model, dtype)
        if isinstance(spec.mixer, AttentionSpec):
            p["mixer"] = attention.init(ks[0], spec.mixer, d_model, dtype)
        elif isinstance(spec.mixer, SSMSpec):
            p["mixer"] = ssm.init(ks[0], spec.mixer, d_model, dtype)
        else:
            p["mixer"] = rglru.init(ks[0], spec.mixer, d_model, dtype)
        if spec.post_norm:
            p["post_norm1"] = L.norm_init(spec.norm, d_model, dtype)
    if spec.cross is not None:
        p["norm_x"] = L.norm_init(spec.norm, d_model, dtype)
        p["cross"] = attention.init(ks[1], spec.cross, d_model, dtype,
                                    cond_dim=cond_dim)
    if spec.ffn is not None:
        p["norm2"] = L.norm_init(spec.norm, d_model, dtype)
        if isinstance(spec.ffn, MoESpec):
            p["ffn"] = moe.init(ks[2], spec.ffn, d_model, dtype)
        else:
            p["ffn"] = mlp.init(ks[2], spec.ffn, d_model, dtype)
        if spec.post_norm:
            p["post_norm2"] = L.norm_init(spec.norm, d_model, dtype)
    if spec.adaln:
        # adaLN-zero: cond → 6*d (shift/scale/gate for mixer and ffn)
        p["mod"] = {"w": L.zeros((adaln_dim, 6 * d_model), dtype),
                    "b": L.zeros((6 * d_model,), dtype)}
    return p


def init_cache(spec: BlockSpec, d_model: int, batch: int, cache_len: int,
               dtype=jnp.bfloat16):
    """Decode-time state cache for this block (None for stateless blocks)."""
    if spec.mixer is None:
        return None
    m = spec.mixer
    if isinstance(m, AttentionSpec):
        clen = min(cache_len, m.window) if m.window else cache_len
        c = attention.init_cache(m, batch, clen, dtype)
        if c is not None:
            c["slots"] = jnp.full((clen,), -1, jnp.int32)
        return c
    if isinstance(m, SSMSpec):
        return ssm.init_cache(m, d_model, batch, jnp.float32)
    return rglru.init_cache(m, d_model, batch, jnp.float32)


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def _modulation(spec: BlockSpec, params, cond):
    if not spec.adaln:
        return None
    m = jax.nn.silu(cond) @ params["mod"]["w"] + params["mod"]["b"]
    return jnp.split(m[:, None, :], 6, axis=-1)  # each (B, 1, d)


def _mod_norm(x_norm, shift, scale):
    return x_norm * (1.0 + scale) + shift


def apply(spec: BlockSpec, params, x, *, mode: str = "full", d_model: int,
          positions=None, pos=None, cache=None, memory=None, cond=None,
          skip=None, branch_cache=None, use_flash: bool = False,
          moe_group_size: int = 2048, moe_strategy: str = "gshard",
          video_shape=None):
    """Returns (x, branch_out, new_state_cache, aux_loss).

    branch_out: dict of pre-residual branch outputs (the SmoothCache cache
    content).  new_state_cache: updated decode cache (or prefill cache in
    full mode).  aux_loss: scalar (MoE load-balance), 0 when absent.
    """
    skip = skip or {}
    branch_cache = branch_cache or {}
    mod = _modulation(spec, params, cond)
    branch_out = {}
    new_cache = None
    aux = jnp.zeros((), jnp.float32)
    types = dict(zip(spec.branch_names(), spec.branch_types()))

    # ----- mixer -----
    if spec.mixer is not None:
        t = types["mixer"]
        if skip.get(t, False):
            out = branch_cache["mixer"]
            new_cache = cache  # state caches only advance when computed
        else:
            h = L.apply_norm(spec.norm, params["norm1"], x)
            if mod is not None:
                h = _mod_norm(h, mod[0], mod[1])
            m = spec.mixer
            if isinstance(m, AttentionSpec):
                if mode == "full":
                    out, kv = attention.apply(m, params["mixer"], h,
                                              positions=positions, mode="full",
                                              use_flash=use_flash,
                                              video_shape=video_shape)
                    new_cache = kv
                else:
                    out, new_cache = attention.apply(
                        m, params["mixer"], h, mode="decode", pos=pos,
                        cache={k: v for k, v in cache.items() if k != "slots"},
                        slot_pos=cache["slots"])
            elif isinstance(m, SSMSpec):
                if mode == "full":
                    out, new_cache = ssm.apply_full(m, params["mixer"], h,
                                                    d_model, use_kernel=use_flash)
                else:
                    out, new_cache = ssm.apply_decode(m, params["mixer"], h,
                                                      cache, d_model)
            else:
                if mode == "full":
                    out, new_cache = rglru.apply_full(m, params["mixer"], h, d_model)
                else:
                    out, new_cache = rglru.apply_decode(m, params["mixer"], h,
                                                        cache, d_model)
            if spec.post_norm:
                out = L.apply_norm(spec.norm, params["post_norm1"], out)
            branch_out["mixer"] = out
        if mod is not None:
            out = out * mod[2]
        x = x + out.astype(x.dtype)

    # ----- cross-attention -----
    if spec.cross is not None:
        if skip.get(types["cross"], False):
            out = branch_cache["cross"]
        else:
            h = L.apply_norm(spec.norm, params["norm_x"], x)
            out, _ = attention.apply(spec.cross, params["cross"], h,
                                     positions=positions, mode="full",
                                     memory=memory)
            branch_out["cross"] = out
        x = x + out.astype(x.dtype)

    # ----- ffn -----
    if spec.ffn is not None:
        t = types["ffn"]
        if skip.get(t, False):
            out = branch_cache["ffn"]
        else:
            h = L.apply_norm(spec.norm, params["norm2"], x)
            if mod is not None:
                h = _mod_norm(h, mod[3], mod[4])
            if isinstance(spec.ffn, MoESpec):
                out, aux = moe.apply(spec.ffn, params["ffn"], h,
                                     strategy=moe_strategy,
                                     group_size=moe_group_size)
            else:
                out = mlp.apply(spec.ffn, params["ffn"], h)
            if spec.post_norm:
                out = L.apply_norm(spec.norm, params["post_norm2"], out)
            branch_out["ffn"] = out
        if mod is not None:
            out = out * mod[5]
        x = x + out.astype(x.dtype)

    return x, branch_out, new_cache, aux
