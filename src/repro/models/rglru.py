"""RG-LRU recurrent mixer from Griffin / RecurrentGemma [arXiv:2402.19427].

Recurrence:  h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)
  a_t = exp(−c · softplus(Λ) · r_t),  r_t = σ(W_a x_t),  i_t = σ(W_x x_t)

Full-sequence mode uses ``jax.lax.associative_scan`` over the linear
recurrence (log-depth on TPU); decode mode is the O(1) step.  The gate
projections W_a / W_x are block-diagonal over ``num_heads`` blocks, as in
the reference implementation.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import RGLRUSpec
from repro.models import layers as L


def width(spec: RGLRUSpec, d_model: int) -> int:
    return spec.expand * d_model


def init(key, spec: RGLRUSpec, d_model: int, dtype=jnp.float32):
    w = width(spec, d_model)
    hd = w // spec.num_heads
    ks = jax.random.split(key, 8)
    # Λ init so that a^c = exp(-c softplus Λ) is in [0.9, 0.999] at r=1
    u = jax.random.uniform(ks[2], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    a_param = jnp.log(jnp.expm1(-jnp.log(u) / (2 * spec.c_constant)))
    blk = (jax.random.truncated_normal(ks[3], -2., 2., (spec.num_heads, hd, hd),
                                       jnp.float32) / math.sqrt(hd))
    blk2 = (jax.random.truncated_normal(ks[4], -2., 2., (spec.num_heads, hd, hd),
                                        jnp.float32) / math.sqrt(hd))
    return {
        "in_x": L.dense_init(ks[0], d_model, w, dtype),
        "in_gate": L.dense_init(ks[1], d_model, w, dtype),
        "conv_w": (jax.random.normal(ks[5], (spec.conv_width, w), jnp.float32)
                   / math.sqrt(spec.conv_width)).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": blk.astype(dtype), "ba": jnp.zeros((w,), dtype),
        "wx": blk2.astype(dtype), "bx": jnp.zeros((w,), dtype),
        "a_param": a_param,
        "out": L.dense_init(ks[6], w, d_model, dtype),
    }


def init_cache(spec: RGLRUSpec, d_model: int, batch: int, dtype=jnp.float32):
    w = width(spec, d_model)
    return {
        "conv": jnp.zeros((batch, spec.conv_width - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def _block_diag(w_blocks, x, nh):
    """x: (..., W) with W split into nh blocks → block-diagonal matmul."""
    shp = x.shape
    xb = x.reshape(shp[:-1] + (nh, shp[-1] // nh))
    out = jnp.einsum("...hi,hij->...hj", xb, w_blocks)
    return out.reshape(shp)


def _gates(spec: RGLRUSpec, params, xr):
    """xr: (..., W) conv output → (log_a (...,W) fp32, gated input)."""
    nh = spec.num_heads
    r = jax.nn.sigmoid((_block_diag(params["wa"], xr, nh) + params["ba"]).astype(jnp.float32))
    i = jax.nn.sigmoid((_block_diag(params["wx"], xr, nh) + params["bx"]).astype(jnp.float32))
    log_a = -spec.c_constant * jax.nn.softplus(params["a_param"]) * r
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * i * xr.astype(jnp.float32)
    return log_a, gated


def _causal_conv(params, x):
    w = params["conv_w"]
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(k)) + params["conv_b"]


def apply_full(spec: RGLRUSpec, params, x, d_model: int):
    """x: (B, L, D) → (B, L, D); returns final cache too."""
    gate = jax.nn.gelu((x @ params["in_gate"]).astype(jnp.float32))
    xr = x @ params["in_x"]
    conv_tail = xr[:, -(spec.conv_width - 1):, :]
    xr = _causal_conv(params, xr)
    log_a, gated = _gates(spec, params, xr)
    a = jnp.exp(log_a)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = (h * gate).astype(x.dtype)
    cache = {"conv": conv_tail, "h": h[:, -1, :]}
    return y @ params["out"], cache


def apply_decode(spec: RGLRUSpec, params, x, cache, d_model: int):
    """x: (B, 1, D)."""
    gate = jax.nn.gelu((x @ params["in_gate"]).astype(jnp.float32))   # (B,1,W)
    xr = x @ params["in_x"]
    win = jnp.concatenate([cache["conv"], xr], axis=1)                # (B,K,W)
    w = params["conv_w"]
    conv_out = jnp.einsum("bkw,kw->bw", win, w) + params["conv_b"]
    log_a, gated = _gates(spec, params, conv_out)                     # (B,W)
    h = jnp.exp(log_a) * cache["h"] + gated
    y = (h[:, None, :] * gate).astype(x.dtype)
    return y @ params["out"], {"conv": win[:, 1:, :], "h": h}
