from repro.models import attention, blocks, layers, mlp, moe, rglru, ssm, transformer  # noqa: F401
