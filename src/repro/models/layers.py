"""Primitive layers: norms, activations, RoPE, embeddings, linear init."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale: Optional[float] = None):
    """Truncated-normal fan-in init (LeCun-ish), matching common LLM inits."""
    std = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    w = jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim), jnp.float32) * std
    return w.astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    w = jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
    return w.astype(dtype)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms (params: {"scale": (d,)} [+ {"bias"} for layernorm])
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}     # gemma-style (1+scale)


def rmsnorm(params, x, eps: float = 1e-6):
    # statistics in f32, scaling applied in the stream dtype: keeps the
    # (B, L, D) primal/cotangent chain in bf16 so TP backward all-reduces
    # stay bf16 (gemma2 §Perf-2 iter 3 — f32 cotangents doubled ICI bytes)
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + params["scale"]).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    out = (x - mu.astype(x.dtype)) * inv
    return out * params["scale"].astype(x.dtype) + params["bias"].astype(x.dtype)


def norm_init(kind: str, d: int, dtype=jnp.float32):
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def apply_norm(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def softcap(x, cap: float):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., L, H, Dh) rotated pairwise-half style; positions: (..., L)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                              # (dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., L, dh/2)
    cos = jnp.cos(ang)[..., :, None, :]                      # (..., L, 1, dh/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Positional / timestep embeddings
# ---------------------------------------------------------------------------

def sinusoidal_embedding(positions, dim: int, max_period: float = 10000.0):
    """positions: (...,) → (..., dim). Also used for diffusion timesteps."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = positions[..., None].astype(jnp.float32) * freqs
    emb = jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
    if dim % 2:
        emb = jnp.pad(emb, [(0, 0)] * (emb.ndim - 1) + [(0, 1)])
    return emb
