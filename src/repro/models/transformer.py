"""Stack builder: turns a ModelConfig into init / forward / decode functions.

Stages with ``repeat > 1`` are executed with ``jax.lax.scan`` over stacked
params — one lowered unit body per stage — which keeps the HLO small enough
to compile 61-layer MoE models for 512 GSPMD devices on one host core.

Entry points
  init_params(key, cfg, dtype)
  forward(cfg, params, tokens | embeds, ...)        # train / prefill / DiT step
  decode_step(cfg, params, token, pos, caches, ...) # one AR token
  init_caches(cfg, batch, cache_len, dtype)
  prefill(cfg, params, tokens, cache_len, ...)      # forward + cache build
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import AttentionSpec, BlockSpec, ModelConfig, Stage
from repro.models import attention, blocks, layers as L


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_unit(key, stage: Stage, d_model, dtype, cond_dim, adaln_dim):
    ks = jax.random.split(key, len(stage.unit))
    return tuple(
        blocks.init(ks[i], b, d_model, dtype, cond_dim=cond_dim,
                    adaln_dim=adaln_dim)
        for i, b in enumerate(stage.unit)
    )


def init_params(key, cfg: ModelConfig, dtype=jnp.float32,
                adaln_dim: int = 0) -> Dict[str, Any]:
    ks = jax.random.split(key, len(cfg.stages) + 4)
    p: Dict[str, Any] = {}
    d = cfg.d_model
    if cfg.task == "lm":
        if cfg.num_codebooks > 1:
            p["embed"] = jnp.stack([
                L.embed_init(k, cfg.vocab_size, d, dtype)
                for k in jax.random.split(ks[0], cfg.num_codebooks)])
            p["heads"] = jnp.stack([
                L.dense_init(k, d, cfg.vocab_size, dtype)
                for k in jax.random.split(ks[1], cfg.num_codebooks)])
        else:
            p["embed"] = L.embed_init(ks[0], cfg.vocab_size, d, dtype)
            if not cfg.tie_embeddings:
                p["lm_head"] = L.dense_init(ks[1], d, cfg.vocab_size, dtype)
    stages = []
    for i, st in enumerate(cfg.stages):
        keys = jax.random.split(ks[2 + i], st.repeat)
        unit_init = functools.partial(_init_unit, stage=st, d_model=d,
                                      dtype=dtype, cond_dim=cfg.cond_dim,
                                      adaln_dim=adaln_dim)
        stages.append(jax.vmap(lambda k: unit_init(k))(keys))
    p["stages"] = stages
    p["final_norm"] = L.norm_init(cfg.norm, d, dtype)
    if cfg.mtp_depth > 0 and cfg.task == "lm":
        # DeepSeek-V3 multi-token prediction: norm(h_t) ⊕ norm(emb_{t+1})
        # → proj → one extra block → shared head  [arXiv:2412.19437 §2.2]
        km = jax.random.split(ks[-1], 3)
        last_spec = cfg.stages[-1].unit[-1]
        p["mtp"] = {
            "h_norm": L.norm_init(cfg.norm, d, dtype),
            "e_norm": L.norm_init(cfg.norm, d, dtype),
            "proj": L.dense_init(km[0], 2 * d, d, dtype),
            "block": blocks.init(km[1], last_spec, d, dtype,
                                 cond_dim=cfg.cond_dim),
        }
    return p


def mtp_logits(cfg: ModelConfig, params, hidden, tokens, *,
               moe_group_size=2048, moe_strategy="gshard"):
    """MTP head: predict token t+2 from hidden_t and embedding of t+1.
    hidden: (B, L, d) final-layer hidden states; tokens: (B, L).
    Returns logits (B, L-1, V) aligned to targets tokens[:, 2:] (+1 pad)."""
    mtp = params["mtp"]
    # keep the full L tokens (repeat the last id) so the MoE group size
    # still divides the token count; the final position is padding
    nxt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    emb_next = jnp.take(params["embed"], nxt, axis=0)
    h = jnp.concatenate([
        L.apply_norm(cfg.norm, mtp["h_norm"], hidden),
        L.apply_norm(cfg.norm, mtp["e_norm"], emb_next)], axis=-1)
    h = h @ mtp["proj"]
    spec = cfg.stages[-1].unit[-1]
    h, _, _, _ = blocks.apply(spec, mtp["block"], h, mode="full",
                              d_model=cfg.d_model,
                              positions=jnp.arange(h.shape[1])[None, :],
                              moe_group_size=moe_group_size,
                              moe_strategy=moe_strategy)
    h = L.apply_norm(cfg.norm, params["final_norm"], h)
    return logits_from_hidden(cfg, params, h)


def init_caches(cfg: ModelConfig, batch: int, cache_len: int,
                dtype=jnp.bfloat16):
    """Stacked decode caches, one tuple-per-block per stage."""
    out = []
    for st in cfg.stages:
        unit_caches = []
        for b in st.unit:
            c = blocks.init_cache(b, cfg.d_model, batch, cache_len, dtype)
            if c is None:
                unit_caches.append(None)
            else:
                unit_caches.append(jax.tree.map(
                    lambda a: jnp.zeros((st.repeat,) + a.shape, a.dtype) if a.dtype != jnp.int32
                    else jnp.full((st.repeat,) + a.shape, -1, a.dtype), c))
        out.append(tuple(unit_caches))
    return out


# ---------------------------------------------------------------------------
# Embedding IO
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params, tokens, prefix_embeds=None):
    """tokens: (B, L) or (B, L, K) → (B, L', d) with optional prefix."""
    if cfg.num_codebooks > 1:
        x = _codebook_embed(params["embed"], tokens)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    if cfg.pos_emb == "sinusoidal":
        pos = jnp.arange(x.shape[1])
        x = x + L.sinusoidal_embedding(pos, cfg.d_model)[None].astype(x.dtype)
    return x


def _codebook_embed(embed, tokens):
    """embed: (K, V, d); tokens: (B, L, K) → summed (B, L, d)."""
    k = embed.shape[0]
    outs = [jnp.take(embed[i], tokens[..., i], axis=0) for i in range(k)]
    return sum(outs)


def logits_from_hidden(cfg: ModelConfig, params, x):
    if cfg.num_codebooks > 1:
        out = jnp.einsum("bld,kdv->blkv", x, params["heads"])
    elif cfg.tie_embeddings:
        out = x @ params["embed"].T
    else:
        out = x @ params["lm_head"]
    if cfg.logit_softcap:
        out = L.softcap(out.astype(jnp.float32), cfg.logit_softcap)
    return out


# ---------------------------------------------------------------------------
# Forward (full-sequence) through stages
# ---------------------------------------------------------------------------

def _normalize_collect(collect_branches):
    """``collect_branches`` is either all-or-nothing (bool) or a per-type
    mask (collection of SmoothCache layer types).  Returns ``None`` for
    "collect every branch" or a frozenset of types to collect."""
    if collect_branches is True:
        return None
    if not collect_branches:          # False / None / empty collection
        return frozenset()
    return frozenset(collect_branches)


def _unit_apply(stage: Stage, unit_params, x, *, mode, d_model, positions,
                pos, unit_cache, memory, cond, skip, unit_branch_cache,
                use_flash, moe_group_size, moe_strategy, collect,
                video_shape=None):
    branch_outs = []
    new_caches = []
    aux = jnp.zeros((), jnp.float32)
    for i, b in enumerate(stage.unit):
        bc = unit_branch_cache[i] if unit_branch_cache is not None else None
        cache = unit_cache[i] if unit_cache is not None else None
        x, bo, nc, a = blocks.apply(
            b, unit_params[i], x, mode=mode, d_model=d_model,
            positions=positions, pos=pos, cache=cache, memory=memory,
            cond=cond, skip=skip, branch_cache=bc, use_flash=use_flash,
            moe_group_size=moe_group_size, moe_strategy=moe_strategy,
            video_shape=video_shape)
        if collect is None:
            kept = bo
        else:
            types = dict(zip(b.branch_names(), b.branch_types()))
            kept = {n: v for n, v in bo.items() if types[n] in collect}
        branch_outs.append(kept or None)
        new_caches.append(nc)
        aux = aux + a
    return x, tuple(branch_outs), tuple(new_caches), aux


def apply_stages(cfg: ModelConfig, params, x, *, mode="full", positions=None,
                 pos=None, caches=None, memory=None, cond=None, skip=None,
                 branch_caches=None, use_flash=False, moe_group_size=2048,
                 moe_strategy="gshard", collect_branches=False,
                 collect_caches=False, remat=False, video_shape=None):
    """Run all stages. Returns (x, branch_outs, new_caches, aux).

    ``collect_branches``: ``True`` collects every branch output, a
    collection of layer types collects only those (liveness-pruned
    SmoothCache execution), falsy collects nothing."""
    collect = _normalize_collect(collect_branches)
    collect_any = collect is None or len(collect) > 0
    all_branch, all_caches = [], []
    aux_total = jnp.zeros((), jnp.float32)
    for si, st in enumerate(cfg.stages):
        sp = params["stages"][si]
        scache = caches[si] if caches is not None else None
        sbc = branch_caches[si] if branch_caches is not None else None

        def body(carry, xs, _st=st):
            x = carry
            up, uc, ubc = xs
            x, bo, nc, aux = _unit_apply(
                _st, up, x, mode=mode, d_model=cfg.d_model,
                positions=positions, pos=pos, unit_cache=uc, memory=memory,
                cond=cond, skip=skip, unit_branch_cache=ubc,
                use_flash=use_flash, moe_group_size=moe_group_size,
                moe_strategy=moe_strategy, collect=collect,
                video_shape=video_shape)
            ys = {}
            if collect_any:
                ys["branch"] = bo
            if collect_caches or mode == "decode":
                ys["cache"] = nc
            return x, (ys, aux)

        if remat:
            body = jax.checkpoint(body)
        xs = (sp, scache, sbc)
        if st.repeat == 1:
            xs0 = jax.tree.map(lambda a: a[0], xs)
            x, (ys, aux) = body(x, xs0)
            ys = jax.tree.map(lambda a: a[None], ys)
            aux_total = aux_total + aux
        else:
            x, (ys, auxs) = jax.lax.scan(body, x, xs)
            aux_total = aux_total + jnp.sum(auxs)
        all_branch.append(ys.get("branch"))
        all_caches.append(ys.get("cache"))
    return x, all_branch, all_caches, aux_total


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, tokens=None, *, embeds=None,
            prefix_embeds=None, memory=None, cond=None, skip=None,
            branch_caches=None, use_flash=False, moe_group_size=2048,
            moe_strategy="gshard", collect_branches=False,
            collect_caches=False, remat=False, positions=None,
            video_shape=None):
    """Full-sequence forward.  For LM: tokens → logits.  For diffusion /
    embedding input: pass ``embeds`` (B, L, d) and get hidden states back
    (the diffusion wrapper owns patchify/head)."""
    if embeds is None:
        x = embed_tokens(cfg, params, tokens, prefix_embeds)
    else:
        x = embeds
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    x, branch, caches, aux = apply_stages(
        cfg, params, x, mode="full", positions=positions, memory=memory,
        cond=cond, skip=skip, branch_caches=branch_caches,
        use_flash=use_flash, moe_group_size=moe_group_size,
        moe_strategy=moe_strategy, collect_branches=collect_branches,
        collect_caches=collect_caches, remat=remat, video_shape=video_shape)
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    if cfg.task == "lm":
        out = logits_from_hidden(cfg, params, x)
    else:
        out = x
    return out, {"branch": branch, "caches": caches, "aux": aux, "hidden": x}


def _to_decode_cache(block_spec: BlockSpec, prefill_cache, cache_len: int,
                     prefill_len: int, cache_dtype):
    """Convert one block's stacked prefill cache → fixed-size decode cache.

    Attention prefill caches are (k, v) / (ckv, krope) tuples of length
    ``prefill_len``; they are scattered into ``cache_len`` slots using the
    same ring indexing the decode step uses (slot = pos % window for local
    attention, slot = pos for full)."""
    m = block_spec.mixer
    if m is None:
        return None
    if not isinstance(m, AttentionSpec):
        # ssm / rglru full-mode caches are already decode-format, but scan
        # stacking yields a leading (repeat,) dim on each leaf — keep it.
        return prefill_cache
    clen = min(cache_len, m.window) if m.window else cache_len
    positions = jnp.arange(prefill_len)
    if m.window and prefill_len > m.window:
        positions = positions[-m.window:]
    slots = positions % clen if m.window else jnp.minimum(positions, clen - 1)
    names = ("ckv", "krope") if m.kind == "mla" else ("k", "v")
    out = {}
    for name, arr in zip(names, prefill_cache):
        # arr: (repeat, B, L, ...) → take kept positions, scatter into slots
        kept = arr[:, :, positions, ...].astype(cache_dtype)
        buf = jnp.zeros(arr.shape[:2] + (clen,) + arr.shape[3:], cache_dtype)
        out[name] = buf.at[:, :, slots, ...].set(kept)
    if m.kind != "mla":
        # decode-GEMM layouts: k (r,B,KV,dh,S), v (r,B,KV,S,dh)
        out["k"] = out["k"].transpose(0, 1, 3, 4, 2)
        out["v"] = out["v"].transpose(0, 1, 3, 2, 4)
    slot_pos = jnp.full((clen,), -1, jnp.int32).at[slots].set(positions)
    out["slots"] = jnp.broadcast_to(slot_pos, (arr.shape[0], clen))
    return out


def prefill(cfg: ModelConfig, params, tokens=None, *, cache_len: int,
            embeds=None, prefix_embeds=None, memory=None,
            cache_dtype=jnp.bfloat16, use_flash=False,
            moe_group_size=2048, moe_strategy="gshard"):
    """Full forward that also builds decode caches. Returns (logits, caches)."""
    out, aux = forward(cfg, params, tokens, embeds=embeds,
                       prefix_embeds=prefix_embeds, memory=memory,
                       use_flash=use_flash, moe_group_size=moe_group_size,
                       moe_strategy=moe_strategy, collect_caches=True)
    plen = (tokens.shape[1] if tokens is not None else embeds.shape[1])
    if prefix_embeds is not None:
        plen += prefix_embeds.shape[1]
    caches = []
    for si, st in enumerate(cfg.stages):
        stage_caches = aux["caches"][si]
        unit = []
        for bi, b in enumerate(st.unit):
            unit.append(_to_decode_cache(b, stage_caches[bi], cache_len,
                                         plen, cache_dtype))
        caches.append(tuple(unit))
    return out, caches


def decode_step(cfg: ModelConfig, params, token, pos, caches, *,
                memory=None, prefix_embeds=None):
    """One AR decode step. token: (B, 1) or (B, 1, K); pos: scalar int."""
    x = embed_tokens(cfg, params, token)
    if cfg.pos_emb == "sinusoidal":
        # embed_tokens added pos-0 embedding; replace with the true position
        x = x - L.sinusoidal_embedding(jnp.arange(1), cfg.d_model)[None].astype(x.dtype)
        x = x + L.sinusoidal_embedding(jnp.full((1,), pos), cfg.d_model)[None].astype(x.dtype)
    x, _, new_caches, _ = apply_stages(
        cfg, params, x, mode="decode", pos=pos, caches=caches, memory=memory)
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    return logits_from_hidden(cfg, params, x), new_caches
