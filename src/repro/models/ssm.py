"""Mamba-2 mixer (SSD — state-space duality) [arXiv:2405.21060].

Full-sequence mode uses the chunked SSD algorithm (quadratic within a chunk,
linear recurrence across chunks) — the same blocking the Pallas kernel in
``repro.kernels.ssd`` implements on TPU.  Decode mode is the O(1) recurrent
state update.  State caches are functional pytrees.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import SSMSpec
from repro.models import layers as L


def dims(spec: SSMSpec, d_model: int):
    d_inner = spec.expand * d_model
    n_heads = d_inner // spec.head_dim
    conv_ch = d_inner + 2 * spec.n_groups * spec.d_state
    return d_inner, n_heads, conv_ch


def init(key, spec: SSMSpec, d_model: int, dtype=jnp.float32):
    d_inner, n_heads, conv_ch = dims(spec, d_model)
    ks = jax.random.split(key, 6)
    in_dim = 2 * d_inner + 2 * spec.n_groups * spec.d_state + n_heads
    lo, hi = spec.a_init_range
    a = jnp.exp(jax.random.uniform(ks[2], (n_heads,), jnp.float32,
                                   math.log(lo), math.log(hi)))
    # dt bias ~ softplus^{-1}(dt) for dt in [1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[3], (n_heads,), jnp.float32,
                                    math.log(1e-3), math.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": L.dense_init(ks[0], d_model, in_dim, dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.d_conv, conv_ch), jnp.float32)
                   / math.sqrt(spec.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(a),
        "dt_bias": dt_bias,
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "out_norm": L.rmsnorm_init(d_inner, dtype),
        "out_proj": L.dense_init(ks[4], d_inner, d_model, dtype),
    }


def init_cache(spec: SSMSpec, d_model: int, batch: int, dtype=jnp.float32):
    d_inner, n_heads, conv_ch = dims(spec, d_model)
    return {
        "conv": jnp.zeros((batch, spec.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, n_heads, spec.head_dim, spec.d_state), jnp.float32),
    }


def _split(spec: SSMSpec, d_model: int, zxbcdt):
    d_inner, n_heads, _ = dims(spec, d_model)
    gn = spec.n_groups * spec.d_state
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xbc, dt


def _causal_conv(params, xbc):
    """Depthwise causal conv over time. xbc: (B, L, C)."""
    w = params["conv_w"]                                  # (K, C)
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + params["conv_b"])


# ---------------------------------------------------------------------------
# SSD chunked scan (pure jnp oracle; Pallas kernel mirrors this blocking)
# ---------------------------------------------------------------------------

def segsum(x):
    """x: (..., L) → (..., L, L) segment sums: out[q, s] = Σ_{s<i≤q} x_i
    (−inf above the diagonal)."""
    l = x.shape[-1]
    # row i carries x_i; cumsum down rows gives Σ_{i≤q, i>s} x_i at [q, s]
    x = jnp.broadcast_to(x[..., :, None], x.shape[:-1] + (l, l))
    mask = jnp.tril(jnp.ones((l, l), bool), -1)   # keep s < i
    x = jnp.where(mask, x, 0.0)
    out = jnp.cumsum(x, axis=-2)
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int, h0=None):
    """SSD scan.

    x: (B, L, H, P) inputs; dt: (B, L, H) positive step sizes;
    a: (H,) positive decay rates (state decay = exp(-dt·a));
    b, c: (B, L, G, N) input/output projections (G groups broadcast to H).
    Returns (y (B,L,H,P), h_final (B,H,P,N)).
    """
    bs, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    if l % chunk:
        # pad with dt=0 steps: decay exp(0)=1 and zero input → state-neutral
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, hT = ssd_chunked(x, dt, a, b, c, chunk, h0)
        return y[:, :l], hT
    nc = l // chunk
    rep = h // g

    da = -dt * a[None, None, :]                            # (B,L,H) log decay
    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h)
    dac = da.reshape(bs, nc, chunk, h)
    bc = jnp.repeat(b.reshape(bs, nc, chunk, g, n), rep, axis=3)
    cc = jnp.repeat(c.reshape(bs, nc, chunk, g, n), rep, axis=3)

    # 1. intra-chunk (quadratic) term
    ss = segsum(dac.transpose(0, 1, 3, 2))                 # (B,nc,H,Q,Q)
    decay = jnp.exp(ss)
    scores = jnp.einsum("bzqhn,bzshn->bzhqs", cc, bc) * decay.astype(cc.dtype)
    y = jnp.einsum("bzhqs,bzsh,bzshp->bzqhp", scores, dtc.astype(cc.dtype), xc)

    # 2. chunk-final states
    decay_end = jnp.exp(jnp.cumsum(dac, axis=2)[:, :, -1:, :] -
                        jnp.cumsum(dac, axis=2))           # (B,nc,Q,H)
    states = jnp.einsum("bzqhn,bzqh,bzqhp->bzhpn", bc,
                        (dtc * decay_end).astype(cc.dtype), xc)

    # 3. inter-chunk recurrence over states: h_{z} = exp(sum_da_z) h_{z-1} + S_z
    chunk_decay = jnp.exp(jnp.sum(dac, axis=2))            # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((bs, h, p, n), jnp.float32)

    def step(hprev, inp):
        dec, s = inp
        hnew = hprev * dec[..., None, None] + s.astype(jnp.float32)
        return hnew, hprev

    (hT, hprevs) = jax.lax.scan(
        step, h0,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)               # (B,nc,H,P,N)

    # 4. inter-chunk output: y += C · h_prev · decay_from_chunk_start
    decay_in = jnp.exp(jnp.cumsum(dac, axis=2))            # (B,nc,Q,H)
    y = y + jnp.einsum("bzqhn,bzhpn,bzqh->bzqhp",
                       cc, hprevs.astype(cc.dtype), decay_in.astype(cc.dtype))
    return y.reshape(bs, l, h, p), hT


def ssd_decode_step(xt, dtt, a, bt, ct, state):
    """One-token recurrence. xt: (B,H,P); dtt: (B,H); bt/ct: (B,G,N);
    state: (B,H,P,N) fp32. Returns (yt, new_state)."""
    bs, h, p = xt.shape
    g = bt.shape[1]
    rep = h // g
    bth = jnp.repeat(bt, rep, axis=1)
    cth = jnp.repeat(ct, rep, axis=1)
    decay = jnp.exp(-dtt * a[None, :])[..., None, None]    # (B,H,1,1)
    upd = jnp.einsum("bhp,bhn,bh->bhpn", xt.astype(jnp.float32),
                     bth.astype(jnp.float32), dtt)
    state = state * decay + upd
    yt = jnp.einsum("bhpn,bhn->bhp", state, cth.astype(jnp.float32))
    return yt.astype(xt.dtype), state


# ---------------------------------------------------------------------------
# Block entry points
# ---------------------------------------------------------------------------

def apply_full(spec: SSMSpec, params, x, d_model: int, use_kernel: bool = False):
    """x: (B, L, D) → (B, L, D); also returns final (conv, ssm) cache."""
    b, l, _ = x.shape
    d_inner, n_heads, conv_ch = dims(spec, d_model)
    gn = spec.n_groups * spec.d_state
    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = _split(spec, d_model, zxbcdt)
    conv_tail = xbc[:, -(spec.d_conv - 1):, :]
    xbc = _causal_conv(params, xbc)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    xs = xs.reshape(b, l, n_heads, spec.head_dim)
    bmat = bmat.reshape(b, l, spec.n_groups, spec.d_state)
    cmat = cmat.reshape(b, l, spec.n_groups, spec.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = jnp.exp(params["a_log"])
    if use_kernel:
        from repro.kernels import ops as kops
        y, hT = kops.ssd(xs, dt, a, bmat, cmat, chunk=spec.chunk)
    else:
        y, hT = ssd_chunked(xs, dt, a, bmat, cmat, spec.chunk)
    y = y + xs * params["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(b, l, d_inner)
    y = L.rmsnorm(params["out_norm"], y * jax.nn.silu(z))
    cache = {"conv": conv_tail, "ssm": hT}
    return y @ params["out_proj"], cache


def apply_decode(spec: SSMSpec, params, x, cache, d_model: int):
    """x: (B, 1, D); cache {"conv": (B,K-1,C), "ssm": (B,H,P,N)}."""
    b = x.shape[0]
    d_inner, n_heads, conv_ch = dims(spec, d_model)
    gn = spec.n_groups * spec.d_state
    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = _split(spec, d_model, zxbcdt)             # (B,1,*)
    win = jnp.concatenate([cache["conv"], xbc], axis=1)    # (B,K,C)
    w = params["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", win, w) + params["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + gn], axis=-1)
    xs = xs.reshape(b, n_heads, spec.head_dim)
    bmat = bmat.reshape(b, spec.n_groups, spec.d_state)
    cmat = cmat.reshape(b, spec.n_groups, spec.d_state)
    dtt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = jnp.exp(params["a_log"])
    yt, state = ssd_decode_step(xs, dtt, a, bmat, cmat, cache["ssm"])
    yt = yt + xs * params["d_skip"][None, :, None].astype(xs.dtype)
    y = yt.reshape(b, 1, d_inner)
    y = L.rmsnorm(params["out_norm"], y * jax.nn.silu(z))
    new_cache = {"conv": win[:, 1:, :], "ssm": state}
    return y @ params["out_proj"], new_cache
