"""Feed-forward layers: (gated) MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import MLPSpec
from repro.models import layers as L


def init(key, spec: MLPSpec, d_model: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_up": L.dense_init(ks[0], d_model, spec.d_ff, dtype),
         "w_down": L.dense_init(ks[1], spec.d_ff, d_model, dtype)}
    if spec.gated:
        p["w_gate"] = L.dense_init(ks[2], d_model, spec.d_ff, dtype)
    return p


def apply(spec: MLPSpec, params, x):
    act = L.activation(spec.activation)
    up = x @ params["w_up"]
    if spec.gated:
        up = act(x @ params["w_gate"]) * up
    else:
        up = act(up)
    return up @ params["w_down"]
