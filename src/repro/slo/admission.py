"""Admission control: load estimation, shedding, deferral, aging.

The load signal is **queue depth × calibrated per-step service cost**: the
:class:`ServiceCostModel` learns seconds-per-sampling-step online from the
engine's finished batches (an EWMA, optionally per store entry — a heavily
cached rung's steps are cheaper than full compute), and the
:class:`LoadEstimator` turns the ready queue plus the in-flight runs'
remaining steps into an estimated backlog in seconds.  Admission then makes
one of three *explicit* decisions per queued request — requests are never
silently dropped:

* ``admit`` — proceed to batch formation;
* ``defer`` — push the request back with a retry time (``retry_at``), used
  for low-priority traffic during a transient; its arrival timestamp is
  untouched so queue-wait accounting stays honest;
* ``shed`` — reject with a reason (``deadline_infeasible`` when the
  backlog already implies a miss, ``overloaded`` when deferral cannot help
  either).  The engine records the reason in its metrics and its
  ``shed`` map.

Starvation freedom: a deferred request's *effective* priority grows with
its time in queue (``priority + aging_rate × wait``), so under sustained
overload every class eventually crosses the admit threshold — low-priority
work is delayed, not starved (``tests/test_slo.py`` asserts this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional


class ServiceCostModel:
    """Online EWMA of observed service seconds per sampling step.

    ``observe`` is fed per finished micro-batch (service time of the whole
    batch over its step count — batching amortizes, so this is a per-batch
    step cost, and under interleaving it includes contention from
    co-scheduled runs, which is exactly the pessimism an admission wait
    estimate wants).  EWMAs are keyed on ``(group, bucket)`` — the group
    is the *resolved* store entry, i.e. the ladder rung a batch actually
    ran, and the bucket its power-of-two batch size — so a ladder move or
    a continuous-batching regroup never transiently mis-prices the
    backlog with another rung's (or another batch shape's) step cost.
    ``per_step(group, bucket)`` falls back ``(rung, bucket)`` → rung →
    global → seed default, so coarse estimates remain available before
    a key has observations.
    """

    def __init__(self, default_step_cost: float = 0.1, alpha: float = 0.3):
        if default_step_cost <= 0:
            raise ValueError(f"default_step_cost must be > 0, got "
                             f"{default_step_cost}")
        if not 0 < alpha <= 1:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.default_step_cost = float(default_step_cost)
        self.alpha = float(alpha)
        self._global: Optional[float] = None
        self._per_group: Dict[str, float] = {}
        self._per_key: Dict[tuple, float] = {}

    def _ewma(self, prev: Optional[float], c: float) -> float:
        return c if prev is None else \
            (1 - self.alpha) * prev + self.alpha * c

    def observe(self, group: str, service_s: float, num_steps: int,
                bucket: Optional[int] = None) -> None:
        if num_steps < 1 or service_s < 0:
            return
        c = service_s / float(num_steps)
        self._global = self._ewma(self._global, c)
        self._per_group[group] = self._ewma(self._per_group.get(group), c)
        if bucket is not None:
            key = (group, int(bucket))
            self._per_key[key] = self._ewma(self._per_key.get(key), c)

    def per_step(self, group: Optional[str] = None,
                 bucket: Optional[int] = None) -> float:
        if group is not None and bucket is not None:
            key = (group, int(bucket))
            if key in self._per_key:
                return self._per_key[key]
        if group is not None and group in self._per_group:
            return self._per_group[group]
        if self._global is not None:
            return self._global
        return self.default_step_cost

    def estimate(self, num_steps: int, group: Optional[str] = None,
                 bucket: Optional[int] = None) -> float:
        """Estimated service seconds for a run of ``num_steps`` steps."""
        return self.per_step(group, bucket) * max(int(num_steps), 0)

    def snapshot(self) -> Dict:
        """The calibrated state as one JSON-safe dict — what the engine
        exports into the metrics registry as ``slo.step_cost_s`` gauges
        (observability of the admission pricing, not just its
        decisions)."""
        return {
            "global": self._global,
            "per_group": dict(sorted(self._per_group.items())),
            "per_key": {f"{g}|b{b}": v for (g, b), v in
                        sorted(self._per_key.items())},
        }


class LoadEstimator:
    """Backlog in seconds from queue depth and in-flight remaining work.

    ``batch_factor`` amortizes queued requests over micro-batching (under
    load, batches fill up to ``max_batch``, so ``max_batch`` queued
    requests cost roughly one run).  In-flight step counts are already
    per batch and enter unamortized."""

    def __init__(self, cost_model: ServiceCostModel, *,
                 batch_factor: float = 1.0):
        if batch_factor < 1:
            raise ValueError(f"batch_factor must be >= 1, got "
                             f"{batch_factor}")
        self.cost_model = cost_model
        self.batch_factor = float(batch_factor)

    def backlog_seconds(self, queued_steps: Iterable[int],
                        inflight_steps: Iterable[int]) -> float:
        c = self.cost_model.per_step()
        queued = sum(max(int(s), 0) for s in queued_steps)
        inflight = sum(max(int(s), 0) for s in inflight_steps)
        return c * (queued / self.batch_factor + inflight)


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    action: str                               # "admit" | "defer" | "shed"
    reason: str = "admitted"
    retry_at: Optional[float] = None          # set for defer


ADMIT = AdmissionDecision("admit")


class AdmissionController:
    """Per-request admit / defer / shed decisions against a backlog
    estimate.

    ``max_backlog_s`` is the overload threshold: above it only requests
    whose *effective* priority (priority + ``aging_rate`` × time in queue)
    reaches ``admit_priority`` are admitted; the rest are deferred by
    ``defer_interval`` — or shed with reason ``overloaded`` when deferral
    provably cannot meet their deadline.  Independently of load, a request
    whose deadline is already infeasible given the backlog is shed
    immediately (``deadline_infeasible``) rather than served late.
    ``headroom`` scales the wait estimate (> 1 sheds earlier/safer, < 1 is
    lenient toward the estimator's pessimism under interleaving)."""

    def __init__(self, *, max_backlog_s: Optional[float] = None,
                 admit_priority: float = 1.0, aging_rate: float = 0.0,
                 defer_interval: float = 0.5, headroom: float = 1.0):
        if max_backlog_s is not None and max_backlog_s < 0:
            raise ValueError(f"max_backlog_s must be >= 0, got "
                             f"{max_backlog_s}")
        if aging_rate < 0:
            raise ValueError(f"aging_rate must be >= 0, got {aging_rate}")
        if defer_interval <= 0:
            raise ValueError(f"defer_interval must be > 0, got "
                             f"{defer_interval}")
        if headroom <= 0:
            raise ValueError(f"headroom must be > 0, got {headroom}")
        self.max_backlog_s = max_backlog_s
        self.admit_priority = float(admit_priority)
        self.aging_rate = float(aging_rate)
        self.defer_interval = float(defer_interval)
        self.headroom = float(headroom)

    def effective_priority(self, req, now: float) -> float:
        wait = 0.0 if req.arrival is None else max(now - req.arrival, 0.0)
        return float(req.priority) + self.aging_rate * wait

    def decide(self, req, now: float, *, backlog_s: float,
               est_service_s: float = 0.0) -> AdmissionDecision:
        deadline = getattr(req, "deadline", None)
        wait_est = self.headroom * (backlog_s + est_service_s)
        if deadline is not None and now + wait_est > deadline:
            return AdmissionDecision("shed", "deadline_infeasible")
        if self.max_backlog_s is None or backlog_s <= self.max_backlog_s:
            return ADMIT
        if self.effective_priority(req, now) >= self.admit_priority:
            return ADMIT
        retry = now + self.defer_interval
        if deadline is not None \
                and retry + self.headroom * est_service_s > deadline:
            # a deferral would return past the point of feasibility — be
            # honest now instead of shedding the same request later
            return AdmissionDecision("shed", "overloaded")
        return AdmissionDecision("defer", "overloaded", retry_at=retry)
