"""repro.slo — SLO-aware, quality-elastic admission control & scheduling.

The production QoS layer over :mod:`repro.serve`: per-request service
objectives (deadline, priority class, quality floor as max τ), EDF
scheduling over in-flight micro-batches, admission control with explicit
defer/shed decisions, and the τ-elastic degradation controller that turns
SmoothCache's error budget into a *load* control — under overload traffic
moves to a higher τ rung of the same artifact (more layer-output reuse,
cheaper steps, zero new compiles) instead of queueing into deadline
misses::

    from repro import serve, slo

    store = serve.ArtifactStore(cfg, solver, cfg_scale=1.5)
    ladder = store.add_ladder(
        "gen", "dit.cache.json",
        spec="adaptive:base=smoothcache(alpha=0.18),tau=[0.0,0.05,0.2]")

    ctrl = slo.ElasticTauController(len(ladder.taus), target_p95_wait_s=2.0)
    eng = serve.ServeEngine(
        ex, params, store,
        scheduler=slo.ElasticPolicy(ctrl),
        admission=slo.AdmissionController(max_backlog_s=30.0,
                                          aging_rate=0.5))
    eng.submit(serve.Request(rid=0, seed=7, policy="gen",
                             slo=slo.SLO(deadline=eng.clock.now() + 10.0,
                                         max_tau=0.05)))

Layering: this package never imports the engine — it talks to it through
the policy interface — so ``repro.serve`` stays usable without SLOs and
the engine resolves string schedulers through :func:`resolve_policy`
lazily.
"""
from repro.slo.admission import (  # noqa: F401
    ADMIT, AdmissionController, AdmissionDecision, LoadEstimator,
    ServiceCostModel)
from repro.slo.controller import ElasticTauController  # noqa: F401
from repro.slo.policy import (  # noqa: F401
    EDFPolicy, ElasticPolicy, FairnessPolicy, FcfsPolicy, SchedulingPolicy,
    resolve_policy)
from repro.slo.slo import (  # noqa: F401
    SLO, batch_deadline, remaining_steps, slack)
from repro.slo.trace import RequestClass, overload_trace  # noqa: F401
