"""Pluggable scheduling policies for the serve engine.

:class:`~repro.serve.engine.ServeEngine` used to hardcode an
``interleave``/``fcfs`` string; it now takes a policy *object* behind one
small interface — ``select`` picks which in-flight micro-batch advances
this tick, ``rotate`` says whether the advanced batch moves to the back of
the rotation, ``on_finish`` observes completed batches (the elastic
policy's feedback tap).  Strings still work for the built-ins
(:func:`resolve_policy` keeps every existing callsite source-compatible).

Policies:

* ``interleave`` (:class:`FairnessPolicy`) — round-robin timeslicing, the
  pre-SLO default: always advance the head, rotate it to the back.
* ``fcfs`` (:class:`FcfsPolicy`) — run the head to completion (the convoy
  baseline).
* ``edf`` (:class:`EDFPolicy`) — earliest-deadline-first by *slack*:
  ``min member deadline − now − remaining_steps × calibrated step cost``,
  so urgency reflects work left, not just deadlines.  Deadline-less
  batches have infinite slack and fall back to round-robin among
  themselves.  Preemption happens only at the engine's advance
  granularity (a plan segment / an adaptive step-chunk) — a batch is
  never torn mid-program.
* ``elastic`` (:class:`ElasticPolicy`) — EDF ordering plus the
  :class:`~repro.slo.controller.ElasticTauController` feedback loop: every
  finished batch's member queue waits feed the controller, and a rung
  change is pushed to the store's τ ladders (zero new compiles — see
  controller module docs).  Needs a constructed controller, so it has no
  bare-string form.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

from repro.slo.controller import ElasticTauController
from repro.slo.slo import batch_deadline, remaining_steps


class SchedulingPolicy:
    """Interface: which in-flight run advances, and what to observe."""

    name = "policy"

    def select(self, engine, now: float) -> int:
        """Index into ``engine._inflight`` of the run to advance."""
        return 0

    def rotate(self) -> bool:
        """Move the advanced (unfinished) run to the back of the list?"""
        return False

    def on_finish(self, engine, record, requests: Sequence,
                  now: float) -> None:
        """Observe a completed micro-batch (record + member requests)."""


class FairnessPolicy(SchedulingPolicy):
    """Round-robin timeslicing (the historical ``interleave``)."""

    name = "interleave"

    def rotate(self) -> bool:
        return True


class FcfsPolicy(SchedulingPolicy):
    """Run the head micro-batch to completion (convoy baseline)."""

    name = "fcfs"


class EDFPolicy(SchedulingPolicy):
    """Least-slack-first over in-flight micro-batches."""

    name = "edf"

    def select(self, engine, now: float) -> int:
        best, best_slack = 0, math.inf
        step_cost = engine.cost_model
        for i, fl in enumerate(engine._inflight):
            dl = batch_deadline(fl.mb.requests)
            if dl is math.inf:
                continue
            rem = remaining_steps(fl.rs) * step_cost.per_step(fl.mb.group)
            s = dl - now - rem
            if s < best_slack:
                best, best_slack = i, s
        return best

    def rotate(self) -> bool:
        # deadline-less runs all tie at infinite slack; rotating keeps
        # them round-robin fair instead of convoying behind index 0
        return True


class ElasticPolicy(EDFPolicy):
    """EDF + the τ-elastic controller feedback tap.

    ``ladders`` restricts which store ladders the controller drives
    (default: every ladder registered in the engine's store)."""

    name = "elastic"

    def __init__(self, controller: ElasticTauController,
                 ladders: Optional[Sequence[str]] = None):
        self.controller = controller
        self.ladders = tuple(ladders) if ladders is not None else None

    def on_finish(self, engine, record, requests: Sequence,
                  now: float) -> None:
        for r in requests:
            w = r.queue_wait
            if w is not None:
                self.controller.observe_wait(w, now)
        rung = self.controller.update(now)
        if rung is not None:
            for name in (self.ladders if self.ladders is not None
                         else engine.store.ladders()):
                engine.store.set_rung(name, rung)


_BUILTINS = {
    "interleave": FairnessPolicy,
    "fairness": FairnessPolicy,
    "fcfs": FcfsPolicy,
    "edf": EDFPolicy,
}


def resolve_policy(spec) -> SchedulingPolicy:
    """A policy object passes through; a string resolves a built-in.
    ``elastic`` has no string form — it needs a constructed controller
    (``ElasticPolicy(ElasticTauController(...))``)."""
    if isinstance(spec, SchedulingPolicy):
        return spec
    if spec == "elastic":
        raise ValueError(
            "the elastic policy needs a controller: pass "
            "ElasticPolicy(ElasticTauController(num_rungs, target)) "
            "instead of the string 'elastic'")
    if spec not in _BUILTINS:
        raise ValueError(
            f"scheduler must be one of {sorted(_BUILTINS)} (or a "
            f"SchedulingPolicy object), got {spec!r}")
    return _BUILTINS[spec]()
