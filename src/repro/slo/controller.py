"""τ-elastic degradation controller.

SmoothCache's error budget τ is the one load lever an LLM server does not
have: under overload the deployment can *degrade quality smoothly* —
serve at a higher τ rung, reusing more layer outputs per step — instead of
queueing into deadline misses or dropping requests.  The mechanism is the
τ **ladder** registered in the
:class:`~repro.serve.store.ArtifactStore`: several rungs of the *same*
artifact, identical schedule / candidate pool / proxy→error map, differing
only in the runtime threshold τ.  Because the fused adaptive path passes
τ (and ``k_max``) as *traced scalar arguments* of the one
``lax.switch`` program per batch bucket, moving between rungs compiles
**zero** new XLA programs — rung changes are a host-side pointer swap.

:class:`ElasticTauController` closes the loop: it observes realized queue
waits (fed by the ``elastic`` scheduling policy from finished batches),
compares the rolling p95 against ``target_p95_wait_s``, and moves the
active rung up (degrade) or down (recover).  Flap suppression is
threefold — a dead band around the target, a cooldown after any change,
and a ``settle`` count of consecutive calm windows required before
stepping back down — asserted by the hysteresis test on a steady trace.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple


def _p95(xs: Sequence[float]) -> float:
    """Linear-interpolation p95 (local so the slo layer stays free of
    serve imports; same definition as repro.serve.metrics.percentile)."""
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    rank = 0.95 * (len(s) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (rank - lo))


class ElasticTauController:
    """Feedback loop: measured p95 queue wait vs target → ladder rung.

    ``update(now)`` evaluates at most once per ``interval_s`` and needs at
    least ``min_samples`` waits in the window:

    * p95 > target × (1 + band)  →  step **up** one rung (more reuse,
      cheaper steps) — at most once per ``cooldown_s``;
    * p95 < target × (1 − band) for ``settle`` consecutive windows →
      step **down** one rung (recover quality);
    * otherwise hold.

    The wait window is cleared on every rung change so the next decision
    measures the *new* operating point rather than averaging across the
    transition.  ``history`` records ``(time, rung, p95)`` at each change
    for tests and the benchmark's controller trace."""

    def __init__(self, num_rungs: int, target_p95_wait_s: float, *,
                 window: int = 64, min_samples: int = 4,
                 interval_s: float = 1.0, band: float = 0.25,
                 cooldown_s: float = 3.0, settle: int = 2,
                 start_rung: int = 0, registry=None, tracer=None):
        if num_rungs < 1:
            raise ValueError(f"num_rungs must be >= 1, got {num_rungs}")
        if target_p95_wait_s <= 0:
            raise ValueError(f"target_p95_wait_s must be > 0, got "
                             f"{target_p95_wait_s}")
        if not 0 <= band < 1:
            raise ValueError(f"band must be in [0, 1), got {band}")
        if not 0 <= start_rung < num_rungs:
            raise ValueError(f"start_rung {start_rung} outside ladder of "
                             f"{num_rungs} rungs")
        self.num_rungs = int(num_rungs)
        self.target = float(target_p95_wait_s)
        self.window = int(window)
        self.min_samples = max(int(min_samples), 1)
        self.interval_s = float(interval_s)
        self.band = float(band)
        self.cooldown_s = float(cooldown_s)
        self.settle = max(int(settle), 1)
        self.rung = int(start_rung)
        #: optional observability hooks (repro.obs): the registry gets
        #: ``slo.p95_wait_s`` / ``slo.rung`` ring-buffer time series at
        #: every evaluation (not just changes — trajectories need the
        #: holds too); the tracer gets a ``rung_move`` instant per change
        self.registry = registry
        self.tracer = tracer
        self.history: List[Tuple[float, int, float]] = []
        self._waits: Deque[float] = deque(maxlen=self.window)
        self._last_eval: Optional[float] = None
        self._last_change: Optional[float] = None
        self._calm = 0

    def observe_wait(self, wait_s: float, now: float) -> None:
        self._waits.append(float(wait_s))

    def _cooled(self, now: float) -> bool:
        return (self._last_change is None
                or now - self._last_change >= self.cooldown_s)

    def _move(self, now: float, rung: int, p95: float) -> int:
        old = self.rung
        self.rung = rung
        self.history.append((now, rung, p95))
        self._last_change = now
        self._waits.clear()
        self._calm = 0
        if self.tracer is not None:
            self.tracer.instant("rung_move", rung=rung, from_rung=old,
                                p95_wait_s=p95)
        if self.registry is not None:
            self.registry.series("slo.rung").record(now, float(rung))
        return rung

    def update(self, now: float) -> Optional[int]:
        """Evaluate the loop; returns the new rung index on a change,
        None otherwise."""
        if self._last_eval is not None \
                and now - self._last_eval < self.interval_s:
            return None
        if len(self._waits) < self.min_samples:
            return None
        self._last_eval = now
        p95 = _p95(self._waits)
        if self.registry is not None:
            self.registry.series("slo.p95_wait_s").record(now, p95)
        if p95 > self.target * (1 + self.band):
            self._calm = 0
            if self.rung + 1 < self.num_rungs and self._cooled(now):
                return self._move(now, self.rung + 1, p95)
            return None
        if p95 < self.target * (1 - self.band):
            self._calm += 1
            if self._calm >= self.settle and self.rung > 0 \
                    and self._cooled(now):
                return self._move(now, self.rung - 1, p95)
            return None
        self._calm = 0
        return None
