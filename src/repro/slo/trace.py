"""Deterministic SLO traffic traces.

The SLO tests and ``benchmarks/slo_bench.py`` need overload scenarios that
are fast and *exactly* reproducible: everything runs on a
:class:`~repro.serve.request.VirtualClock` with a seeded RNG, so scheduler
decisions, shed counts, and controller rung changes are bit-stable
assertions.  :func:`overload_trace` composes the existing
``poisson_arrivals`` helper into an **arrival-rate ramp**: a sequence of
``(rate, n)`` phases drained back-to-back (e.g. warm → surge → cool), with
each arrival assigned a traffic class by weight — its policy/ladder,
priority, per-class deadline draw (relative budget, turned absolute at the
arrival timestamp), and quality floor ``max_tau``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

from repro.serve.request import Request, poisson_arrivals
from repro.slo.slo import SLO

#: a fixed relative deadline budget, or a (lo, hi) uniform draw
Budget = Union[float, Tuple[float, float]]


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One traffic class of a synthetic trace."""
    name: str
    policy: str                               # store entry or ladder name
    weight: float = 1.0                       # class mix (relative)
    priority: int = 0
    deadline_budget: Optional[Budget] = None  # seconds after arrival
    max_tau: Optional[float] = None           # quality floor

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"class {self.name!r}: weight must be > 0, "
                             f"got {self.weight}")

    def draw_deadline(self, arrival: float, rng) -> Optional[float]:
        if self.deadline_budget is None:
            return None
        b = self.deadline_budget
        if isinstance(b, tuple):
            b = float(rng.uniform(b[0], b[1]))
        return arrival + float(b)


def overload_trace(classes: Sequence[RequestClass],
                   phases: Sequence[Tuple[float, int]], rng, *,
                   start: float = 0.0, rid_start: int = 0
                   ) -> List[Request]:
    """Build a rate-ramp trace: for each ``(rate, n)`` phase, ``n``
    Poisson arrivals at ``rate`` req/s continuing from the previous
    phase's last arrival; each request draws its class by weight and its
    deadline from the class budget.  ``rng`` is a seeded numpy
    RandomState/Generator — same seed, same trace."""
    if not classes:
        raise ValueError("overload_trace needs at least one RequestClass")
    total_w = sum(c.weight for c in classes)
    reqs: List[Request] = []
    t = float(start)
    rid = rid_start
    for rate, n in phases:
        arrivals = poisson_arrivals(rate, n, rng, start=t)
        if arrivals:
            t = arrivals[-1]
        for a in arrivals:
            u = float(rng.uniform(0.0, total_w))
            acc, cls = 0.0, classes[-1]
            for c in classes:
                acc += c.weight
                if u < acc:
                    cls = c
                    break
            slo = SLO(deadline=cls.draw_deadline(a, rng),
                      max_tau=cls.max_tau, cls=cls.name)
            reqs.append(Request(rid=rid, seed=rid, policy=cls.policy,
                                priority=cls.priority, arrival=a, slo=slo))
            rid += 1
    return reqs
