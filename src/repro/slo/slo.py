"""Per-request service-level objectives.

An :class:`SLO` rides on a :class:`~repro.serve.request.Request` and makes
three production intents explicit:

* ``deadline`` — absolute engine-clock time by which the result must be
  materialized.  Deadlines drive EDF scheduling (urgency replaces
  round-robin), admission infeasibility shedding, and the attainment /
  goodput accounting in :class:`~repro.serve.metrics.ServerMetrics`.
* ``max_tau`` — the request's *quality floor*, expressed as the largest
  SmoothCache error budget τ it tolerates.  The elastic controller may
  degrade bulk traffic to a higher τ rung under load, but a capped request
  is only ever served at a rung with ``tau <= max_tau`` (or shed with
  reason ``quality_floor`` when no registered rung qualifies).
* ``cls`` — a priority-class label for metrics and trace generation; the
  scheduling weight itself stays ``Request.priority``.

Deadlines compose with the executor's resumable-run surface through
:func:`remaining_steps`: every run state (static-plan, adaptive,
fused-adaptive, and the test fakes) exposes how many sampling steps are
left, so slack is estimated as ``deadline - now - remaining_steps ×
calibrated_step_cost`` and a micro-batch is preempted only at
segment/chunk boundaries — exactly the granularity the engine's
``advance`` already uses.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class SLO:
    """Service-level objective of one request (all fields optional — a
    bare ``SLO()`` is equivalent to no SLO at all)."""
    deadline: Optional[float] = None          # absolute engine-clock time
    max_tau: Optional[float] = None           # quality floor: largest τ ok
    cls: str = "default"                      # class label (metrics/traces)

    def __post_init__(self):
        if self.max_tau is not None and self.max_tau < 0:
            raise ValueError(f"max_tau must be >= 0, got {self.max_tau}")

    def attained(self, finished: Optional[float]) -> bool:
        """Did a request finishing at ``finished`` meet this SLO?  A shed
        request (``finished is None``) never attains; without a deadline
        any finish attains."""
        if finished is None:
            return False
        return self.deadline is None or finished <= self.deadline


def remaining_steps(rs) -> int:
    """Sampling steps left in a resumable run state.

    Every executor run state exposes ``num_steps``/``step`` (the adaptive
    and fused states directly, the static-plan state via properties); plan
    states that predate those properties are handled through
    ``plan.runs[run_index:]``.  Eager stand-ins without either shape count
    as 0 — they complete in one advance."""
    num = getattr(rs, "num_steps", None)
    step = getattr(rs, "step", None)
    if num is not None and step is not None:
        return max(int(num) - int(step), 0)
    plan = getattr(rs, "plan", None)
    idx = getattr(rs, "run_index", None)
    if plan is not None and idx is not None:
        return sum(run.length for run in plan.runs[idx:])
    return 0


def batch_deadline(requests: Sequence) -> float:
    """Earliest member deadline of a micro-batch (``inf`` when no member
    carries one) — the quantity EDF orders in-flight batches by."""
    dls = [r.deadline for r in requests
           if getattr(r, "deadline", None) is not None]
    return min(dls) if dls else math.inf


def slack(deadline: Optional[float], now: float,
          est_remaining_s: float) -> float:
    """Estimated time to spare: ``deadline - now - est_remaining_s``
    (``inf`` without a deadline).  Negative slack means the deadline will
    be missed even if the run is serviced exclusively from now on."""
    if deadline is None:
        return math.inf
    return deadline - now - est_remaining_s
