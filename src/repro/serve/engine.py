"""Step-interleaved continuous-batching serving engine.

The engine drains a :class:`~repro.serve.request.RequestQueue` through the
executor's **resumable stepping API** (``start_run`` / ``advance_run`` for
static plans — one :class:`~repro.core.plan.ExecutionPlan` segment per
advance — and, for adaptive entries, ``start_adaptive_fused_run`` /
``advance_adaptive_fused`` when the executor supports the fused path: a
whole ``adaptive_chunk`` of steps in ONE donated program dispatch, with
the reuse decisions made on device, so timeslicing adaptive runs costs
zero per-step host round-trips.  Non-scannable solvers fall back to the
host-dispatched ``start_adaptive_run`` / ``advance_adaptive_run`` loop —
one decision sync + program dispatch per step).  Several in-flight
micro-batches timeslice the device: which one advances each tick is
decided by a pluggable :class:`repro.slo.SchedulingPolicy` — the default
``interleave`` (round-robin, so a short, heavily-cached schedule admitted
behind a full-compute one finishes early instead of convoying behind it),
``fcfs`` (the convoy baseline), ``edf`` (least-slack-first over member
deadlines, remaining-steps-aware), or an ``elastic`` policy object that
additionally drives the store's τ ladders from measured p95 waits.
Preemption granularity is the advance unit (plan segment / adaptive
chunk) — a batch is never torn mid-program.

SLO semantics (all optional — without them the engine behaves exactly as
before): requests may carry a :class:`repro.slo.SLO`; each tick first
runs an SLO sweep that sheds quality-infeasible requests (no registered
rung at or below the request's ``max_tau``) and, when an
:class:`repro.slo.AdmissionController` is installed, sheds/defers against
the estimated backlog (queue depth × the online-calibrated per-step
service cost).  Every rejection is recorded with a reason in
``ServeEngine.shed`` and the metrics — check :meth:`ServeEngine.outcome`
for any rid.

Determinism contract: a micro-batch over requests ``[r0..rn-1]`` samples
with ``batch_key(seeds)`` — serving a batch is *bit-identical* to calling
``DiffusionPipeline.generate(params, batch_key(seeds), n, label=...)``
with the same store entry, because start+advance-until-done executes
exactly the ops of ``sample_with_plan`` / ``sample_adaptive``
(``tests/test_serve.py`` asserts this end-to-end).

Compiled-program budget: programs specialize on (signature, batch shape),
so the engine's compile count is bounded by |buckets used| ×
|signature pool| across all entries — reported by :meth:`ServeEngine.report`
against the executor's ``xla_program_count``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import NULL_TRACER, MetricsRegistry, run_cache_reports
from repro.resilience.faults import NAN_LATENT, STUCK_BATCH, BatchFault
from repro.serve.batcher import MicroBatch, MicroBatcher, bucket_sizes
from repro.serve.metrics import ServerMetrics
from repro.serve.request import Request, RequestQueue, WallClock
from repro.serve.store import ArtifactStore

#: built-in scheduler names (resolved through repro.slo.resolve_policy;
#: "elastic" additionally exists as a policy *object* since it needs a
#: constructed controller)
SCHEDULERS = ("interleave", "fcfs", "edf")


def batch_key(seeds: Sequence[int]):
    """Deterministic PRNG key of a micro-batch: a fold of the member
    requests' seeds (order-sensitive — the batch row order).  Exposed so
    tests and clients can replay any served batch through
    ``DiffusionPipeline.generate`` and get bit-identical latents."""
    key = jax.random.PRNGKey(len(seeds))
    for s in seeds:
        # full 32-bit fold: seeds differing only in bit 31 must not
        # collapse to the same key
        key = jax.random.fold_in(key, jnp.uint32(int(s) & 0xFFFFFFFF))
    return key


@dataclasses.dataclass
class BatchRecord:
    """Provenance of one served micro-batch (enough to replay it)."""
    group: str
    version: int
    bucket: int
    rids: Tuple[int, ...]
    seeds: Tuple[int, ...]
    labels: Tuple[Optional[int], ...]
    num_steps: int
    compute_fraction: float
    formed_at: float
    finished_at: float
    decisions: Optional[Tuple[tuple, ...]] = None   # adaptive runs only
    tau: float = 0.0                          # realized τ (rung at launch)
    quality_cost: Optional[float] = None      # predicted, from proxy map
    #: continuous-batching provenance: every join / regroup / coalesce /
    #: split-retry event this batch's run-state went through, in order
    #: (``join@<step>:<rids>``, ``regroup@<step>:<rids>``, …).  Empty for
    #: a batch that rode formation → finish unchanged; with per-row keys
    #: replay stays per-request (``generate(params, batch_key([seed]),
    #: 1)``) no matter the lineage.
    lineage: Tuple[str, ...] = ()


class _EagerState:
    """Run-state stand-in for the ``--eager`` escape hatch (whole batch
    sampled in one advance; no interleaving)."""

    def __init__(self):
        self.x = None
        self.decisions = None

    @property
    def done(self) -> bool:
        return self.x is not None


@dataclasses.dataclass
class _Inflight:
    mb: MicroBatch
    kind: str                                 # "plan" | "adaptive" | "eager"
    rs: object
    label: object
    #: per-row health known so far (np bool, True = healthy); None = all
    #: healthy.  Monotone: a poisoned row never recovers mid-run.
    taint: object = None
    #: exclude this batch's service time from the cost-model EWMA (it
    #: faulted / stalled — retries must not poison admission estimates)
    cost_excluded: bool = False
    #: continuous-batching linkage: a *chaser* replays joiners from step 0
    #: up to its target's boundary (``chaser_for`` points at the parked
    #: target, whose ``parked_by`` points back); ``row_keyed`` records the
    #: per-row PRNG contract that makes join/split/regroup replayable
    #: per request; ``lineage`` accumulates the run-state's history
    chaser_for: object = None
    parked_by: object = None
    row_keyed: bool = False
    lineage: Tuple[str, ...] = ()
    #: observability: tracer track id of this run's span (0 = engine
    #: track, i.e. tracing disabled at launch) and the engine-wide batch
    #: serial the track is named after — merge/regroup/split events
    #: reference serials so lineage survives as span links in the trace
    track: int = 0
    serial: int = 0
    #: durability: boundary advances survived so far — the checkpoint
    #: cadence counter (a snapshot lands every ``checkpoint_every``-th)
    advances: int = 0


class ServeEngine:
    """Queue → batcher → interleaved executor runs → metrics."""

    def __init__(self, executor, params, store: ArtifactStore, *,
                 clock=None, max_batch: int = 8, max_wait: float = 0.0,
                 max_inflight: int = 2, scheduler="interleave",
                 adaptive_chunk: int = 4, eager: bool = False,
                 check: bool = False, admission=None, cost_model=None,
                 resilience=None, continuous: bool = False,
                 join_horizon: float = 0.5, tracer=None, registry=None,
                 telemetry: bool = False, journal=None, snapshot_dir=None,
                 checkpoint_every: int = 1):
        # lazy so repro.serve stays importable without the slo layer
        # loaded (and the layering acyclic: slo never imports the engine)
        from repro.slo.admission import LoadEstimator, ServiceCostModel
        from repro.slo.policy import resolve_policy
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if adaptive_chunk < 1:
            raise ValueError(f"adaptive_chunk must be >= 1, got "
                             f"{adaptive_chunk}")
        self.executor = executor
        self.params = params
        self.store = store
        self.clock = clock if clock is not None else WallClock()
        self.queue = RequestQueue(self.clock)
        self.batcher = MicroBatcher(self.queue, store, max_batch=max_batch,
                                    max_wait=max_wait)
        #: observability (repro.obs): one MetricsRegistry backs every
        #: ServerMetrics counter plus the controller/backlog time series;
        #: the tracer (NULL_TRACER by default — all hooks are no-ops)
        #: records the full batch lifecycle as Chrome trace events, one
        #: track per in-flight batch.  ``telemetry=True`` additionally
        #: asks fused adaptive runs to carry their per-step proxy values
        #: on device (read only at finish — zero extra host syncs) so
        #: every served request gets a :class:`repro.obs.CacheReport` in
        #: ``cache_reports``.
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        self.metrics = ServerMetrics(registry=self.registry)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if tracer is not None:
            store.tracer = tracer
            self.batcher.tracer = tracer
        self.telemetry = bool(telemetry)
        self.cache_reports: Dict[int, object] = {}   # rid → CacheReport
        self._serial = 0                      # batch serial (trace tracks)
        #: the scheduling policy object; ``scheduler`` may be a built-in
        #: name ("interleave"/"fcfs"/"edf") or any
        #: repro.slo.SchedulingPolicy (e.g. ElasticPolicy(controller))
        self.policy = resolve_policy(scheduler)
        self.scheduler = self.policy.name
        self.admission = admission            # repro.slo.AdmissionController
        self.cost_model = (cost_model if cost_model is not None
                           else ServiceCostModel())
        self.load = LoadEstimator(self.cost_model,
                                  batch_factor=max_batch)
        if not (0.0 <= join_horizon <= 1.0):
            raise ValueError(f"join_horizon must be in [0, 1], got "
                             f"{join_horizon}")
        self.max_inflight = max_inflight
        self.adaptive_chunk = adaptive_chunk
        self.eager = eager
        self.check = check
        #: continuous in-flight batching: waiting compatible requests may
        #: join an in-flight run at its next boundary (catch-up chaser +
        #: run-state merge), and τ>0 fused batches regroup by realized
        #: mask signature.  Requires an executor with ``split_run``/
        #: ``merge_runs`` and a deterministic solver; launches switch to
        #: per-row PRNG keys so each request replays as
        #: ``generate(params, batch_key([seed]), 1)``.
        self.continuous = continuous
        #: latest join point as a fraction of the run (a joiner replays
        #: the target's past steps, so late joins cost more than they
        #: save)
        self.join_horizon = float(join_horizon)
        #: repro.resilience.ResiliencePolicy, or None — None keeps the
        #: exact pre-resilience behavior: no health reads, no watchdog,
        #: BatchFaults propagate, the stall guard raises
        self.resilience = resilience
        if resilience is not None and resilience.entry_fault_threshold \
                is not None:
            store.health.fault_threshold = resilience.entry_fault_threshold
        self.results: Dict[int, np.ndarray] = {}
        self.records: List[BatchRecord] = []
        self.shed: Dict[int, Tuple[str, float]] = {}   # rid → (reason, t)
        self._inflight: List[_Inflight] = []
        self._rids: set = set()               # every rid ever submitted
        self._attempts: Dict[int, int] = {}   # rid → fault retry count
        self._requeues: Dict[int, int] = {}   # rid → survivor re-queues
        self._level: Dict[int, int] = {}      # rid → degradation level
        self._origin: Dict[int, str] = {}     # rid → group first submitted
        #: durability (repro.durable): optional write-ahead journal +
        #: boundary run-state snapshots.  Both lazily imported so an
        #: engine without them never touches msgpack; ``journal`` may be
        #: a path or a constructed RequestJournal; ``recover()`` replays
        #: both after a restart.
        if checkpoint_every < 1:
            raise ValueError(f"checkpoint_every must be >= 1, got "
                             f"{checkpoint_every}")
        self.checkpoint_every = int(checkpoint_every)
        self.journal = None
        if journal is not None:
            from repro.durable import RequestJournal
            self.journal = (journal if isinstance(journal, RequestJournal)
                            else RequestJournal(str(journal)))
        self._snapshots = None
        if snapshot_dir is not None:
            if not getattr(executor, "supports_export", False):
                raise ValueError(
                    "snapshot_dir= needs an executor with run-state "
                    "export/import seams (supports_export)")
            from repro.durable import SnapshotStore
            self._snapshots = SnapshotStore(str(snapshot_dir))
        self._done: set = set()               # journal-known finishes
        self._sweep_needed = (admission is not None
                              or resilience is not None)

    # -- submission ----------------------------------------------------------

    def submit(self, *reqs: Request) -> None:
        """Enqueue requests (arrival stamped now unless preset).

        Invalid submissions become *reasoned outcomes*, never exceptions
        that would kill a serving loop mid-stream: an unknown policy name
        is recorded as a ``no_entry`` shed (``outcome(rid)`` reports it),
        and a duplicate rid — against *every* rid ever submitted (queued,
        in flight, done, or earlier in this very call), since a duplicate
        would silently overwrite its sibling's result — is dropped and
        counted, leaving the original request's outcome untouched."""
        now = self.clock.now()
        accepted = []
        recs = []
        for r in reqs:
            if r.rid in self._rids:
                self.metrics.observe_reject("duplicate_rid")
                self.tracer.instant("reject", rid=r.rid,
                                    reason="duplicate_rid")
                continue
            if r.policy not in self.store:
                self._rids.add(r.rid)
                self.shed[r.rid] = ("no_entry", now)
                self.metrics.observe_shed(r, "no_entry", now)
                self.metrics.observe_reject("no_entry")
                self.tracer.instant("reject", rid=r.rid, reason="no_entry")
                if self.journal is not None:
                    recs.append(self._submit_rec(r, now))
                    recs.append({"ev": "shed", "rid": r.rid,
                                 "reason": "no_entry", "t": now})
                continue
            self._rids.add(r.rid)
            accepted.append(r)
            if self.journal is not None:
                recs.append(self._submit_rec(r, now))
            if getattr(r, "max_tau", None) is not None:
                self._sweep_needed = True
            if self.tracer.enabled:
                self.tracer.instant("submit", rid=r.rid, policy=r.policy,
                                    priority=r.priority)
        if recs:
            # the write-ahead contract: a submission is on disk (fsynced)
            # before the queue can act on it — a crash after this line
            # cannot lose an accepted request
            self.journal.append_many(recs, sync=True)
        self.queue.submit_many(accepted)

    def outcome(self, rid: int):
        """Explicit fate of a submitted request — requests are never
        silently dropped: ``("done", latent)``, ``("shed", reason)``, or
        ``("pending", None)``.  After a restart the *verdict* of a
        pre-crash finish survives via the journal — ``("done", None)``:
        the latent payload itself is not journaled (it was delivered
        before the crash), but the request is provably not lost."""
        if rid not in self._rids:
            raise KeyError(f"rid {rid} was never submitted")
        if rid in self.results:
            return ("done", self.results[rid])
        if rid in self.shed:
            return ("shed", self.shed[rid][0])
        if rid in self._done:
            return ("done", None)
        return ("pending", None)

    # -- durability plumbing --------------------------------------------------

    def _submit_rec(self, r: Request, now: float) -> Dict:
        """The journaled form of one submission — everything needed to
        rebuild the Request verbatim after a restart (original arrival
        included, so re-admission never launders queue wait)."""
        rec = {"ev": "submit", "rid": r.rid, "seed": int(r.seed),
               "policy": r.policy,
               "arrival": float(r.arrival) if r.arrival is not None
               else float(now)}
        if r.label is not None:
            rec["label"] = int(r.label)
        if r.priority:
            rec["priority"] = int(r.priority)
        if r.slo is not None:
            rec["slo"] = {"deadline": r.slo.deadline,
                          "max_tau": r.slo.max_tau, "cls": r.slo.cls}
        return rec

    def _journal(self, ev: str, *, sync: bool = True, **fields) -> None:
        if self.journal is not None:
            self.journal.append(ev, sync=sync, **fields)

    def _drop_snapshot(self, fl: "_Inflight") -> None:
        """The run left flight (finished / faulted / merged away /
        regrouped / split) — its snapshot no longer describes anything."""
        if self._snapshots is not None:
            self._snapshots.drop(fl.serial)

    # -- SLO sweep (quality floors + admission) -------------------------------

    def _backlog_seconds(self, now: float) -> float:
        """Load estimate: queued steps (batch-amortized) + in-flight
        remaining steps, priced at the calibrated per-step cost."""
        from repro.slo.slo import remaining_steps
        queued = []
        for g in self.queue.ready_groups(now):
            for r in self.queue.peek(g, now):
                e = self.store.resolve_entry_for(g, r)
                queued.append(e.plan.num_steps if e is not None else 0)
        inflight = [remaining_steps(fl.rs) for fl in self._inflight]
        return self.load.backlog_seconds(queued, inflight)

    def _shed(self, req: Request, reason: str, now: float) -> None:
        self.queue.take_rids(req.policy, [req.rid], now)
        self.shed[req.rid] = (reason, now)
        self.metrics.observe_shed(req, reason, now)
        self.tracer.instant("shed", rid=req.rid, reason=reason)
        self._journal("shed", rid=req.rid, reason=reason, t=float(now))

    def _slo_sweep(self, now: float) -> None:
        """Walk the ready queue: shed requests whose quality floor no
        registered rung satisfies, then let the admission controller
        shed/defer against the backlog estimate.  The backlog is
        snapshotted once per sweep so decisions are order-independent."""
        if not self._sweep_needed:
            return
        backlog = None
        for g in list(self.queue.ready_groups(now)):
            for r in self.queue.peek(g, now):
                entry = self.store.resolve_entry_for(g, r)
                if entry is None:
                    # distinguish "this entry was marked unhealthy by the
                    # fault registry" from "no rung satisfies the floor"
                    reason = ("unhealthy_entry"
                              if not self.store.health.is_servable(g)
                              else "quality_floor")
                    self._shed(r, reason, now)
                    continue
                if self.admission is None:
                    continue
                if backlog is None:
                    backlog = self._backlog_seconds(now)
                    self.registry.series("slo.backlog_s").record(now,
                                                                 backlog)
                est = self.cost_model.estimate(entry.plan.num_steps,
                                               group=entry.name)
                d = self.admission.decide(r, now, backlog_s=backlog,
                                          est_service_s=est)
                if d.action == "shed":
                    self._shed(r, d.reason, now)
                elif d.action == "defer":
                    self.queue.take_rids(g, [r.rid], now)
                    self.metrics.observe_defer(r, now)
                    self.tracer.instant("defer", rid=r.rid,
                                        retry_at=d.retry_at)
                    self.queue.resubmit(r, d.retry_at)

    # -- scheduling ----------------------------------------------------------

    def _active_inflight(self) -> int:
        """In-flight runs that actually advance — parked join targets
        wait on their chaser and don't occupy a timeslice."""
        return sum(1 for f in self._inflight if f.parked_by is None)

    def _admit(self, now: float) -> None:
        while self._active_inflight() < self.max_inflight:
            mb = self.batcher.next_batch(now)
            if mb is None:
                break
            self._launch(mb, now)
        if self.continuous:
            self._join_waiting(now)

    def _begin_track(self, mb: MicroBatch, kind: str, *, parent=None,
                     via=None, chaser_for=None) -> Tuple[int, int]:
        """Allocate the next batch serial and — when tracing — a tracer
        track with an open ``run`` span.  Lineage events (join / regroup /
        split_retry) name the parent serial in the child span's args, the
        trace-side mirror of ``BatchRecord.lineage``."""
        self._serial += 1
        serial, track = self._serial, 0
        if self.tracer.enabled:
            track = self.tracer.new_track(
                f"batch#{serial} {mb.entry.name} b{mb.bucket}")
            args = {"group": mb.entry.name, "version": mb.entry.version,
                    "bucket": mb.bucket, "kind": kind,
                    "rids": list(mb.rids)}
            if parent is not None:
                args["parent"] = parent
            if via is not None:
                args["via"] = via
            if chaser_for is not None:
                args["chaser_for"] = chaser_for
            self.tracer.begin(track, "run", **args)
        return serial, track

    def _launch(self, mb: MicroBatch, now: float, *,
                chaser_for=None) -> _Inflight:
        entry = mb.entry
        key = batch_key(mb.seeds)
        extra = {}
        row_keyed = False
        if (self.continuous and not self.eager
                and getattr(self.executor, "supports_split", False)):
            # per-row PRNG contract: row i's latent is the B=1 draw of
            # its own key, so join/split/regroup never change any
            # request's bits and replay is per-request
            extra["row_keys"] = [batch_key([s]) for s in mb.seeds]
            row_keyed = True
        label = None
        if any(lab is not None for lab in mb.labels):
            label = jnp.asarray([0 if lab is None else int(lab)
                                 for lab in mb.labels], jnp.int32)
        if self.eager:
            kind, rs = "eager", _EagerState()
        elif entry.adaptive and self._fused_adaptive:
            kind = "adaptive_fused"
            if self.telemetry:
                # decision-trace carry rides the fused program; passed
                # only when on so executors (and test fakes) without the
                # kwarg keep working
                extra["telemetry"] = True
            rs = self.executor.start_adaptive_fused_run(
                self.params, key, mb.bucket, schedule=entry.schedule,
                tau=entry.tau, proxy_map=entry.proxy_map,
                pool=entry.pool(), k_max=entry.k_max, label=label,
                **extra)
        elif entry.adaptive:
            kind = "adaptive"
            rs = self.executor.start_adaptive_run(
                self.params, key, mb.bucket, schedule=entry.schedule,
                tau=entry.tau, proxy_map=entry.proxy_map,
                pool=entry.pool(), k_max=entry.k_max, label=label,
                **extra)
        else:
            kind = "plan"
            rs = self.executor.start_run(
                self.params, key, mb.bucket, plan=entry.plan,
                schedule=entry.schedule, label=label, **extra)
        for r in mb.requests:
            r.started = now
        serial, track = self._begin_track(
            mb, kind,
            chaser_for=chaser_for.serial if chaser_for is not None
            else None)
        fl = _Inflight(mb=mb, kind=kind, rs=rs, label=label,
                       row_keyed=row_keyed, chaser_for=chaser_for,
                       track=track, serial=serial)
        self._inflight.append(fl)
        # progress event, not an ack — flushed, not fsynced: losing it in
        # a crash only re-launches the batch from its submit records
        self._journal("launch", sync=False, serial=serial, kind=kind,
                      entry=entry.name, version=entry.version,
                      bucket=mb.bucket, rids=list(mb.rids), t=float(now))
        return fl

    @property
    def _fused_adaptive(self) -> bool:
        """Serve adaptive entries through the fused on-device path when
        the executor offers it (scannable solver): one program per entry
        instead of pool-size × steps of dispatches, zero per-step
        decision syncs."""
        return bool(getattr(self.executor, "supports_fused_adaptive",
                            False))

    def _advance(self, fl: _Inflight) -> None:
        entry = fl.mb.entry
        if fl.kind == "plan":
            fl.rs = self.executor.advance_run(self.params, fl.rs,
                                              check=self.check)
        elif fl.kind == "adaptive_fused":
            # the whole chunk is one program dispatch — the timeslice
            # granularity costs no extra host round-trips.  A chaser
            # clamps to its parked target's boundary so the two align
            # exactly for the merge.
            n = self.adaptive_chunk
            if fl.chaser_for is not None:
                n = min(n, fl.chaser_for.rs.step - fl.rs.step)
            fl.rs = self.executor.advance_adaptive_fused(
                self.params, fl.rs, n_steps=max(n, 1))
        elif fl.kind == "adaptive":
            n = self.adaptive_chunk
            if fl.chaser_for is not None:
                n = min(n, fl.chaser_for.rs.step - fl.rs.step)
            for _ in range(max(n, 1)):
                if fl.rs.done:
                    break
                fl.rs = self.executor.advance_adaptive_run(self.params,
                                                           fl.rs)
        else:                                  # eager escape hatch
            key = batch_key(fl.mb.seeds)
            fl.rs.x = self.executor.sample(
                self.params, key, fl.mb.bucket, schedule=entry.schedule,
                label=fl.label)

    def _advance_traced(self, fl: _Inflight) -> None:
        """``_advance`` under a per-advance span on the batch's track —
        the try/finally keeps B/E pairs matched even when the advance
        raises (fault injection), so exported traces always validate."""
        tr = self.tracer
        if not tr.enabled or not fl.track:
            self._advance(fl)
            return
        args = {"kind": fl.kind}
        step = getattr(fl.rs, "step", None)
        if step is not None:
            args["step_from"] = int(step)
        if fl.kind == "plan":
            plan = getattr(fl.rs, "plan", None)
            ri = getattr(fl.rs, "run_index", None)
            if plan is not None and ri is not None \
                    and hasattr(plan, "run_label"):
                try:
                    args["segment"] = plan.run_label(int(ri))
                except (IndexError, TypeError):
                    pass
        tr.begin(fl.track, "advance", **args)
        try:
            self._advance(fl)
        finally:
            end = {}
            step = getattr(fl.rs, "step", None)
            if step is not None:
                end["step_to"] = int(step)
            tr.end(fl.track, "advance", **end)

    # -- continuous batching (join / regroup / coalesce) ---------------------

    @staticmethod
    def _p2_groups(rows: List[int]) -> List[List[int]]:
        """Decompose a row list into power-of-two-sized groups, largest
        first — every sub-run lands on an already-compiled bucket shape,
        so split/regroup never grow ``xla_program_count``."""
        out = []
        rows = list(rows)
        while rows:
            take = 1
            while take * 2 <= len(rows):
                take *= 2
            out.append(rows[:take])
            rows = rows[take:]
        return out

    def _is_linked(self, fl: _Inflight) -> bool:
        return (fl.parked_by is not None or fl.chaser_for is not None
                or any(o.chaser_for is fl for o in self._inflight))

    def _unlink(self, fl: _Inflight) -> None:
        """Detach a run leaving flight (fault/abort) from any join pair
        so its partner doesn't wait forever: a dying chaser unparks its
        target; a dying target releases its chaser to run to completion
        on its own."""
        if fl.chaser_for is not None and fl.chaser_for.parked_by is fl:
            fl.chaser_for.parked_by = None
        fl.chaser_for = None
        if fl.parked_by is not None:
            fl.parked_by.chaser_for = None
            fl.parked_by = None
        for o in self._inflight:
            if o.chaser_for is fl:
                o.chaser_for = None

    def _join_waiting(self, now: float) -> None:
        """Continuous feeder: waiting compatible requests join an
        in-flight run at its next boundary instead of queuing for a
        fresh slot.  The join is a *catch-up chaser*: the joiners launch
        as their own p2 batch at step 0 (their queue wait ends here),
        the target parks, the chaser replays to the target's boundary
        (clamped advances), and the two run-states merge — pure row
        concat, bit-identical per row — once aligned."""
        from repro.slo.slo import remaining_steps
        if not getattr(self.executor, "supports_split", False):
            return
        for fl in list(self._inflight):
            if (fl.kind == "eager" or not fl.row_keyed or fl.rs.done
                    or self._is_linked(fl)):
                continue
            steps = fl.mb.entry.plan.num_steps
            done_steps = steps - remaining_steps(fl.rs)
            if done_steps > self.join_horizon * steps:
                continue                      # too far gone to chase
            joiners = self.batcher.take_join(now, fl.mb.entry,
                                             fl.mb.bucket)
            if not joiners:
                continue
            mb = MicroBatch(requests=tuple(joiners), entry=fl.mb.entry,
                            formed_at=now)
            chaser = self._launch(mb, now, chaser_for=fl)
            fl.parked_by = chaser
            for r in joiners:
                r.joined_at = now
            self.metrics.observe_join(len(joiners))
            if self.tracer.enabled:
                self.tracer.instant(
                    "join", tid=fl.track, at_step=int(fl.rs.step),
                    chaser=chaser.serial,
                    rids=[r.rid for r in joiners])
            self._try_merge(chaser)           # step-0 target: merge now

    def _merge_pair(self, a: _Inflight, b: _Inflight,
                    tag: str) -> _Inflight:
        """Merge two aligned in-flight runs (rows of ``a`` first, matching
        ``merge_runs``'s concat order) into one new in-flight record."""
        merged_rs = self.executor.merge_runs([a.rs, b.rs])
        mb = MicroBatch(requests=a.mb.requests + b.mb.requests,
                        entry=a.mb.entry, formed_at=a.mb.formed_at)
        taint = None
        if a.taint is not None or b.taint is not None:
            ta = (a.taint if a.taint is not None
                  else np.ones(a.mb.bucket, bool))
            tb = (b.taint if b.taint is not None
                  else np.ones(b.mb.bucket, bool))
            taint = np.concatenate([ta, tb])
        label = None
        if any(lab is not None for lab in mb.labels):
            label = jnp.asarray([0 if lab is None else int(lab)
                                 for lab in mb.labels], jnp.int32)
        rids = ",".join(str(r) for r in b.mb.rids)
        # the merged run keeps a's track/serial — in the trace, b's span
        # ends here with a "merged into a" outcome (a span link by serial)
        if self.tracer.enabled and b.track:
            self.tracer.end(b.track, "run", outcome=f"merged:{tag}",
                            into=a.serial)
        # b's run-state is gone; a's snapshot (if any) is superseded at
        # its next boundary checkpoint and the rid-vs-pending staleness
        # check guards the window in between
        self._drop_snapshot(b)
        merged = _Inflight(
            mb=mb, kind=a.kind, rs=merged_rs, label=label, taint=taint,
            cost_excluded=a.cost_excluded or b.cost_excluded,
            row_keyed=True,
            lineage=a.lineage + b.lineage
            + (f"{tag}@{a.rs.step}:{rids}",),
            track=a.track, serial=a.serial)
        idx = self._inflight.index(a)
        self._inflight[idx] = merged
        self._inflight.remove(b)
        self.metrics.observe_merge(kind=tag)
        self.metrics.observe_lineage(tag)
        return merged

    def _try_merge(self, chaser: _Inflight) -> None:
        target = chaser.chaser_for
        if target is None or chaser.rs.step != target.rs.step:
            return
        target.parked_by = None
        chaser.chaser_for = None
        self._merge_pair(target, chaser, "join")

    def _maybe_regroup(self, fl: _Inflight) -> None:
        """At a fused chunk boundary, split a τ>0 batch whose rows now
        *want* different masks into per-signature sub-runs (p2 sizes
        only): each sub-run's executed mask is the AND over fewer rows,
        so cache-willing rows stop being dragged to full compute by one
        conservative neighbor."""
        if (fl.kind != "adaptive_fused" or fl.mb.entry.tau <= 0
                or fl.mb.bucket <= 1 or not fl.row_keyed or fl.rs.done
                or self._is_linked(fl)
                or not getattr(self.executor, "supports_split", False)):
            return
        sigs = fl.rs.row_signatures()
        if sigs is None or len(set(sigs)) <= 1:
            return
        bysig: Dict[tuple, List[int]] = {}
        for j, s in enumerate(sigs):
            bysig.setdefault(s, []).append(j)
        groups = []
        for s in sorted(bysig):               # deterministic order
            groups.extend(self._p2_groups(bysig[s]))
        subs = self.executor.split_run(fl.rs, groups)
        if self.tracer.enabled and fl.track:
            self.tracer.end(fl.track, "run",
                            outcome=f"regroup:{len(groups)}")
        self._drop_snapshot(fl)
        idx = self._inflight.index(fl)
        repl = []
        for g, sub in zip(groups, subs):
            mb = MicroBatch(
                requests=tuple(fl.mb.requests[j] for j in g),
                entry=fl.mb.entry, formed_at=fl.mb.formed_at)
            rids = ",".join(str(r.rid) for r in mb.requests)
            serial, track = self._begin_track(mb, fl.kind,
                                              parent=fl.serial,
                                              via="regroup")
            repl.append(_Inflight(
                mb=mb, kind=fl.kind, rs=sub, label=fl.label,
                taint=(None if fl.taint is None
                       else fl.taint[np.asarray(g)]),
                cost_excluded=fl.cost_excluded, row_keyed=True,
                lineage=fl.lineage
                + (f"regroup@{fl.rs.step}:{rids}",),
                track=track, serial=serial))
        self._inflight[idx:idx + 1] = repl
        self.metrics.observe_regroup(len(repl))
        self.metrics.observe_lineage("regroup", len(repl))

    def _coalesce(self) -> None:
        """Opportunistic reverse of regroup: two unlinked runs of the
        same entry/version/kind, aligned at the same step with equal
        buckets, merge back into one (2·b stays p2, so still on budget).
        A τ>0 fused pair must currently want the same mask — merging
        divergent rows would re-impose the shared-mask AND regroup just
        removed."""
        if not getattr(self.executor, "supports_split", False):
            return
        for a in list(self._inflight):
            if a not in self._inflight:
                continue
            if (a.kind == "eager" or not a.row_keyed or a.rs.done
                    or self._is_linked(a)):
                continue
            for b in list(self._inflight):
                if (b is a or b not in self._inflight
                        or a not in self._inflight):
                    continue
                if (b.kind != a.kind or not b.row_keyed or b.rs.done
                        or self._is_linked(b)
                        or b.mb.entry.name != a.mb.entry.name
                        or b.mb.entry.version != a.mb.entry.version
                        or b.mb.bucket != a.mb.bucket
                        or a.mb.bucket + b.mb.bucket
                        > self.batcher.max_batch
                        or b.rs.step != a.rs.step):
                    continue
                if a.kind == "adaptive_fused" and a.mb.entry.tau > 0:
                    sa, sb = a.rs.row_signatures(), b.rs.row_signatures()
                    if sa is None or sb is None or set(sa) != set(sb) \
                            or len(set(sa)) != 1:
                        continue
                self._merge_pair(a, b, "coalesce")

    # -- fault handling (degrade, don't die) ---------------------------------

    def _read_health(self, fl: _Inflight):
        """Merge the run state's sentinel flags into the in-flight taint
        record.  Returns the merged (B,) bool array, or None when neither
        the sentinels nor the chaos harness flagged anything.  Newly
        poisoned rows are counted as one fault event against the group."""
        flags = getattr(fl.rs, "healthy", None)
        if flags is None:
            return fl.taint
        cur = np.asarray(jax.device_get(flags)).astype(bool)
        if fl.taint is not None:
            cur = cur & fl.taint
        prev = fl.taint
        newly = (~cur) if prev is None else (prev & ~cur)
        if newly.any():
            self.metrics.observe_fault(fl.mb.group, NAN_LATENT)
            self.store.report_fault(fl.mb.group, NAN_LATENT)
        fl.taint = cur
        return cur

    def _fault_abort(self, fl: _Inflight, kind: str, sample_flags,
                     now: float, *, count: bool = True) -> None:
        """Abandon an in-flight batch after a fault.  Rows flagged healthy
        (per-sample resolution) or all rows (no resolution) *survive*:
        they re-queue at their original arrival time (``resubmit`` never
        touches ``arrival``, so queue-wait accounting keeps charging from
        first arrival).  Poisoned rows go down the degradation ladder via
        :meth:`_retry_or_fail`.  Survivors that keep landing in aborted
        batches are bounded too — past the retry budget they join the
        fault path instead of looping forever."""
        mb = fl.mb
        self._unlink(fl)
        self._drop_snapshot(fl)
        if self.tracer.enabled and fl.track:
            self.tracer.end(fl.track, "run", outcome=f"fault:{kind}")
        if count:
            self.metrics.observe_fault(mb.group, kind)
            self.store.report_fault(mb.group, kind)
        flags = sample_flags if sample_flags is not None else fl.taint
        budget = self.resilience.retry.max_retries
        for j, r in enumerate(mb.requests):
            ok = True if flags is None else bool(flags[j])
            if not ok:
                self._retry_or_fail(r, kind, now)
                continue
            n = self._requeues.get(r.rid, 0) + 1
            self._requeues[r.rid] = n
            if n > budget + 1:
                # repeatedly a bystander of dying batches — stop looping
                self._retry_or_fail(r, kind, now)
            else:
                r.started = None
                self.queue.resubmit(r, now)
                self.metrics.observe_requeue(1)

    def _retry_or_fail(self, r: Request, kind: str, now: float) -> None:
        """Bounded retry of one faulted request, stepping down the
        degradation ladder (current rung → τ=0 → no_cache) with
        deterministic backoff; past the budget the request ends as a
        reasoned terminal outcome (``fault:<kind>``), counted like any
        shed — never a crash, never a silent drop."""
        pol = self.resilience
        att = self._attempts.get(r.rid, 0) + 1
        self._attempts[r.rid] = att
        if att > pol.retry.max_retries:
            self.shed[r.rid] = (f"fault:{kind}", now)
            self.metrics.observe_shed(r, f"fault:{kind}", now)
            self.tracer.instant("shed", rid=r.rid, reason=f"fault:{kind}")
            self._journal("shed", rid=r.rid, reason=f"fault:{kind}",
                          t=float(now))
            return
        origin = self._origin.setdefault(r.rid, r.policy)
        if pol.degrade:
            level = self._level.get(r.rid, 0) + 1
            target = self.store.degraded_entry_name(origin, level)
            if target is None:    # no τ=0 form for this group: skip a rung
                level = 2
                target = self.store.degraded_entry_name(origin, level)
            self._level[r.rid] = level
            if target != r.policy:
                r.policy = target
                self.metrics.observe_degrade(r)
        r.started = None
        self.metrics.observe_retry(r)
        self.tracer.instant("retry", rid=r.rid, attempt=att,
                            policy=r.policy)
        self._journal("retry", sync=False, rid=r.rid, attempt=att,
                      policy=r.policy, level=self._level.get(r.rid, 0),
                      t=float(now))
        self.queue.resubmit(r, now + pol.retry.delay(att, r.rid))

    def _stall_shed(self, reason: str, now: float) -> None:
        """Degrade-don't-die replacement for the stall guard: every queued
        request gets an explicit shed outcome instead of the engine
        raising out of its serving loop."""
        recs = []
        for r in self.queue.drain_all():
            self.shed[r.rid] = (reason, now)
            self.metrics.observe_shed(r, reason, now)
            self.tracer.instant("shed", rid=r.rid, reason=reason)
            recs.append({"ev": "shed", "rid": r.rid, "reason": reason,
                         "t": float(now)})
        if recs and self.journal is not None:
            self.journal.append_many(recs, sync=True)

    def _watchdog_deadline(self, steps: int, group: str,
                           bucket: Optional[int] = None) -> float:
        # keyed on the same (rung, bucket) the cost model learns on, so
        # a ladder move or a regrouped bucket size gets its own deadline
        est = self.cost_model.estimate(max(int(steps), 1), group=group,
                                       bucket=bucket)
        return self.resilience.deadline(est)

    def _advance_guarded(self, i: int, fl: _Inflight) -> bool:
        """Advance under the fault net: a ``BatchFault`` raised
        mid-advance, a blown watchdog deadline, or sentinel-flagged rows
        all route into the recovery path instead of propagating.  Returns
        True when the batch was aborted (``fl`` removed from flight)."""
        from repro.slo.slo import remaining_steps
        pol = self.resilience
        before = self.clock.now()
        steps_before = remaining_steps(fl.rs)
        try:
            self._advance_traced(fl)
        except BatchFault as bf:
            self._inflight.pop(i)
            self._fault_abort(fl, bf.kind, bf.sample_flags,
                              self.clock.now())
            return True
        after = self.clock.now()
        if pol.watchdog_factor is not None:
            steps_adv = steps_before - remaining_steps(fl.rs)
            deadline = self._watchdog_deadline(steps_adv, fl.mb.group,
                                               fl.mb.bucket)
            if after - before > deadline:
                self.tracer.instant("watchdog_fire", tid=fl.track,
                                    group=fl.mb.group,
                                    elapsed_s=after - before,
                                    deadline_s=deadline)
                if fl.rs.done:
                    # too late to re-queue — deliver, but keep the stall
                    # out of the cost model and on the books
                    fl.cost_excluded = True
                    self.metrics.observe_fault(fl.mb.group, STUCK_BATCH)
                    self.store.report_fault(fl.mb.group, STUCK_BATCH)
                else:
                    self._inflight.pop(i)
                    self._fault_abort(fl, STUCK_BATCH, None, after)
                    return True
        flags = self._read_health(fl)
        if flags is not None and not flags.any() and not fl.rs.done:
            # every row is poisoned — nothing left worth carrying to the
            # finish line (already counted by _read_health)
            self._inflight.pop(i)
            self._fault_abort(fl, NAN_LATENT, flags, after, count=False)
            return True
        if (flags is not None and not flags.all() and not fl.rs.done
                and getattr(pol, "split_retry", False)
                and fl.mb.bucket > 1 and fl.kind != "eager"
                and not self._is_linked(fl)
                and getattr(self.executor, "supports_split", False)):
            # per-row retry within a continuing batch: faulted rows split
            # out and sent down the ladder NOW, survivors keep their
            # run-state (p2 sub-batches — no new shapes) instead of
            # dragging dead rows to the finish line
            self._split_retry(i, fl, flags, after)
            return True
        return False

    def _split_retry(self, i: int, fl: _Inflight, flags,
                     now: float) -> None:
        good = [j for j in range(fl.mb.bucket) if flags[j]]
        bad = [j for j in range(fl.mb.bucket) if not flags[j]]
        groups = self._p2_groups(good)
        subs = self.executor.split_run(fl.rs, groups)
        if self.tracer.enabled and fl.track:
            self.tracer.end(fl.track, "run",
                            outcome=f"split_retry:{len(bad)}")
        self._drop_snapshot(fl)
        self._inflight.pop(i)
        for g, sub in zip(groups, subs):
            mb = MicroBatch(
                requests=tuple(fl.mb.requests[j] for j in g),
                entry=fl.mb.entry, formed_at=fl.mb.formed_at)
            rids = ",".join(str(r.rid) for r in mb.requests)
            serial, track = self._begin_track(mb, fl.kind,
                                              parent=fl.serial,
                                              via="split_retry")
            self._inflight.append(_Inflight(
                mb=mb, kind=fl.kind, rs=sub, label=fl.label, taint=None,
                cost_excluded=fl.cost_excluded, row_keyed=fl.row_keyed,
                lineage=fl.lineage
                + (f"split_retry@{fl.rs.step}:{rids}",),
                track=track, serial=serial))
        for j in bad:
            self._retry_or_fail(fl.mb.requests[j], NAN_LATENT, now)
        self.metrics.observe_row_retry(len(bad))
        self.metrics.observe_lineage("split_retry", len(groups))

    def _finish(self, fl: _Inflight) -> None:
        mb, rs = fl.mb, fl.rs
        x = jax.block_until_ready(rs.x)
        done = self.clock.now()
        x = np.asarray(x)
        # service time of the whole batch, snapshotted before any faulted
        # row's re-queue resets its start stamp
        service = done - mb.requests[0].started
        flags = None
        if self.resilience is not None:
            # rows are computationally independent (attention is within-
            # sample, CFG splits per sample), so a poisoned row never
            # contaminates its neighbors: deliver the healthy rows —
            # bit-identical to an uninjected run — and send only the
            # poisoned ones down the ladder
            finite = np.isfinite(x.reshape(x.shape[0], -1)).all(axis=1)
            flags = finite if fl.taint is None else (fl.taint & finite)
            if flags.all():
                flags = None
            else:
                newly = ((~flags) if fl.taint is None
                         else (fl.taint & ~flags))
                if newly.any():
                    # final-latent check found poison the sentinels had
                    # not already counted (eager/fake paths without
                    # carry flags)
                    self.metrics.observe_fault(mb.group, NAN_LATENT)
                    self.store.report_fault(mb.group, NAN_LATENT)
        delivered = []
        for j, r in enumerate(mb.requests):
            if flags is not None and not flags[j]:
                self._retry_or_fail(r, NAN_LATENT, done)
                continue
            r.finished = done
            self.results[r.rid] = x[j]
            self.metrics.observe_request(r)
            delivered.append(r)
        if delivered and self.journal is not None:
            # ack event: the finish verdict is on disk before the engine
            # moves on — outcome(rid) survives the process
            self.journal.append("finish", sync=True,
                                rids=[r.rid for r in delivered],
                                t=float(done))
        for r in delivered:
            self._done.add(r.rid)
        self._drop_snapshot(fl)
        entry = mb.entry
        num_types = len(entry.schedule.skip)
        decisions = getattr(rs, "decisions", None)
        if decisions:
            skipped = sum(len(d) for d in decisions)
            frac = 1.0 - skipped / float(entry.plan.num_steps * num_types)
        else:
            frac = entry.compute_fraction()
        self.metrics.observe_batch(mb.group, mb.bucket, frac,
                                   entry.plan.num_steps, num_types)
        # feed the calibrated per-step cost model (service time of the
        # whole batch — includes interleaving contention, which is the
        # pessimism an admission wait estimate wants); faulted/stalled
        # batches are excluded so retries don't poison admission estimates
        if flags is None and not fl.cost_excluded:
            self.cost_model.observe(mb.group, service,
                                    entry.plan.num_steps,
                                    bucket=mb.bucket)
        qcost = entry.predicted_quality_cost(decisions)
        self.metrics.observe_quality(entry.tau, qcost, n=mb.bucket)
        if self.tracer.enabled and fl.track:
            self.tracer.end(fl.track, "run", outcome="done",
                            compute_fraction=frac)
        if self.telemetry:
            # per-request cache-decision explainers; one boundary read
            # per finished batch (the fused path device_gets its decision
            # trace exactly once here — zero per-step syncs)
            reports = run_cache_reports(rs, mb.bucket,
                                        schedule=entry.schedule,
                                        tau=entry.tau)
            for j, r in enumerate(mb.requests):
                if j < len(reports) and (flags is None or flags[j]):
                    self.cache_reports[r.rid] = reports[j]
        record = BatchRecord(
            group=mb.group, version=entry.version, bucket=mb.bucket,
            rids=mb.rids, seeds=mb.seeds, labels=mb.labels,
            num_steps=entry.plan.num_steps, compute_fraction=frac,
            formed_at=mb.formed_at, finished_at=done, decisions=decisions,
            tau=entry.tau, quality_cost=qcost, lineage=fl.lineage)
        self.records.append(record)
        self.policy.on_finish(self, record,
                              delivered if flags is not None
                              else mb.requests, done)

    # -- durability: boundary checkpoints + restart recovery ------------------

    def _maybe_checkpoint(self, fl: _Inflight) -> None:
        """Count a survived boundary advance; every
        ``checkpoint_every``-th one snapshots the run.  Eager runs have
        no boundaries (one advance = the whole batch) and finished runs
        are about to deliver — neither checkpoints."""
        if self._snapshots is None or fl.kind == "eager" or fl.rs.done:
            return
        fl.advances += 1
        if fl.advances % self.checkpoint_every:
            return
        self._checkpoint(fl)

    def _checkpoint(self, fl: _Inflight) -> None:
        """Snapshot one in-flight run (arrays via the executor's export
        seam, provenance-stamped meta via the entry).  Degrade, don't
        die: a failed write is counted and traced, never raised — the
        batch just loses restore coverage until the next boundary."""
        now = self.clock.now()
        entry = fl.mb.entry
        try:
            kind, arrays, static = self.executor.export_run(fl.rs)
            meta = dict(entry.provenance(), kind=kind, serial=fl.serial,
                        static=static, rids=list(fl.mb.rids),
                        seeds=[int(s) for s in fl.mb.seeds],
                        priorities=[int(r.priority)
                                    for r in fl.mb.requests],
                        formed_at=float(fl.mb.formed_at),
                        row_keyed=bool(fl.row_keyed),
                        lineage=list(fl.lineage), t=float(now))
            name, nbytes = self._snapshots.save(fl.serial, arrays, meta)
        except Exception as e:
            self.metrics.observe_checkpoint_error()
            self.tracer.instant("checkpoint_error", serial=fl.serial,
                                error=type(e).__name__)
            return
        self.metrics.observe_checkpoint(nbytes)
        step = static.get("step", static.get("run_index", 0))
        self._journal("checkpoint", sync=False, serial=fl.serial,
                      snapshot=name, step=int(step),
                      rids=list(fl.mb.rids), t=float(now))
        if self.tracer.enabled:
            self.tracer.instant("checkpoint", tid=fl.track, snapshot=name,
                                bytes=int(nbytes))

    def _rebuild_request(self, rec: Dict) -> Request:
        """Journal submit record → Request, verbatim (original arrival,
        label, priority, SLO)."""
        slo = None
        if rec.get("slo") is not None:
            from repro.slo import SLO
            s = rec["slo"]
            slo = SLO(deadline=s.get("deadline"),
                      max_tau=s.get("max_tau"),
                      cls=s.get("cls", "default"))
        return Request(rid=rec["rid"], seed=rec["seed"],
                       policy=rec["policy"], label=rec.get("label"),
                       priority=int(rec.get("priority", 0)), slo=slo,
                       arrival=rec.get("arrival"))

    def _refuse_snapshot(self, path: str, reason: str,
                         summary: Dict) -> None:
        """A snapshot that cannot be trusted (torn file, checksum
        mismatch, provenance drift, import failure): quarantined on disk
        and in the store's health ledger — its requests take the
        replay-from-start path, which the row-keys contract makes
        bit-identical anyway."""
        qname = self._snapshots.quarantine(path)
        self.store.health.quarantine(f"snapshot:{qname}", reason)
        summary["refused"].append((qname, reason))
        self.metrics.observe_snapshot_refused()
        self.tracer.instant("snapshot_refused", snapshot=qname,
                            reason=reason)

    def _restore_snapshot(self, path: str, pending: Dict, restored: set,
                          started: Dict, now: float,
                          summary: Dict) -> None:
        from repro.checkpoint import CheckpointError
        from repro.durable import SnapshotError
        try:
            arrays, meta = self._snapshots.load(path)
        except (CheckpointError, SnapshotError, OSError, ValueError) as e:
            self._refuse_snapshot(path, f"{type(e).__name__}: {e}",
                                  summary)
            return
        rids = list(meta.get("rids", ()))
        if not rids or any(r in restored for r in rids) \
                or not all(r in pending for r in rids):
            # superseded, not suspect: its requests already finished /
            # shed / were restored from a newer snapshot — silent delete
            self._snapshots.discard(path)
            summary["stale"] += 1
            return
        try:
            entry = self.store.get(meta.get("entry"))
        except KeyError:
            self._refuse_snapshot(
                path, f"entry {meta.get('entry')!r} no longer in store",
                summary)
            return
        prov = entry.provenance()
        for k in ("version", "schedule_fp", "plan_hash",
                  "artifact_checksum", "tau", "k_max"):
            if meta.get(k) != prov.get(k):
                self._refuse_snapshot(
                    path, f"provenance drift on {k}: snapshot "
                    f"{meta.get(k)!r} vs entry {prov.get(k)!r}", summary)
                return
        kind = meta.get("kind")
        kw = {}
        if kind == "plan":
            kw["plan"] = entry.plan
        else:
            kw.update(schedule=entry.schedule, tau=entry.tau,
                      proxy_map=entry.proxy_map, pool=entry.pool(),
                      k_max=entry.k_max)
        try:
            rs = self.executor.import_run(self.params, kind, arrays,
                                          meta["static"], **kw)
        except (KeyError, TypeError, ValueError) as e:
            self._refuse_snapshot(
                path, f"import failed: {type(e).__name__}: {e}", summary)
            return
        reqs = []
        for r in rids:
            req = self._rebuild_request(pending[r])
            req.started = started.get(r, now)
            reqs.append(req)
        mb = MicroBatch(requests=tuple(reqs), entry=entry,
                        formed_at=float(meta.get("formed_at", now)))
        label = None
        if any(lab is not None for lab in mb.labels):
            label = jnp.asarray([0 if lab is None else int(lab)
                                 for lab in mb.labels], jnp.int32)
        serial, track = self._begin_track(mb, kind, via="restore")
        static = meta.get("static", {})
        at = int(static.get("step", static.get("run_index", 0)))
        fl = _Inflight(mb=mb, kind=kind, rs=rs, label=label,
                       row_keyed=bool(meta.get("row_keyed", False)),
                       lineage=tuple(meta.get("lineage", ()))
                       + (f"restore@{at}",),
                       track=track, serial=serial)
        self._inflight.append(fl)
        self._snapshots.adopt(serial, path)
        for r in rids:
            restored.add(r)
            pending.pop(r, None)
        summary["restored_runs"] += 1
        summary["restored_requests"] += len(rids)

    def recover(self, journal=None, snapshot_dir=None) -> Dict:
        """Restart recovery: replay the write-ahead journal, restore
        in-flight batches from their newest valid snapshots, and re-admit
        everything else at its original arrival.

        * journal verdicts seed ``outcome()`` — finished/shed requests
          stay finished/shed across the restart (``("done", None)`` for a
          pre-crash finish: the verdict survives, the delivered payload
          was the old process's to lose);
        * snapshots are scanned newest-sequence-first with rid dedup:
          a valid snapshot whose requests are all still pending restores
          as a live in-flight batch and continues through the normal
          ``advance_*`` path; an invalid one (torn, tampered, provenance
          drift) is quarantined with a reason; a superseded one is
          deleted;
        * every pending request not covered by a restored run replays
          from the start — bit-identical to never having crashed, by the
          per-row key determinism contract.

        Pass ``journal``/``snapshot_dir`` to attach durability to an
        engine constructed without it (the factory pattern of the kill
        harness); both default to whatever the constructor wired.
        Returns a JSON-safe summary and journals a ``recover`` event."""
        if journal is not None:
            from repro.durable import RequestJournal
            self.journal = (journal
                            if isinstance(journal, RequestJournal)
                            else RequestJournal(str(journal)))
        if snapshot_dir is not None:
            from repro.durable import SnapshotStore
            self._snapshots = SnapshotStore(str(snapshot_dir))
        summary: Dict = {"done": 0, "shed": 0, "restored_runs": 0,
                         "restored_requests": 0, "replayed": 0,
                         "refused": [], "stale": 0, "journal_skipped": 0}
        if self.journal is None:
            return summary
        from repro.durable import JournalState
        st = JournalState.replay(self.journal.path)
        summary["journal_skipped"] = st.skipped
        now = self.clock.now()
        for rid in st.submitted:
            self._rids.add(rid)
        self._done.update(st.done)
        self.shed.update(st.shed)
        self._attempts.update(st.attempts)
        self._level.update(st.levels)
        summary["done"] = len(st.done)
        summary["shed"] = len(st.shed)
        pending = st.pending()
        restored: set = set()
        if self._snapshots is not None:
            for path in self._snapshots.scan():
                self._restore_snapshot(path, pending, restored,
                                       st.started, now, summary)
        replay = [self._rebuild_request(rec)
                  for _, rec in sorted(
                      pending.items(),
                      key=lambda kv: (kv[1].get("arrival", 0.0),
                                      str(kv[0])))]
        if any(r.max_tau is not None for r in replay):
            self._sweep_needed = True
        self.queue.submit_many(replay)
        summary["replayed"] = len(replay)
        self.metrics.observe_recovery(summary["restored_runs"],
                                      summary["restored_requests"],
                                      summary["replayed"],
                                      summary["stale"])
        self._journal("recover", sync=True,
                      restored_runs=summary["restored_runs"],
                      restored_requests=summary["restored_requests"],
                      replayed=summary["replayed"],
                      refused=len(summary["refused"]), t=float(now))
        self.tracer.instant("recover", **{
            k: v for k, v in summary.items() if k != "refused"})
        return summary

    def step(self) -> bool:
        """One scheduling tick: sweep SLOs (quality-floor sheds, admission
        shed/defer), admit what fits, then advance the in-flight run the
        scheduling policy selects by one unit (a plan segment / an
        adaptive step-chunk / a whole eager batch).  Returns False when
        nothing is runnable *right now* (requests may still be in flight
        toward their arrival time)."""
        now = self.clock.now()
        self._slo_sweep(now)
        self._admit(now)
        if not self._inflight:
            return False
        i = self.policy.select(self, now)
        fl = self._inflight[i]
        if fl.parked_by is not None:
            # a parked join target doesn't advance — its timeslice goes
            # to the chaser catching up to it
            fl = fl.parked_by
            i = self._inflight.index(fl)
        if self.resilience is None:
            self._advance_traced(fl)
        elif self._advance_guarded(i, fl):
            return True                       # batch aborted into recovery
        if fl.rs.done:
            self._inflight.pop(i)
            self._finish(fl)
        else:
            if self.continuous:
                if fl.chaser_for is not None:
                    self._try_merge(fl)
                else:
                    self._maybe_regroup(fl)
                self._coalesce()
            if fl in self._inflight:
                # boundary checkpoint: the host just finished an advance
                # (plan segment / adaptive chunk) — the only place a
                # snapshot is ever taken, so the fused path's
                # host_sync_count stays exactly where it was
                self._maybe_checkpoint(fl)
            if fl in self._inflight and self.policy.rotate():
                self._inflight.remove(fl)
                self._inflight.append(fl)
        return True

    def run_until_drained(self) -> Dict[int, np.ndarray]:
        """Serve until every submitted request has an *outcome* — a
        result, or an explicit shed (reason in ``self.shed``/metrics) —
        sleeping the clock across arrival gaps / batching windows /
        deferral retries.  Returns {rid: latent row} for the served
        ones; use :meth:`outcome` to resolve any rid's fate."""
        stalled = 0
        last_now = None
        while True:
            if self.step():
                stalled = 0
                continue
            if len(self.queue) == 0:
                break
            now = self.clock.now()
            t = self.batcher.next_event(now)
            if t is None:
                # with a resilience policy the stall guard degrades
                # instead of dying: every stuck request becomes an
                # explicit "stalled" shed and the drain completes
                if self.resilience is not None:
                    self._stall_shed("stalled", now)
                    continue
                raise RuntimeError(
                    "serve engine stalled: queued requests but no "
                    "schedulable event")
            if t <= now:
                # wall clock crossed an arrival / batching window between
                # step()'s reading and this one — the work is formable now,
                # re-tick.  Under a frozen VirtualClock a repeat of this
                # branch with no progress means a livelock (an event that
                # never fires) — fail loudly instead of spinning forever.
                stalled = stalled + 1 if now == last_now else 0
                last_now = now
                if stalled > 64:
                    if self.resilience is not None:
                        self._stall_shed("stalled", now)
                        stalled = 0
                        continue
                    raise RuntimeError(
                        f"serve engine livelocked at t={now}: "
                        f"next_event={t} never becomes schedulable")
                continue
            last_now = now
            self.clock.sleep_until(t)
        return self.results

    # -- reporting -----------------------------------------------------------

    def program_budget(self) -> int:
        """Static upper bound on shape-specialized model programs this
        deployment may compile: |admissible buckets| × Σ per-entry
        program cost.  A **fused** adaptive servable costs 1 program per
        bucket (the whole candidate pool rides inside one ``lax.switch``
        program); a host-dispatched adaptive entry costs its pool size
        (2^|ever-skipped| per-signature programs); a static entry costs
        its plan's unique signatures.  Independent of the traffic
        actually served — no request mix can push compiles past it;
        entries sharing signatures only tighten it."""
        buckets = len(bucket_sizes(self.batcher.max_batch))
        pool = 0
        for name in self.store.names():
            entry = self.store.get(name)
            pool += entry.program_cost(fused=self._fused_adaptive)
        return buckets * pool

    #: executor table kinds holding *model* programs (the budgeted set;
    #: the per-shape solver-step/proxy/decide helper jits are not
    #: signature-bound)
    MODEL_PROGRAM_KINDS = ("seg", "sigstep", "eager", "fused")

    def report(self) -> Dict:
        compiles = {
            kind: self.executor.compiled_variant_count(kind)
            for kind in self.MODEL_PROGRAM_KINDS
            if self.executor.compiled_variant_count(kind)
        }
        compiles["xla_programs"] = sum(
            self.executor.xla_program_count(kind)
            for kind in self.MODEL_PROGRAM_KINDS)
        # export the calibrated per-step cost model as registry gauges so
        # snapshot()/exposition() carry the admission controller's view
        snap = self.cost_model.snapshot()
        if snap["global"] is not None:
            self.registry.set_gauge("slo.step_cost_s", snap["global"])
        for g, v in snap["per_group"].items():
            self.registry.set_gauge("slo.step_cost_s", v, group=g)
        return self.metrics.report(compile_counts=compiles,
                                   program_budget=self.program_budget())
