"""Step-interleaved continuous-batching serving engine.

The engine drains a :class:`~repro.serve.request.RequestQueue` through the
executor's **resumable stepping API** (``start_run`` / ``advance_run`` for
static plans — one :class:`~repro.core.plan.ExecutionPlan` segment per
advance — and, for adaptive entries, ``start_adaptive_fused_run`` /
``advance_adaptive_fused`` when the executor supports the fused path: a
whole ``adaptive_chunk`` of steps in ONE donated program dispatch, with
the reuse decisions made on device, so timeslicing adaptive runs costs
zero per-step host round-trips.  Non-scannable solvers fall back to the
host-dispatched ``start_adaptive_run`` / ``advance_adaptive_run`` loop —
one decision sync + program dispatch per step).  Several in-flight
micro-batches timeslice the device: under the default ``interleave``
scheduler each tick advances the head of a round-robin rotation, so a
short, heavily-cached schedule admitted behind a full-compute one
finishes early instead of convoying behind it (``fcfs`` reproduces the
convoy for comparison).

Determinism contract: a micro-batch over requests ``[r0..rn-1]`` samples
with ``batch_key(seeds)`` — serving a batch is *bit-identical* to calling
``DiffusionPipeline.generate(params, batch_key(seeds), n, label=...)``
with the same store entry, because start+advance-until-done executes
exactly the ops of ``sample_with_plan`` / ``sample_adaptive``
(``tests/test_serve.py`` asserts this end-to-end).

Compiled-program budget: programs specialize on (signature, batch shape),
so the engine's compile count is bounded by |buckets used| ×
|signature pool| across all entries — reported by :meth:`ServeEngine.report`
against the executor's ``xla_program_count``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.batcher import MicroBatch, MicroBatcher, bucket_sizes
from repro.serve.metrics import ServerMetrics
from repro.serve.request import Request, RequestQueue, WallClock
from repro.serve.store import ArtifactStore

#: scheduling strategies: round-robin timeslicing vs run-to-completion
SCHEDULERS = ("interleave", "fcfs")


def batch_key(seeds: Sequence[int]):
    """Deterministic PRNG key of a micro-batch: a fold of the member
    requests' seeds (order-sensitive — the batch row order).  Exposed so
    tests and clients can replay any served batch through
    ``DiffusionPipeline.generate`` and get bit-identical latents."""
    key = jax.random.PRNGKey(len(seeds))
    for s in seeds:
        # full 32-bit fold: seeds differing only in bit 31 must not
        # collapse to the same key
        key = jax.random.fold_in(key, jnp.uint32(int(s) & 0xFFFFFFFF))
    return key


@dataclasses.dataclass
class BatchRecord:
    """Provenance of one served micro-batch (enough to replay it)."""
    group: str
    version: int
    bucket: int
    rids: Tuple[int, ...]
    seeds: Tuple[int, ...]
    labels: Tuple[Optional[int], ...]
    num_steps: int
    compute_fraction: float
    formed_at: float
    finished_at: float
    decisions: Optional[Tuple[tuple, ...]] = None   # adaptive runs only


class _EagerState:
    """Run-state stand-in for the ``--eager`` escape hatch (whole batch
    sampled in one advance; no interleaving)."""

    def __init__(self):
        self.x = None
        self.decisions = None

    @property
    def done(self) -> bool:
        return self.x is not None


@dataclasses.dataclass
class _Inflight:
    mb: MicroBatch
    kind: str                                 # "plan" | "adaptive" | "eager"
    rs: object
    label: object


class ServeEngine:
    """Queue → batcher → interleaved executor runs → metrics."""

    def __init__(self, executor, params, store: ArtifactStore, *,
                 clock=None, max_batch: int = 8, max_wait: float = 0.0,
                 max_inflight: int = 2, scheduler: str = "interleave",
                 adaptive_chunk: int = 4, eager: bool = False,
                 check: bool = False):
        if scheduler not in SCHEDULERS:
            raise ValueError(f"scheduler must be one of {SCHEDULERS}, got "
                             f"{scheduler!r}")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if adaptive_chunk < 1:
            raise ValueError(f"adaptive_chunk must be >= 1, got "
                             f"{adaptive_chunk}")
        self.executor = executor
        self.params = params
        self.store = store
        self.clock = clock if clock is not None else WallClock()
        self.queue = RequestQueue(self.clock)
        self.batcher = MicroBatcher(self.queue, store, max_batch=max_batch,
                                    max_wait=max_wait)
        self.metrics = ServerMetrics()
        self.scheduler = scheduler
        self.max_inflight = max_inflight
        self.adaptive_chunk = adaptive_chunk
        self.eager = eager
        self.check = check
        self.results: Dict[int, np.ndarray] = {}
        self.records: List[BatchRecord] = []
        self._inflight: List[_Inflight] = []
        self._rids: set = set()               # every rid ever submitted

    # -- submission ----------------------------------------------------------

    def submit(self, *reqs: Request) -> None:
        """Enqueue requests (arrival stamped now unless preset).  Unknown
        policy names are rejected at the door, not at batch formation."""
        seen = set()
        for r in reqs:
            if r.policy not in self.store:
                raise KeyError(f"request {r.rid}: no servable entry "
                               f"{r.policy!r}; have {self.store.names()}")
            # against *every* rid ever submitted (queued, in flight, done,
            # or earlier in this very call), not just completed ones — a
            # duplicate would silently overwrite its sibling's result
            if r.rid in self._rids or r.rid in seen:
                raise ValueError(f"duplicate request id {r.rid}")
            seen.add(r.rid)
        self._rids |= seen
        self.queue.submit_many(list(reqs))

    # -- scheduling ----------------------------------------------------------

    def _admit(self, now: float) -> None:
        while len(self._inflight) < self.max_inflight:
            mb = self.batcher.next_batch(now)
            if mb is None:
                return
            self._launch(mb, now)

    def _launch(self, mb: MicroBatch, now: float) -> None:
        entry = mb.entry
        key = batch_key(mb.seeds)
        label = None
        if any(lab is not None for lab in mb.labels):
            label = jnp.asarray([0 if lab is None else int(lab)
                                 for lab in mb.labels], jnp.int32)
        if self.eager:
            kind, rs = "eager", _EagerState()
        elif entry.adaptive and self._fused_adaptive:
            kind = "adaptive_fused"
            rs = self.executor.start_adaptive_fused_run(
                self.params, key, mb.bucket, schedule=entry.schedule,
                tau=entry.tau, proxy_map=entry.proxy_map,
                pool=entry.pool(), k_max=entry.k_max, label=label)
        elif entry.adaptive:
            kind = "adaptive"
            rs = self.executor.start_adaptive_run(
                self.params, key, mb.bucket, schedule=entry.schedule,
                tau=entry.tau, proxy_map=entry.proxy_map,
                pool=entry.pool(), k_max=entry.k_max, label=label)
        else:
            kind = "plan"
            rs = self.executor.start_run(
                self.params, key, mb.bucket, plan=entry.plan,
                schedule=entry.schedule, label=label)
        for r in mb.requests:
            r.started = now
        self._inflight.append(_Inflight(mb=mb, kind=kind, rs=rs,
                                        label=label))

    @property
    def _fused_adaptive(self) -> bool:
        """Serve adaptive entries through the fused on-device path when
        the executor offers it (scannable solver): one program per entry
        instead of pool-size × steps of dispatches, zero per-step
        decision syncs."""
        return bool(getattr(self.executor, "supports_fused_adaptive",
                            False))

    def _advance(self, fl: _Inflight) -> None:
        entry = fl.mb.entry
        if fl.kind == "plan":
            fl.rs = self.executor.advance_run(self.params, fl.rs,
                                              check=self.check)
        elif fl.kind == "adaptive_fused":
            # the whole chunk is one program dispatch — the timeslice
            # granularity costs no extra host round-trips
            fl.rs = self.executor.advance_adaptive_fused(
                self.params, fl.rs, n_steps=self.adaptive_chunk)
        elif fl.kind == "adaptive":
            for _ in range(self.adaptive_chunk):
                if fl.rs.done:
                    break
                fl.rs = self.executor.advance_adaptive_run(self.params,
                                                           fl.rs)
        else:                                  # eager escape hatch
            key = batch_key(fl.mb.seeds)
            fl.rs.x = self.executor.sample(
                self.params, key, fl.mb.bucket, schedule=entry.schedule,
                label=fl.label)

    def _finish(self, fl: _Inflight) -> None:
        mb, rs = fl.mb, fl.rs
        x = jax.block_until_ready(rs.x)
        done = self.clock.now()
        x = np.asarray(x)
        for j, r in enumerate(mb.requests):
            r.finished = done
            self.results[r.rid] = x[j]
            self.metrics.observe_request(r)
        entry = mb.entry
        num_types = len(entry.schedule.skip)
        decisions = getattr(rs, "decisions", None)
        if decisions:
            skipped = sum(len(d) for d in decisions)
            frac = 1.0 - skipped / float(entry.plan.num_steps * num_types)
        else:
            frac = entry.compute_fraction()
        self.metrics.observe_batch(mb.group, mb.bucket, frac,
                                   entry.plan.num_steps, num_types)
        self.records.append(BatchRecord(
            group=mb.group, version=entry.version, bucket=mb.bucket,
            rids=mb.rids, seeds=mb.seeds, labels=mb.labels,
            num_steps=entry.plan.num_steps, compute_fraction=frac,
            formed_at=mb.formed_at, finished_at=done, decisions=decisions))

    def step(self) -> bool:
        """One scheduling tick: admit what fits, then advance one in-flight
        run by one unit (a plan segment / an adaptive step-chunk / a whole
        eager batch).  Returns False when nothing is runnable *right now*
        (requests may still be in flight toward their arrival time)."""
        now = self.clock.now()
        self._admit(now)
        if not self._inflight:
            return False
        if self.scheduler == "interleave":
            fl = self._inflight.pop(0)         # rotate: head runs one unit
            self._advance(fl)
            if fl.rs.done:
                self._finish(fl)
            else:
                self._inflight.append(fl)
        else:                                  # fcfs: run head to done
            fl = self._inflight[0]
            self._advance(fl)
            if fl.rs.done:
                self._inflight.pop(0)
                self._finish(fl)
        return True

    def run_until_drained(self) -> Dict[int, np.ndarray]:
        """Serve until every submitted request has a result, sleeping the
        clock across arrival gaps / batching windows.  Returns
        {rid: latent row}."""
        while True:
            if self.step():
                continue
            if len(self.queue) == 0:
                break
            now = self.clock.now()
            t = self.batcher.next_event(now)
            if t is None:
                raise RuntimeError(
                    "serve engine stalled: queued requests but no "
                    "schedulable event")
            if t <= now:
                # wall clock crossed an arrival / batching window between
                # step()'s reading and this one — the work is formable now,
                # re-tick.  (Under a frozen VirtualClock t > now always:
                # an expired window would have formed a batch in step().)
                continue
            self.clock.sleep_until(t)
        return self.results

    # -- reporting -----------------------------------------------------------

    def program_budget(self) -> int:
        """Static upper bound on shape-specialized model programs this
        deployment may compile: |admissible buckets| × Σ per-entry
        program cost.  A **fused** adaptive servable costs 1 program per
        bucket (the whole candidate pool rides inside one ``lax.switch``
        program); a host-dispatched adaptive entry costs its pool size
        (2^|ever-skipped| per-signature programs); a static entry costs
        its plan's unique signatures.  Independent of the traffic
        actually served — no request mix can push compiles past it;
        entries sharing signatures only tighten it."""
        buckets = len(bucket_sizes(self.batcher.max_batch))
        pool = 0
        for name in self.store.names():
            entry = self.store.get(name)
            pool += entry.program_cost(fused=self._fused_adaptive)
        return buckets * pool

    #: executor table kinds holding *model* programs (the budgeted set;
    #: the per-shape solver-step/proxy/decide helper jits are not
    #: signature-bound)
    MODEL_PROGRAM_KINDS = ("seg", "sigstep", "eager", "fused")

    def report(self) -> Dict:
        compiles = {
            kind: self.executor.compiled_variant_count(kind)
            for kind in self.MODEL_PROGRAM_KINDS
            if self.executor.compiled_variant_count(kind)
        }
        compiles["xla_programs"] = sum(
            self.executor.xla_program_count(kind)
            for kind in self.MODEL_PROGRAM_KINDS)
        return self.metrics.report(compile_counts=compiles,
                                   program_budget=self.program_budget())
