"""Step-interleaved continuous-batching serving engine.

The engine drains a :class:`~repro.serve.request.RequestQueue` through the
executor's **resumable stepping API** (``start_run`` / ``advance_run`` for
static plans — one :class:`~repro.core.plan.ExecutionPlan` segment per
advance — and, for adaptive entries, ``start_adaptive_fused_run`` /
``advance_adaptive_fused`` when the executor supports the fused path: a
whole ``adaptive_chunk`` of steps in ONE donated program dispatch, with
the reuse decisions made on device, so timeslicing adaptive runs costs
zero per-step host round-trips.  Non-scannable solvers fall back to the
host-dispatched ``start_adaptive_run`` / ``advance_adaptive_run`` loop —
one decision sync + program dispatch per step).  Several in-flight
micro-batches timeslice the device: which one advances each tick is
decided by a pluggable :class:`repro.slo.SchedulingPolicy` — the default
``interleave`` (round-robin, so a short, heavily-cached schedule admitted
behind a full-compute one finishes early instead of convoying behind it),
``fcfs`` (the convoy baseline), ``edf`` (least-slack-first over member
deadlines, remaining-steps-aware), or an ``elastic`` policy object that
additionally drives the store's τ ladders from measured p95 waits.
Preemption granularity is the advance unit (plan segment / adaptive
chunk) — a batch is never torn mid-program.

SLO semantics (all optional — without them the engine behaves exactly as
before): requests may carry a :class:`repro.slo.SLO`; each tick first
runs an SLO sweep that sheds quality-infeasible requests (no registered
rung at or below the request's ``max_tau``) and, when an
:class:`repro.slo.AdmissionController` is installed, sheds/defers against
the estimated backlog (queue depth × the online-calibrated per-step
service cost).  Every rejection is recorded with a reason in
``ServeEngine.shed`` and the metrics — check :meth:`ServeEngine.outcome`
for any rid.

Determinism contract: a micro-batch over requests ``[r0..rn-1]`` samples
with ``batch_key(seeds)`` — serving a batch is *bit-identical* to calling
``DiffusionPipeline.generate(params, batch_key(seeds), n, label=...)``
with the same store entry, because start+advance-until-done executes
exactly the ops of ``sample_with_plan`` / ``sample_adaptive``
(``tests/test_serve.py`` asserts this end-to-end).

Compiled-program budget: programs specialize on (signature, batch shape),
so the engine's compile count is bounded by |buckets used| ×
|signature pool| across all entries — reported by :meth:`ServeEngine.report`
against the executor's ``xla_program_count``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.batcher import MicroBatch, MicroBatcher, bucket_sizes
from repro.serve.metrics import ServerMetrics
from repro.serve.request import Request, RequestQueue, WallClock
from repro.serve.store import ArtifactStore

#: built-in scheduler names (resolved through repro.slo.resolve_policy;
#: "elastic" additionally exists as a policy *object* since it needs a
#: constructed controller)
SCHEDULERS = ("interleave", "fcfs", "edf")


def batch_key(seeds: Sequence[int]):
    """Deterministic PRNG key of a micro-batch: a fold of the member
    requests' seeds (order-sensitive — the batch row order).  Exposed so
    tests and clients can replay any served batch through
    ``DiffusionPipeline.generate`` and get bit-identical latents."""
    key = jax.random.PRNGKey(len(seeds))
    for s in seeds:
        # full 32-bit fold: seeds differing only in bit 31 must not
        # collapse to the same key
        key = jax.random.fold_in(key, jnp.uint32(int(s) & 0xFFFFFFFF))
    return key


@dataclasses.dataclass
class BatchRecord:
    """Provenance of one served micro-batch (enough to replay it)."""
    group: str
    version: int
    bucket: int
    rids: Tuple[int, ...]
    seeds: Tuple[int, ...]
    labels: Tuple[Optional[int], ...]
    num_steps: int
    compute_fraction: float
    formed_at: float
    finished_at: float
    decisions: Optional[Tuple[tuple, ...]] = None   # adaptive runs only
    tau: float = 0.0                          # realized τ (rung at launch)
    quality_cost: Optional[float] = None      # predicted, from proxy map


class _EagerState:
    """Run-state stand-in for the ``--eager`` escape hatch (whole batch
    sampled in one advance; no interleaving)."""

    def __init__(self):
        self.x = None
        self.decisions = None

    @property
    def done(self) -> bool:
        return self.x is not None


@dataclasses.dataclass
class _Inflight:
    mb: MicroBatch
    kind: str                                 # "plan" | "adaptive" | "eager"
    rs: object
    label: object


class ServeEngine:
    """Queue → batcher → interleaved executor runs → metrics."""

    def __init__(self, executor, params, store: ArtifactStore, *,
                 clock=None, max_batch: int = 8, max_wait: float = 0.0,
                 max_inflight: int = 2, scheduler="interleave",
                 adaptive_chunk: int = 4, eager: bool = False,
                 check: bool = False, admission=None, cost_model=None):
        # lazy so repro.serve stays importable without the slo layer
        # loaded (and the layering acyclic: slo never imports the engine)
        from repro.slo.admission import LoadEstimator, ServiceCostModel
        from repro.slo.policy import resolve_policy
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if adaptive_chunk < 1:
            raise ValueError(f"adaptive_chunk must be >= 1, got "
                             f"{adaptive_chunk}")
        self.executor = executor
        self.params = params
        self.store = store
        self.clock = clock if clock is not None else WallClock()
        self.queue = RequestQueue(self.clock)
        self.batcher = MicroBatcher(self.queue, store, max_batch=max_batch,
                                    max_wait=max_wait)
        self.metrics = ServerMetrics()
        #: the scheduling policy object; ``scheduler`` may be a built-in
        #: name ("interleave"/"fcfs"/"edf") or any
        #: repro.slo.SchedulingPolicy (e.g. ElasticPolicy(controller))
        self.policy = resolve_policy(scheduler)
        self.scheduler = self.policy.name
        self.admission = admission            # repro.slo.AdmissionController
        self.cost_model = (cost_model if cost_model is not None
                           else ServiceCostModel())
        self.load = LoadEstimator(self.cost_model,
                                  batch_factor=max_batch)
        self.max_inflight = max_inflight
        self.adaptive_chunk = adaptive_chunk
        self.eager = eager
        self.check = check
        self.results: Dict[int, np.ndarray] = {}
        self.records: List[BatchRecord] = []
        self.shed: Dict[int, Tuple[str, float]] = {}   # rid → (reason, t)
        self._inflight: List[_Inflight] = []
        self._rids: set = set()               # every rid ever submitted
        self._sweep_needed = admission is not None

    # -- submission ----------------------------------------------------------

    def submit(self, *reqs: Request) -> None:
        """Enqueue requests (arrival stamped now unless preset).  Unknown
        policy names are rejected at the door, not at batch formation."""
        seen = set()
        for r in reqs:
            if r.policy not in self.store:
                raise KeyError(f"request {r.rid}: no servable entry "
                               f"{r.policy!r}; have {self.store.names()}")
            # against *every* rid ever submitted (queued, in flight, done,
            # or earlier in this very call), not just completed ones — a
            # duplicate would silently overwrite its sibling's result
            if r.rid in self._rids or r.rid in seen:
                raise ValueError(f"duplicate request id {r.rid}")
            seen.add(r.rid)
            if getattr(r, "max_tau", None) is not None:
                self._sweep_needed = True
        self._rids |= seen
        self.queue.submit_many(list(reqs))

    def outcome(self, rid: int):
        """Explicit fate of a submitted request — requests are never
        silently dropped: ``("done", latent)``, ``("shed", reason)``, or
        ``("pending", None)``."""
        if rid not in self._rids:
            raise KeyError(f"rid {rid} was never submitted")
        if rid in self.results:
            return ("done", self.results[rid])
        if rid in self.shed:
            return ("shed", self.shed[rid][0])
        return ("pending", None)

    # -- SLO sweep (quality floors + admission) -------------------------------

    def _backlog_seconds(self, now: float) -> float:
        """Load estimate: queued steps (batch-amortized) + in-flight
        remaining steps, priced at the calibrated per-step cost."""
        from repro.slo.slo import remaining_steps
        queued = []
        for g in self.queue.ready_groups(now):
            for r in self.queue.peek(g, now):
                e = self.store.resolve_entry_for(g, r)
                queued.append(e.plan.num_steps if e is not None else 0)
        inflight = [remaining_steps(fl.rs) for fl in self._inflight]
        return self.load.backlog_seconds(queued, inflight)

    def _shed(self, req: Request, reason: str, now: float) -> None:
        self.queue.take_rids(req.policy, [req.rid], now)
        self.shed[req.rid] = (reason, now)
        self.metrics.observe_shed(req, reason, now)

    def _slo_sweep(self, now: float) -> None:
        """Walk the ready queue: shed requests whose quality floor no
        registered rung satisfies, then let the admission controller
        shed/defer against the backlog estimate.  The backlog is
        snapshotted once per sweep so decisions are order-independent."""
        if not self._sweep_needed:
            return
        backlog = None
        for g in list(self.queue.ready_groups(now)):
            for r in self.queue.peek(g, now):
                entry = self.store.resolve_entry_for(g, r)
                if entry is None:
                    self._shed(r, "quality_floor", now)
                    continue
                if self.admission is None:
                    continue
                if backlog is None:
                    backlog = self._backlog_seconds(now)
                est = self.cost_model.estimate(entry.plan.num_steps,
                                               group=entry.name)
                d = self.admission.decide(r, now, backlog_s=backlog,
                                          est_service_s=est)
                if d.action == "shed":
                    self._shed(r, d.reason, now)
                elif d.action == "defer":
                    self.queue.take_rids(g, [r.rid], now)
                    self.metrics.observe_defer(r, now)
                    self.queue.resubmit(r, d.retry_at)

    # -- scheduling ----------------------------------------------------------

    def _admit(self, now: float) -> None:
        while len(self._inflight) < self.max_inflight:
            mb = self.batcher.next_batch(now)
            if mb is None:
                return
            self._launch(mb, now)

    def _launch(self, mb: MicroBatch, now: float) -> None:
        entry = mb.entry
        key = batch_key(mb.seeds)
        label = None
        if any(lab is not None for lab in mb.labels):
            label = jnp.asarray([0 if lab is None else int(lab)
                                 for lab in mb.labels], jnp.int32)
        if self.eager:
            kind, rs = "eager", _EagerState()
        elif entry.adaptive and self._fused_adaptive:
            kind = "adaptive_fused"
            rs = self.executor.start_adaptive_fused_run(
                self.params, key, mb.bucket, schedule=entry.schedule,
                tau=entry.tau, proxy_map=entry.proxy_map,
                pool=entry.pool(), k_max=entry.k_max, label=label)
        elif entry.adaptive:
            kind = "adaptive"
            rs = self.executor.start_adaptive_run(
                self.params, key, mb.bucket, schedule=entry.schedule,
                tau=entry.tau, proxy_map=entry.proxy_map,
                pool=entry.pool(), k_max=entry.k_max, label=label)
        else:
            kind = "plan"
            rs = self.executor.start_run(
                self.params, key, mb.bucket, plan=entry.plan,
                schedule=entry.schedule, label=label)
        for r in mb.requests:
            r.started = now
        self._inflight.append(_Inflight(mb=mb, kind=kind, rs=rs,
                                        label=label))

    @property
    def _fused_adaptive(self) -> bool:
        """Serve adaptive entries through the fused on-device path when
        the executor offers it (scannable solver): one program per entry
        instead of pool-size × steps of dispatches, zero per-step
        decision syncs."""
        return bool(getattr(self.executor, "supports_fused_adaptive",
                            False))

    def _advance(self, fl: _Inflight) -> None:
        entry = fl.mb.entry
        if fl.kind == "plan":
            fl.rs = self.executor.advance_run(self.params, fl.rs,
                                              check=self.check)
        elif fl.kind == "adaptive_fused":
            # the whole chunk is one program dispatch — the timeslice
            # granularity costs no extra host round-trips
            fl.rs = self.executor.advance_adaptive_fused(
                self.params, fl.rs, n_steps=self.adaptive_chunk)
        elif fl.kind == "adaptive":
            for _ in range(self.adaptive_chunk):
                if fl.rs.done:
                    break
                fl.rs = self.executor.advance_adaptive_run(self.params,
                                                           fl.rs)
        else:                                  # eager escape hatch
            key = batch_key(fl.mb.seeds)
            fl.rs.x = self.executor.sample(
                self.params, key, fl.mb.bucket, schedule=entry.schedule,
                label=fl.label)

    def _finish(self, fl: _Inflight) -> None:
        mb, rs = fl.mb, fl.rs
        x = jax.block_until_ready(rs.x)
        done = self.clock.now()
        x = np.asarray(x)
        for j, r in enumerate(mb.requests):
            r.finished = done
            self.results[r.rid] = x[j]
            self.metrics.observe_request(r)
        entry = mb.entry
        num_types = len(entry.schedule.skip)
        decisions = getattr(rs, "decisions", None)
        if decisions:
            skipped = sum(len(d) for d in decisions)
            frac = 1.0 - skipped / float(entry.plan.num_steps * num_types)
        else:
            frac = entry.compute_fraction()
        self.metrics.observe_batch(mb.group, mb.bucket, frac,
                                   entry.plan.num_steps, num_types)
        # feed the calibrated per-step cost model (service time of the
        # whole batch — includes interleaving contention, which is the
        # pessimism an admission wait estimate wants)
        service = done - mb.requests[0].started
        self.cost_model.observe(mb.group, service, entry.plan.num_steps)
        qcost = entry.predicted_quality_cost(decisions)
        self.metrics.observe_quality(entry.tau, qcost, n=mb.bucket)
        record = BatchRecord(
            group=mb.group, version=entry.version, bucket=mb.bucket,
            rids=mb.rids, seeds=mb.seeds, labels=mb.labels,
            num_steps=entry.plan.num_steps, compute_fraction=frac,
            formed_at=mb.formed_at, finished_at=done, decisions=decisions,
            tau=entry.tau, quality_cost=qcost)
        self.records.append(record)
        self.policy.on_finish(self, record, mb.requests, done)

    def step(self) -> bool:
        """One scheduling tick: sweep SLOs (quality-floor sheds, admission
        shed/defer), admit what fits, then advance the in-flight run the
        scheduling policy selects by one unit (a plan segment / an
        adaptive step-chunk / a whole eager batch).  Returns False when
        nothing is runnable *right now* (requests may still be in flight
        toward their arrival time)."""
        now = self.clock.now()
        self._slo_sweep(now)
        self._admit(now)
        if not self._inflight:
            return False
        i = self.policy.select(self, now)
        fl = self._inflight[i]
        self._advance(fl)
        if fl.rs.done:
            self._inflight.pop(i)
            self._finish(fl)
        elif self.policy.rotate():
            self._inflight.pop(i)
            self._inflight.append(fl)
        return True

    def run_until_drained(self) -> Dict[int, np.ndarray]:
        """Serve until every submitted request has an *outcome* — a
        result, or an explicit shed (reason in ``self.shed``/metrics) —
        sleeping the clock across arrival gaps / batching windows /
        deferral retries.  Returns {rid: latent row} for the served
        ones; use :meth:`outcome` to resolve any rid's fate."""
        stalled = 0
        last_now = None
        while True:
            if self.step():
                stalled = 0
                continue
            if len(self.queue) == 0:
                break
            now = self.clock.now()
            t = self.batcher.next_event(now)
            if t is None:
                raise RuntimeError(
                    "serve engine stalled: queued requests but no "
                    "schedulable event")
            if t <= now:
                # wall clock crossed an arrival / batching window between
                # step()'s reading and this one — the work is formable now,
                # re-tick.  Under a frozen VirtualClock a repeat of this
                # branch with no progress means a livelock (an event that
                # never fires) — fail loudly instead of spinning forever.
                stalled = stalled + 1 if now == last_now else 0
                last_now = now
                if stalled > 64:
                    raise RuntimeError(
                        f"serve engine livelocked at t={now}: "
                        f"next_event={t} never becomes schedulable")
                continue
            last_now = now
            self.clock.sleep_until(t)
        return self.results

    # -- reporting -----------------------------------------------------------

    def program_budget(self) -> int:
        """Static upper bound on shape-specialized model programs this
        deployment may compile: |admissible buckets| × Σ per-entry
        program cost.  A **fused** adaptive servable costs 1 program per
        bucket (the whole candidate pool rides inside one ``lax.switch``
        program); a host-dispatched adaptive entry costs its pool size
        (2^|ever-skipped| per-signature programs); a static entry costs
        its plan's unique signatures.  Independent of the traffic
        actually served — no request mix can push compiles past it;
        entries sharing signatures only tighten it."""
        buckets = len(bucket_sizes(self.batcher.max_batch))
        pool = 0
        for name in self.store.names():
            entry = self.store.get(name)
            pool += entry.program_cost(fused=self._fused_adaptive)
        return buckets * pool

    #: executor table kinds holding *model* programs (the budgeted set;
    #: the per-shape solver-step/proxy/decide helper jits are not
    #: signature-bound)
    MODEL_PROGRAM_KINDS = ("seg", "sigstep", "eager", "fused")

    def report(self) -> Dict:
        compiles = {
            kind: self.executor.compiled_variant_count(kind)
            for kind in self.MODEL_PROGRAM_KINDS
            if self.executor.compiled_variant_count(kind)
        }
        compiles["xla_programs"] = sum(
            self.executor.xla_program_count(kind)
            for kind in self.MODEL_PROGRAM_KINDS)
        return self.metrics.report(compile_counts=compiles,
                                   program_budget=self.program_budget())
