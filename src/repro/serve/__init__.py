"""repro.serve — cache-aware continuous-batching serving for diffusion.

The layer that turns the executor machinery (segment-compiled plans,
adaptive signature pools, serializable artifacts) into a system that
drains heterogeneous traffic::

    from repro import serve
    from repro.core import solvers
    from repro.core.executor import SmoothCacheExecutor

    ex = SmoothCacheExecutor(cfg, solvers.ddim(50), cfg_scale=1.5)
    store = serve.ArtifactStore(cfg, ex.solver, cfg_scale=1.5)
    store.add_policy("no_cache", "none")
    store.add_artifact("smooth", "dit_xl_ddim50.cache.json")   # validated

    engine = serve.ServeEngine(ex, params, store, max_batch=8)
    engine.submit(serve.Request(rid=0, seed=17, policy="smooth", label=3))
    results = engine.run_until_drained()       # {rid: latent}
    print(engine.report())                     # p50/p95, throughput, compiles

Pieces: :class:`Request`/:class:`RequestQueue` (real arrival timestamps,
virtual-clock test mode), :class:`MicroBatcher` (power-of-two buckets per
(entry, signature) group), :class:`ArtifactStore` (strict-validated
hot-reload; serving never recalibrates), :class:`ServeEngine`
(step-interleaved scheduler over the executor's resumable runs), and
:class:`ServerMetrics` (queue wait vs service percentiles, compile counts,
realized compute fraction).

Production QoS — deadlines, priorities, quality floors, admission
control, and the τ-elastic degradation controller over
:meth:`ArtifactStore.add_ladder` τ ladders — lives one layer up in
:mod:`repro.slo`; the engine accepts any of its scheduling policies via
``scheduler=`` and its admission controllers via ``admission=``.
"""
from repro.serve.batcher import (  # noqa: F401
    MicroBatch, MicroBatcher, bucket_for, bucket_sizes)
from repro.serve.engine import (  # noqa: F401
    BatchRecord, SCHEDULERS, ServeEngine, batch_key)
from repro.serve.metrics import ServerMetrics, percentile  # noqa: F401
from repro.serve.request import (  # noqa: F401
    Request, RequestQueue, VirtualClock, WallClock, poisson_arrivals)
from repro.serve.store import (  # noqa: F401
    ArtifactStore, ServableEntry, TauLadder)
