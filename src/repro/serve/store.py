"""Servable artifact store: strict-validated load + hot-reload.

Serving **never recalibrates** (the PR-1/PR-3 contract): every schedule a
server runs comes either from a :class:`~repro.cache.artifact.CacheArtifact`
produced by an offline calibration process, or from a calibration-free
policy (``none``, ``static:n=2``) resolved directly.  The store is the
serving side of that contract:

* :meth:`ArtifactStore.add_artifact` loads an artifact and runs the *same*
  strict validation as ``DiffusionPipeline.load_artifact``
  (``CacheArtifact.validate_for``: architecture, solver × step count,
  cfg_scale, adaptive tau/k_max/pool provenance) before the entry becomes
  visible to the batcher.
* :meth:`ArtifactStore.reload` hot-swaps an entry *atomically*: the
  replacement is fully loaded and validated first, and a bad file leaves
  the old entry serving (the swap raises instead of wedging traffic).
  Each swap bumps ``entry.version`` — in-flight batches keep the entry
  they launched with; new batches resolve the current one.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

from repro.cache import registry
from repro.cache.artifact import CacheArtifact
from repro.obs import NULL_TRACER
from repro.cache.policy import AdaptivePolicy, CachePolicy
from repro.core import calibration as calibration_lib
from repro.core import plan as plan_lib
from repro.core.schedule import Schedule
from repro.resilience.integrity import HealthRegistry

#: reserved entry-name prefix for store-materialized degradation targets
#: (fault-retry rungs) — not client-addressable policies
DEGRADED_PREFIX = "!degraded/"
FALLBACK_ENTRY = "!fallback/no_cache"


@dataclasses.dataclass
class ServableEntry:
    """Everything the engine needs to serve one policy: the resolved
    schedule, its pre-analyzed execution plan, and — for adaptive policies
    — the runtime decision parameters shipped in the artifact."""
    name: str
    policy: CachePolicy
    schedule: Schedule
    plan: plan_lib.ExecutionPlan
    artifact: Optional[CacheArtifact] = None
    proxy_map: Optional[calibration_lib.ProxyMap] = None
    version: int = 1
    path: Optional[str] = None
    #: the ``policy=`` override add_artifact() was called with, if any —
    #: reload() must re-apply it or a hot swap would silently fall back
    #: to the artifact's stored policy (e.g. flip a static-base entry
    #: back to adaptive serving)
    policy_override: Optional[CachePolicy] = None
    #: memoized candidate pool (adaptive entries) — derived once per
    #: entry, not per launched batch
    _pool: Optional[tuple] = dataclasses.field(default=None, repr=False)
    #: memoized provenance stamp (durable snapshots) — see provenance()
    _provenance: Optional[dict] = dataclasses.field(default=None,
                                                    repr=False)

    @property
    def adaptive(self) -> bool:
        return isinstance(self.policy, AdaptivePolicy)

    def pool(self) -> tuple:
        """Precompiled candidate signature pool of an adaptive entry (the
        schedule's mask lattice — already validated against the artifact's
        stored pool provenance by ``validate_for``), memoized so the
        engine derives it once per entry rather than once per batch."""
        if not self.adaptive:
            raise ValueError(f"entry {self.name!r} is not adaptive")
        if self._pool is None:
            self._pool = plan_lib.mask_lattice(self.schedule)
        return self._pool

    def pool_size(self) -> int:
        """Candidate-pool cardinality (2^|ever-skipped| for adaptive
        entries, the plan's unique signatures otherwise) — the per-entry
        factor in the host-dispatch program budget."""
        if self.adaptive:
            return len(self.pool())
        return self.plan.num_unique_signatures

    def program_cost(self, fused: bool) -> int:
        """Shape-specialized model programs this entry can compile per
        batch bucket: a fused adaptive servable compiles ONE program (the
        whole pool's branches live inside a single ``lax.switch``
        program) vs ``pool_size()`` per-signature programs under host
        dispatch; static entries compile one per plan signature."""
        if self.adaptive and fused:
            return 1
        return self.pool_size()

    @property
    def tau(self) -> float:
        return self.policy.tau if self.adaptive else 0.0

    @property
    def k_max(self) -> int:
        return self.policy.k_max

    def fingerprint(self) -> str:
        """Schedule-content digest + version — an identifier for logs and
        batch records.  (Version isolation itself needs no key: the
        batcher snapshots the current entry atomically when it forms a
        batch, so one micro-batch always serves exactly one version.)"""
        return f"{self.schedule.fingerprint()}/v{self.version}"

    def provenance(self) -> dict:
        """JSON-safe identity stamp of everything a restored run's bits
        depend on: entry name + version, schedule fingerprint, execution
        plan hash, adaptive decision parameters, and the artifact's
        content checksum.  Durable snapshots embed it at checkpoint time;
        recovery refuses any snapshot whose stamp disagrees with the
        entry now in the store — an entry that hot-reloaded across the
        restart must replay from the start, not continue on drifted
        parameters.  Memoized: entries are immutable once registered
        (reload builds a new entry)."""
        if self._provenance is None:
            import json as _json

            from repro.durable.snapshot import plan_hash
            from repro.resilience.integrity import (CHECKSUM_KEY,
                                                    payload_checksum)
            art_ck = None
            if self.artifact is not None:
                payload = _json.loads(self.artifact.to_json())
                art_ck = payload.get(CHECKSUM_KEY) \
                    or payload_checksum(payload)
            self._provenance = {
                "entry": self.name,
                "version": int(self.version),
                "schedule_fp": self.schedule.fingerprint(),
                "plan_hash": plan_hash(self.plan),
                "tau": float(self.tau),
                "k_max": int(self.k_max) if self.adaptive else None,
                "artifact_checksum": art_ck,
            }
        return dict(self._provenance)

    def compute_fraction(self) -> float:
        """Static compute fraction of the entry's schedule (adaptive runs
        report their *realized* fraction per batch instead)."""
        import numpy as np
        return float(np.mean([1.0 - np.mean(v)
                              for v in self.schedule.skip.values()]))

    def predicted_quality_cost(self, decisions=None) -> Optional[float]:
        """Predicted cumulative relative output error of one run served
        by this entry, from the artifact's fitted proxy→error map: the
        sum of ``est(type, proxy)`` over every (step, type) reuse —
        ``decisions`` when the run's realized per-step skip sets are
        known (adaptive runs), the static schedule's skips otherwise.
        The proxy is evaluated at the calibration-mean signal (0 when the
        artifact predates ``mean_proxy``).  None without a proxy map —
        entries that never calibrated one make no quality claim."""
        if self.proxy_map is None:
            return None
        import numpy as np
        p = self.proxy_map.mean_proxy
        if not np.isfinite(p):
            p = 0.0
        if decisions is None:
            decisions = [
                tuple(t for t, v in sorted(self.schedule.skip.items())
                      if v[s])
                for s in range(self.schedule.num_steps)]
        return float(sum(self.proxy_map.est(t, p)
                         for skips in decisions for t in skips))


@dataclasses.dataclass
class TauLadder:
    """Pre-registered τ rungs of one artifact: ``rung_names[i]`` is the
    store entry serving ``taus[i]`` (strictly ascending).  ``active`` is
    the rung the elastic controller currently routes uncapped traffic to;
    requests with a ``max_tau`` quality floor are clamped to their highest
    admissible rung regardless of the active one."""
    name: str
    rung_names: Tuple[str, ...]
    taus: Tuple[float, ...]
    active: int = 0

    def rung_for_cap(self, max_tau: float) -> Optional[int]:
        """Highest rung index with ``tau <= max_tau`` (None when even the
        lowest rung exceeds the cap — the request must be shed)."""
        best = None
        for i, t in enumerate(self.taus):
            if t <= max_tau + 1e-12:
                best = i
        return best


class ArtifactStore:
    """Named servable entries validated against one deployment
    (architecture + solver + guidance scale)."""

    def __init__(self, cfg, solver, *, cfg_scale: Optional[float] = None,
                 health: Optional[HealthRegistry] = None):
        self.cfg = cfg
        self.solver = solver
        self.cfg_scale = cfg_scale
        self._entries: Dict[str, ServableEntry] = {}
        self._ladders: Dict[str, TauLadder] = {}
        #: per-entry serving-health ledger: failed hot-reloads are
        #: quarantined here (old entry keeps serving); engine-reported
        #: faults can mark a group unhealthy, which resolve_entry_for
        #: honors — the registry the engine consults before formation
        self.health = health if health is not None else HealthRegistry()
        #: observability hook (repro.obs.Tracer); the engine installs its
        #: tracer here so rung moves, hot reloads, and fault reports emit
        #: instant events no matter which component drives them
        self.tracer = NULL_TRACER

    # -- loading -------------------------------------------------------------

    def _build_entry(self, name: str,
                     src: Union[str, CacheArtifact],
                     policy: Optional[Union[str, dict, CachePolicy]],
                     strict: bool, version: int) -> ServableEntry:
        path = src if isinstance(src, str) else None
        art = CacheArtifact.load(src) if isinstance(src, str) else src
        override = registry.get(policy) if policy is not None else None
        pol = override if override is not None \
            else registry.from_config(art.policy)
        if strict:
            art.validate_for(
                arch=self.cfg.name, solver=self.solver.name,
                num_steps=self.solver.num_steps, cfg_scale=self.cfg_scale,
                policy=pol if isinstance(pol, AdaptivePolicy) else None)
        schedule = art.schedule if art.schedule is not None \
            else art.resolve(pol)
        plan = art.execution_plan()
        if plan is None:
            plan = plan_lib.analyze(schedule)
        proxy_map = None
        if art.adaptive and art.adaptive.get("proxy_map"):
            proxy_map = calibration_lib.ProxyMap.from_jsonable(
                art.adaptive["proxy_map"])
        if isinstance(pol, AdaptivePolicy) and pol.tau > 0 \
                and proxy_map is None:
            raise ValueError(
                f"entry {name!r}: adaptive policy with tau={pol.tau} needs "
                "an artifact carrying a fitted proxy_map — recalibrate "
                "(serving never calibrates)")
        return ServableEntry(name=name, policy=pol, schedule=schedule,
                             plan=plan, artifact=art, proxy_map=proxy_map,
                             version=version, path=path,
                             policy_override=override)

    def add_artifact(self, name: str, src: Union[str, CacheArtifact], *,
                     policy=None, strict: bool = True) -> ServableEntry:
        """Load + validate an artifact under ``name``.  ``policy``
        overrides the artifact's stored policy config (rare; e.g. serving
        a stored schedule under its non-adaptive base)."""
        if name in self._entries:
            raise ValueError(f"entry {name!r} exists; use reload() to "
                             "hot-swap it")
        entry = self._build_entry(name, src, policy, strict, version=1)
        self._entries[name] = entry
        return entry

    def add_policy(self, name: str,
                   policy: Union[str, dict, CachePolicy]) -> ServableEntry:
        """Register a calibration-free policy (``none``, ``static:n=2``)
        resolved directly against the deployment — no artifact involved.
        Calibration-based policies must arrive as artifacts."""
        if name in self._entries:
            raise ValueError(f"entry {name!r} exists; use reload() to "
                             "hot-swap it")
        pol = registry.get(policy)
        if pol.requires_calibration:
            raise ValueError(
                f"policy {pol.spec()!r} needs calibration curves; serving "
                "never calibrates — load its CacheArtifact via "
                "add_artifact() instead")
        schedule = pol.build(self.cfg.layer_types(), self.solver.num_steps)
        entry = ServableEntry(name=name, policy=pol, schedule=schedule,
                              plan=plan_lib.analyze(schedule))
        self._entries[name] = entry
        return entry

    def add_ladder(self, name: str, src: Union[str, CacheArtifact], *,
                   spec: Optional[str] = None,
                   taus: Optional[List[float]] = None,
                   strict: bool = True) -> TauLadder:
        """Register a τ **ladder**: several rungs of ONE adaptive artifact
        differing only in the runtime threshold τ — the degradation lever
        the elastic controller moves traffic across under load.

        Rungs come either from a ladder spec
        (``"adaptive:base=smoothcache(alpha=0.18),tau=[0.0,0.05,0.2]"``,
        expanded by :func:`repro.cache.registry.expand_ladder`) or from
        plain ``taus=[...]`` reusing the artifact's stored adaptive
        policy.  Each rung becomes a real store entry
        (``"<name>/tau=<v>"``) built from ``CacheArtifact.at_tau`` and
        strict-validated like any artifact; registration additionally
        validates that every rung shares the first rung's proxy→error map
        and candidate pool — the invariant that makes rung changes free
        (one fused program per bucket serves the whole ladder's τ range;
        τ is a traced argument, so no rung adds XLA programs beyond the
        per-rung budget the engine reports against).

        ``name`` itself resolves (``get``/``submit``) to the *active*
        rung; :meth:`set_rung` retargets it atomically.  Ladder rungs are
        artifact copies, so :meth:`reload` applies to individual rung
        entries, not the ladder name."""
        if name in self._entries or name in self._ladders:
            raise ValueError(f"entry {name!r} exists")
        if (spec is None) == (taus is None):
            raise ValueError("pass exactly one of spec= or taus=")
        art = CacheArtifact.load(src) if isinstance(src, str) else src
        if spec is not None:
            policies = registry.expand_ladder(spec)
        else:
            if dict(art.policy).get("name") not in ("adaptive", "teacache"):
                raise ValueError(
                    f"ladder {name!r}: taus= needs an artifact calibrated "
                    f"under an adaptive policy, got "
                    f"{dict(art.policy).get('name')!r}")
            tau_list = [float(t) for t in taus]
            if sorted(tau_list) != tau_list \
                    or len(set(tau_list)) != len(tau_list):
                raise ValueError(f"ladder taus must be strictly "
                                 f"ascending, got {tau_list}")
            policies = [registry.from_config({**dict(art.policy),
                                              "tau": t}) for t in tau_list]
        staged: Dict[str, ServableEntry] = {}
        rung_names: List[str] = []
        ref: Optional[ServableEntry] = None
        for pol in policies:
            ename = f"{name}/tau={pol.tau:g}"
            entry = self._build_entry(ename, art.at_tau(pol.tau), pol,
                                      strict, version=1)
            if ref is None:
                ref = entry
            else:
                pm = (entry.proxy_map.to_jsonable()
                      if entry.proxy_map else None)
                pm_ref = (ref.proxy_map.to_jsonable()
                          if ref.proxy_map else None)
                if pm != pm_ref:
                    raise ValueError(
                        f"ladder {name!r}: rung tau={pol.tau:g} has a "
                        "different proxy→error map than the first rung — "
                        "all rungs must share one map")
                if entry.pool() != ref.pool():
                    raise ValueError(
                        f"ladder {name!r}: rung tau={pol.tau:g} has a "
                        "different candidate pool than the first rung — "
                        "all rungs must share one pool")
            staged[ename] = entry
            rung_names.append(ename)
        # all-or-nothing: entries become visible only after every rung
        # validated, so a bad spec never leaves a partial ladder serving
        self._entries.update(staged)
        ladder = TauLadder(name=name, rung_names=tuple(rung_names),
                           taus=tuple(p.tau for p in policies))
        self._ladders[name] = ladder
        return ladder

    def reload(self, name: str,
               src: Optional[Union[str, CacheArtifact]] = None, *,
               strict: bool = True) -> ServableEntry:
        """Hot-swap ``name`` with a freshly validated artifact (default:
        re-read the entry's original path).  Validation happens *before*
        the swap: a bad replacement raises and the old entry keeps
        serving.  The new entry's ``version`` is bumped so the batcher's
        grouping key changes and records show which version served."""
        old = self.get(name)
        if src is None:
            if old.path is None:
                raise ValueError(f"entry {name!r} was not loaded from a "
                                 "path; pass the replacement explicitly")
            src = old.path
        try:
            entry = self._build_entry(name, src, old.policy_override,
                                      strict, version=old.version + 1)
        except Exception as e:
            # atomic failure: the old entry is still serving — record the
            # rejected replacement (with its reason) in the quarantine
            # ledger and re-raise for the operator
            self.health.quarantine(
                name, f"hot-reload rejected: {type(e).__name__}: {e}")
            self.tracer.instant("hot_reload_rejected", entry=name,
                                error=type(e).__name__)
            raise
        self._entries[name] = entry
        self.tracer.instant("hot_reload", entry=name,
                            version=entry.version)
        # a good swap is a fresh start: clear any quarantine record and
        # reset the entry's fault count / unhealthy flag
        self.health.clear_quarantine(name)
        self.health.mark_healthy(name)
        return entry

    # -- lookup --------------------------------------------------------------

    def get(self, name: str) -> ServableEntry:
        """Resolve an entry; a ladder name resolves to its *active* rung."""
        if name in self._ladders:
            lad = self._ladders[name]
            return self._entries[lad.rung_names[lad.active]]
        if name not in self._entries:
            raise KeyError(f"no servable entry {name!r}; have "
                           f"{sorted(self._entries)}")
        return self._entries[name]

    def ladder(self, name: str) -> TauLadder:
        if name not in self._ladders:
            raise KeyError(f"no τ ladder {name!r}; have "
                           f"{sorted(self._ladders)}")
        return self._ladders[name]

    def ladders(self) -> List[str]:
        return sorted(self._ladders)

    def set_rung(self, name: str, index: int) -> ServableEntry:
        """Retarget a ladder's active rung (clamped to the ladder) — the
        elastic controller's actuation.  Atomic from the batcher's view:
        in-flight batches keep the rung entry they snapshotted; new
        batches resolve the new rung.  Zero compiles, by construction."""
        lad = self.ladder(name)
        lad.active = max(0, min(int(index), len(lad.rung_names) - 1))
        # the one choke point every rung driver goes through (elastic
        # controller, operator, tests) — instant-event it here
        self.tracer.instant("set_rung", ladder=name, rung=lad.active,
                            tau=lad.taus[lad.active],
                            entry=lad.rung_names[lad.active])
        return self._entries[lad.rung_names[lad.active]]

    def resolve_entry_for(self, group: str, req) -> Optional[ServableEntry]:
        """The entry that should serve ``req`` under group ``group``,
        honoring the request's quality floor: for a ladder, the active
        rung clamped down to the request's ``max_tau`` cap; for a plain
        entry, the entry itself.  None means no registered rung/entry
        satisfies the floor — the engine sheds with ``quality_floor``."""
        if not self.health.is_servable(group):
            return None
        cap = getattr(req, "max_tau", None)
        if group in self._ladders:
            lad = self._ladders[group]
            idx = lad.active
            if cap is not None:
                c = lad.rung_for_cap(cap)
                if c is None:
                    return None
                idx = min(idx, c)
            name = lad.rung_names[idx]
            if not self.health.is_servable(name):
                return None
            return self._entries[name]
        entry = self.get(group)
        if cap is not None and entry.tau > cap + 1e-12:
            return None
        return entry

    # -- fault handling ------------------------------------------------------

    def report_fault(self, group: str, kind: str = "fault") -> bool:
        """Engine hook: count a serving fault against ``group`` in the
        health registry.  Returns True when this report tripped the
        registry's threshold and the group is now unservable (the engine
        sheds its traffic with reason ``unhealthy_entry`` until a
        successful :meth:`reload` or ``health.mark_healthy``)."""
        tripped = self.health.report_fault(group, kind)
        if tripped:
            self.tracer.instant("entry_unhealthy", entry=group, kind=kind)
        return tripped

    def degraded_entry_name(self, group: str,
                            level: int) -> Optional[str]:
        """The entry a faulted ``group`` request should retry on, one
        ``level`` down the degradation ladder:

        * level 0 — ``group`` itself (retry in place),
        * level 1 — the τ=0 form: a ladder's τ=0 rung, or (plain adaptive
          entries) a store-materialized ``!degraded/<group>/tau0`` entry
          built from the artifact's ``at_tau(0.0)``; None when the group
          has no distinct τ=0 form (static entries — skip to level 2),
        * level ≥ 2 — the universal :data:`FALLBACK_ENTRY` (``no_cache``:
          full compute, no reuse — the rung that cannot be poisoned by a
          mis-calibrated schedule).
        """
        if level <= 0:
            return group
        if level == 1:
            if group in self._ladders:
                lad = self._ladders[group]
                i = lad.rung_for_cap(0.0)
                if i is not None and lad.taus[i] == 0.0:
                    return lad.rung_names[i]
                return None
            entry = self.get(group)
            if (entry.adaptive and entry.tau > 0
                    and entry.artifact is not None):
                dname = f"{DEGRADED_PREFIX}{group}/tau0"
                if dname not in self._entries:
                    pol = registry.from_config(
                        {**dict(entry.artifact.policy), "tau": 0.0})
                    self._entries[dname] = self._build_entry(
                        dname, entry.artifact.at_tau(0.0), pol,
                        strict=True, version=1)
                return dname
            return None
        return self.ensure_fallback_entry()

    def ensure_fallback_entry(self) -> str:
        """Materialize (once) and name the terminal degradation rung: a
        calibration-free ``no_cache`` entry — every layer computed every
        step, nothing reused, nothing a bad artifact can corrupt."""
        if FALLBACK_ENTRY not in self._entries:
            pol = registry.get("none")
            schedule = pol.build(self.cfg.layer_types(),
                                 self.solver.num_steps)
            self._entries[FALLBACK_ENTRY] = ServableEntry(
                name=FALLBACK_ENTRY, policy=pol, schedule=schedule,
                plan=plan_lib.analyze(schedule))
        return FALLBACK_ENTRY

    def names(self) -> List[str]:
        """Real entry names (ladder rungs included, ladder aliases not —
        the program-budget sum iterates this, and the alias resolves to a
        rung that is already counted)."""
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries or name in self._ladders

    def __len__(self) -> int:
        return len(self._entries)

    def summary(self) -> str:
        rows = [f"ArtifactStore({self.cfg.name}, {self.solver.name}"
                f"x{self.solver.num_steps}, {len(self._entries)} entries)"]
        for name in self.names():
            e = self._entries[name]
            kind = "adaptive" if e.adaptive else "static"
            src = e.path or ("artifact" if e.artifact else "policy")
            rows.append(f"  {name:16s} {e.policy.spec():40s} {kind:8s} "
                        f"v{e.version} [{src}] "
                        f"compute={e.compute_fraction():.2f} "
                        f"sigs={e.plan.num_unique_signatures}")
        return "\n".join(rows)
