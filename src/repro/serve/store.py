"""Servable artifact store: strict-validated load + hot-reload.

Serving **never recalibrates** (the PR-1/PR-3 contract): every schedule a
server runs comes either from a :class:`~repro.cache.artifact.CacheArtifact`
produced by an offline calibration process, or from a calibration-free
policy (``none``, ``static:n=2``) resolved directly.  The store is the
serving side of that contract:

* :meth:`ArtifactStore.add_artifact` loads an artifact and runs the *same*
  strict validation as ``DiffusionPipeline.load_artifact``
  (``CacheArtifact.validate_for``: architecture, solver × step count,
  cfg_scale, adaptive tau/k_max/pool provenance) before the entry becomes
  visible to the batcher.
* :meth:`ArtifactStore.reload` hot-swaps an entry *atomically*: the
  replacement is fully loaded and validated first, and a bad file leaves
  the old entry serving (the swap raises instead of wedging traffic).
  Each swap bumps ``entry.version`` — in-flight batches keep the entry
  they launched with; new batches resolve the current one.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

from repro.cache import registry
from repro.cache.artifact import CacheArtifact
from repro.cache.policy import AdaptivePolicy, CachePolicy
from repro.core import calibration as calibration_lib
from repro.core import plan as plan_lib
from repro.core.schedule import Schedule


@dataclasses.dataclass
class ServableEntry:
    """Everything the engine needs to serve one policy: the resolved
    schedule, its pre-analyzed execution plan, and — for adaptive policies
    — the runtime decision parameters shipped in the artifact."""
    name: str
    policy: CachePolicy
    schedule: Schedule
    plan: plan_lib.ExecutionPlan
    artifact: Optional[CacheArtifact] = None
    proxy_map: Optional[calibration_lib.ProxyMap] = None
    version: int = 1
    path: Optional[str] = None
    #: the ``policy=`` override add_artifact() was called with, if any —
    #: reload() must re-apply it or a hot swap would silently fall back
    #: to the artifact's stored policy (e.g. flip a static-base entry
    #: back to adaptive serving)
    policy_override: Optional[CachePolicy] = None
    #: memoized candidate pool (adaptive entries) — derived once per
    #: entry, not per launched batch
    _pool: Optional[tuple] = dataclasses.field(default=None, repr=False)

    @property
    def adaptive(self) -> bool:
        return isinstance(self.policy, AdaptivePolicy)

    def pool(self) -> tuple:
        """Precompiled candidate signature pool of an adaptive entry (the
        schedule's mask lattice — already validated against the artifact's
        stored pool provenance by ``validate_for``), memoized so the
        engine derives it once per entry rather than once per batch."""
        if not self.adaptive:
            raise ValueError(f"entry {self.name!r} is not adaptive")
        if self._pool is None:
            self._pool = plan_lib.mask_lattice(self.schedule)
        return self._pool

    def pool_size(self) -> int:
        """Candidate-pool cardinality (2^|ever-skipped| for adaptive
        entries, the plan's unique signatures otherwise) — the per-entry
        factor in the host-dispatch program budget."""
        if self.adaptive:
            return len(self.pool())
        return self.plan.num_unique_signatures

    def program_cost(self, fused: bool) -> int:
        """Shape-specialized model programs this entry can compile per
        batch bucket: a fused adaptive servable compiles ONE program (the
        whole pool's branches live inside a single ``lax.switch``
        program) vs ``pool_size()`` per-signature programs under host
        dispatch; static entries compile one per plan signature."""
        if self.adaptive and fused:
            return 1
        return self.pool_size()

    @property
    def tau(self) -> float:
        return self.policy.tau if self.adaptive else 0.0

    @property
    def k_max(self) -> int:
        return self.policy.k_max

    def fingerprint(self) -> str:
        """Schedule-content digest + version — an identifier for logs and
        batch records.  (Version isolation itself needs no key: the
        batcher snapshots the current entry atomically when it forms a
        batch, so one micro-batch always serves exactly one version.)"""
        return f"{self.schedule.fingerprint()}/v{self.version}"

    def compute_fraction(self) -> float:
        """Static compute fraction of the entry's schedule (adaptive runs
        report their *realized* fraction per batch instead)."""
        import numpy as np
        return float(np.mean([1.0 - np.mean(v)
                              for v in self.schedule.skip.values()]))


class ArtifactStore:
    """Named servable entries validated against one deployment
    (architecture + solver + guidance scale)."""

    def __init__(self, cfg, solver, *, cfg_scale: Optional[float] = None):
        self.cfg = cfg
        self.solver = solver
        self.cfg_scale = cfg_scale
        self._entries: Dict[str, ServableEntry] = {}

    # -- loading -------------------------------------------------------------

    def _build_entry(self, name: str,
                     src: Union[str, CacheArtifact],
                     policy: Optional[Union[str, dict, CachePolicy]],
                     strict: bool, version: int) -> ServableEntry:
        path = src if isinstance(src, str) else None
        art = CacheArtifact.load(src) if isinstance(src, str) else src
        override = registry.get(policy) if policy is not None else None
        pol = override if override is not None \
            else registry.from_config(art.policy)
        if strict:
            art.validate_for(
                arch=self.cfg.name, solver=self.solver.name,
                num_steps=self.solver.num_steps, cfg_scale=self.cfg_scale,
                policy=pol if isinstance(pol, AdaptivePolicy) else None)
        schedule = art.schedule if art.schedule is not None \
            else art.resolve(pol)
        plan = art.execution_plan()
        if plan is None:
            plan = plan_lib.analyze(schedule)
        proxy_map = None
        if art.adaptive and art.adaptive.get("proxy_map"):
            proxy_map = calibration_lib.ProxyMap.from_jsonable(
                art.adaptive["proxy_map"])
        if isinstance(pol, AdaptivePolicy) and pol.tau > 0 \
                and proxy_map is None:
            raise ValueError(
                f"entry {name!r}: adaptive policy with tau={pol.tau} needs "
                "an artifact carrying a fitted proxy_map — recalibrate "
                "(serving never calibrates)")
        return ServableEntry(name=name, policy=pol, schedule=schedule,
                             plan=plan, artifact=art, proxy_map=proxy_map,
                             version=version, path=path,
                             policy_override=override)

    def add_artifact(self, name: str, src: Union[str, CacheArtifact], *,
                     policy=None, strict: bool = True) -> ServableEntry:
        """Load + validate an artifact under ``name``.  ``policy``
        overrides the artifact's stored policy config (rare; e.g. serving
        a stored schedule under its non-adaptive base)."""
        if name in self._entries:
            raise ValueError(f"entry {name!r} exists; use reload() to "
                             "hot-swap it")
        entry = self._build_entry(name, src, policy, strict, version=1)
        self._entries[name] = entry
        return entry

    def add_policy(self, name: str,
                   policy: Union[str, dict, CachePolicy]) -> ServableEntry:
        """Register a calibration-free policy (``none``, ``static:n=2``)
        resolved directly against the deployment — no artifact involved.
        Calibration-based policies must arrive as artifacts."""
        if name in self._entries:
            raise ValueError(f"entry {name!r} exists; use reload() to "
                             "hot-swap it")
        pol = registry.get(policy)
        if pol.requires_calibration:
            raise ValueError(
                f"policy {pol.spec()!r} needs calibration curves; serving "
                "never calibrates — load its CacheArtifact via "
                "add_artifact() instead")
        schedule = pol.build(self.cfg.layer_types(), self.solver.num_steps)
        entry = ServableEntry(name=name, policy=pol, schedule=schedule,
                              plan=plan_lib.analyze(schedule))
        self._entries[name] = entry
        return entry

    def reload(self, name: str,
               src: Optional[Union[str, CacheArtifact]] = None, *,
               strict: bool = True) -> ServableEntry:
        """Hot-swap ``name`` with a freshly validated artifact (default:
        re-read the entry's original path).  Validation happens *before*
        the swap: a bad replacement raises and the old entry keeps
        serving.  The new entry's ``version`` is bumped so the batcher's
        grouping key changes and records show which version served."""
        old = self.get(name)
        if src is None:
            if old.path is None:
                raise ValueError(f"entry {name!r} was not loaded from a "
                                 "path; pass the replacement explicitly")
            src = old.path
        entry = self._build_entry(name, src, old.policy_override, strict,
                                  version=old.version + 1)
        self._entries[name] = entry
        return entry

    # -- lookup --------------------------------------------------------------

    def get(self, name: str) -> ServableEntry:
        if name not in self._entries:
            raise KeyError(f"no servable entry {name!r}; have "
                           f"{sorted(self._entries)}")
        return self._entries[name]

    def names(self) -> List[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def summary(self) -> str:
        rows = [f"ArtifactStore({self.cfg.name}, {self.solver.name}"
                f"x{self.solver.num_steps}, {len(self._entries)} entries)"]
        for name in self.names():
            e = self._entries[name]
            kind = "adaptive" if e.adaptive else "static"
            src = e.path or ("artifact" if e.artifact else "policy")
            rows.append(f"  {name:16s} {e.policy.spec():40s} {kind:8s} "
                        f"v{e.version} [{src}] "
                        f"compute={e.compute_fraction():.2f} "
                        f"sigs={e.plan.num_unique_signatures}")
        return "\n".join(rows)
