"""Serving metrics: latency percentiles, throughput, compute, compiles.

Queue wait and service time are tracked **separately** (the old example
reported their sum under one shared submit timestamp, which degenerates to
queue position).  Realized compute fraction is the fraction of layer
evaluations actually executed — for static entries that equals the
schedule's compute fraction, for adaptive entries it comes from the run's
realized per-step decisions, weighted by batch size.  Compile counts are
injected by the engine from the executor's variant table
(``compiled_variant_count`` per kind, plus shape-specialized
``xla_program_count``) and reported against the program budget
``|buckets used| × |signature pool|``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.serve.request import Request


def percentile(xs: Sequence[float], p: float) -> float:
    """Linear-interpolation percentile (numpy-free so fake-executor tests
    stay dependency-light).  ``p`` in [0, 100]."""
    if not xs:
        raise ValueError("percentile of an empty sequence")
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    rank = (p / 100.0) * (len(s) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (rank - lo))


def _dist(xs: List[float]) -> Dict[str, float]:
    return {
        "mean": sum(xs) / len(xs),
        "p50": percentile(xs, 50),
        "p95": percentile(xs, 95),
        "max": max(xs),
    }


class ServerMetrics:
    """Accumulates per-request and per-batch observations; ``report()``
    renders one JSON-safe snapshot."""

    def __init__(self):
        self.queue_waits: List[float] = []
        self.service_times: List[float] = []
        self.first_arrival: Optional[float] = None
        self.last_finish: Optional[float] = None
        self.batches = 0
        self.bucket_counts: Dict[int, int] = {}
        self.group_requests: Dict[str, int] = {}
        self._evals_done = 0.0                # request-weighted layer evals
        self._evals_total = 0.0

    # -- observation ---------------------------------------------------------

    def observe_request(self, req: Request) -> None:
        if req.queue_wait is None or req.service_time is None:
            raise ValueError(f"request {req.rid} is missing timestamps")
        self.queue_waits.append(req.queue_wait)
        self.service_times.append(req.service_time)
        if self.first_arrival is None or req.arrival < self.first_arrival:
            self.first_arrival = req.arrival
        if self.last_finish is None or req.finished > self.last_finish:
            self.last_finish = req.finished

    def observe_batch(self, group: str, bucket: int,
                      compute_fraction: float, num_steps: int,
                      num_types: int) -> None:
        self.batches += 1
        self.bucket_counts[bucket] = self.bucket_counts.get(bucket, 0) + 1
        self.group_requests[group] = (self.group_requests.get(group, 0)
                                      + bucket)
        evals = float(num_steps * num_types * bucket)
        self._evals_total += evals
        self._evals_done += compute_fraction * evals

    # -- reporting -----------------------------------------------------------

    @property
    def requests(self) -> int:
        return len(self.queue_waits)

    def realized_compute_fraction(self) -> Optional[float]:
        if self._evals_total == 0:
            return None
        return self._evals_done / self._evals_total

    def report(self, compile_counts: Optional[Dict[str, int]] = None,
               program_budget: Optional[int] = None) -> Dict:
        """One JSON-safe snapshot.  Throughput is measured over the
        first-arrival → last-finish makespan (open-loop serving: arrival
        gaps count against the server, idle pre-warm time does not)."""
        out: Dict = {
            "requests": self.requests,
            "batches": self.batches,
            "buckets": {str(b): c
                        for b, c in sorted(self.bucket_counts.items())},
            "per_group_requests": dict(sorted(self.group_requests.items())),
            "compute_fraction": self.realized_compute_fraction(),
        }
        if self.requests:
            makespan = self.last_finish - self.first_arrival
            out["makespan_s"] = makespan
            out["throughput_rps"] = (self.requests / makespan
                                     if makespan > 0 else float("inf"))
            out["queue_wait_s"] = _dist(self.queue_waits)
            out["service_s"] = _dist(self.service_times)
        if compile_counts is not None:
            out["compiles"] = dict(compile_counts)
        if program_budget is not None:
            out["program_budget"] = program_budget
        return out
