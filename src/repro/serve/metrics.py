"""Serving metrics: latency percentiles, throughput, compute, compiles.

Queue wait and service time are tracked **separately** (the old example
reported their sum under one shared submit timestamp, which degenerates to
queue position).  Realized compute fraction is the fraction of layer
evaluations actually executed — for static entries that equals the
schedule's compute fraction, for adaptive entries it comes from the run's
realized per-step decisions, weighted by batch size.  Compile counts are
injected by the engine from the executor's variant table
(``compiled_variant_count`` per kind, plus shape-specialized
``xla_program_count``) and reported against the program budget
``|buckets used| × |signature pool|``.

SLO accounting (the ``repro.slo`` layer feeds it): deadline **attainment**
over deadline-carrying requests, **goodput** (deadline-met work) vs
throughput over all *offered* traffic — shed and deferred requests are
explicit outcomes with reasons, counted in the denominator, never
silently dropped — plus the realized-τ histogram and predicted quality
cost under the elastic τ controller.

Since the ``repro.obs`` layer landed, :class:`ServerMetrics` is a **view
over a** :class:`~repro.obs.MetricsRegistry`: every ``observe_*`` call
writes named registry instruments (counters with labels, histograms with
raw samples), and the attribute surface tests and callers use —
``metrics.joins``, ``metrics.fault_kinds``, ``metrics.queue_waits`` — is
reconstructed from the registry on read.  ``report()`` is byte-stable
with the pre-registry shape (extended, never reshaped), and the same
numbers are additionally available as a JSON ``registry.snapshot()`` or
Prometheus-style ``registry.exposition()``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

from repro.obs import MetricsRegistry
from repro.serve.request import Request


def percentile(xs: Sequence[float], p: float) -> float:
    """Linear-interpolation percentile (numpy-free so fake-executor tests
    stay dependency-light).  ``p`` in [0, 100]; NaN/inf samples are
    rejected — sorting them would silently corrupt every quantile (NaN
    compares unordered, so ``sorted`` leaves it wherever it started)."""
    if not xs:
        raise ValueError("percentile of an empty sequence")
    if not 0 <= p <= 100:
        raise ValueError(f"percentile p must be in [0, 100], got {p}")
    for x in xs:
        if not math.isfinite(x):
            raise ValueError(f"percentile over non-finite sample {x!r}")
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    rank = (p / 100.0) * (len(s) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (rank - lo))


def _dist(xs: Sequence[float]) -> Dict[str, Optional[float]]:
    # empty-safe: shed-heavy scenarios legitimately produce zero-sample
    # distributions (e.g. every request of a group rejected) — report
    # them as null fields, never ZeroDivisionError/IndexError.  Non-finite
    # samples raise (via percentile) — they mean an upstream accounting
    # bug, not a legitimate latency.
    xs = list(xs)
    if not xs:
        return {"mean": None, "p50": None, "p95": None, "max": None,
                "n": 0}
    p50 = percentile(xs, 50)
    return {
        "mean": sum(xs) / len(xs),
        "p50": p50,
        "p95": percentile(xs, 95),
        "max": max(xs),
        "n": len(xs),
    }


class ServerMetrics:
    """Accumulates per-request and per-batch observations; ``report()``
    renders one JSON-safe snapshot.  All state lives in the
    :class:`~repro.obs.MetricsRegistry` (pass one to share it with the
    engine's tracer/controller plumbing; one is created otherwise)."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.first_arrival: Optional[float] = None
        self.last_finish: Optional[float] = None

    # -- observation ---------------------------------------------------------

    def observe_request(self, req: Request) -> None:
        if req.queue_wait is None or req.service_time is None:
            raise ValueError(f"request {req.rid} is missing timestamps")
        reg = self.registry
        reg.observe("serve.queue_wait_s", req.queue_wait)
        reg.observe("serve.service_s", req.service_time)
        if req.joined_at is not None:
            # joiner-specific wait: a boundary join ends the queue wait
            # at the chaser launch — this distribution is what the join
            # mechanism is supposed to improve
            reg.observe("serve.queue_wait_joined_s", req.queue_wait)
        if self.first_arrival is None or req.arrival < self.first_arrival:
            self.first_arrival = req.arrival
        if self.last_finish is None or req.finished > self.last_finish:
            self.last_finish = req.finished
        deadline = getattr(req, "deadline", None)
        attained = deadline is None or req.finished <= deadline
        if deadline is not None:
            reg.inc("slo.with_deadline")
            if attained:
                reg.inc("slo.attained")
        if attained:
            reg.inc("slo.good")

    def observe_shed(self, req: Request, reason: str, now: float) -> None:
        """A rejected request: counted against attainment and goodput
        (its deadline — if any — is definitionally missed)."""
        self.registry.inc("serve.shed", reason=reason)
        if getattr(req, "deadline", None) is not None:
            self.registry.inc("slo.with_deadline")
        if req.arrival is not None and (
                self.first_arrival is None
                or req.arrival < self.first_arrival):
            self.first_arrival = req.arrival

    def observe_defer(self, req: Request, now: float) -> None:
        self.registry.inc("serve.deferrals")

    # -- resilience ----------------------------------------------------------

    def observe_fault(self, group: str, kind: str) -> None:
        """One micro-batch fault (NaN latent, stuck advance, injected
        error, …) — counted per kind and per serving group."""
        self.registry.inc("resilience.faults", kind=kind)
        self.registry.inc("resilience.fault_groups", group=group)

    def observe_retry(self, req: Request) -> None:
        self.registry.inc("resilience.retries")

    def observe_requeue(self, n: int = 1) -> None:
        """Healthy survivors of an aborted batch put back in the queue at
        their original arrival."""
        self.registry.inc("resilience.requeued", int(n))

    def observe_degrade(self, req: Request) -> None:
        """A faulted request stepped down the degradation ladder for its
        retry (rung → τ=0 → no_cache)."""
        self.registry.inc("resilience.degraded")

    def observe_reject(self, reason: str) -> None:
        """A submission rejected at the door with a reasoned outcome
        (``no_entry``, ``duplicate_rid``) instead of an engine-killing
        exception."""
        self.registry.inc("serve.rejects", reason=reason)

    # -- continuous batching -------------------------------------------------

    def observe_join(self, n: int = 1) -> None:
        """``n`` waiting requests joined an in-flight run at a boundary —
        their queue wait ends at the join launch, not at batch finish."""
        self.registry.inc("continuous.joins")
        self.registry.inc("continuous.joined_requests", int(n))

    def observe_regroup(self, n_subruns: int) -> None:
        """One in-flight batch split into ``n_subruns`` by realized mask
        signature at a chunk/segment boundary."""
        self.registry.inc("continuous.regroups")

    def observe_merge(self, n: int = 1, kind: str = "join") -> None:
        """``n`` run-state merges; ``kind`` distinguishes chaser catch-up
        (``join``) from opportunistic ``coalesce``."""
        self.registry.inc("continuous.merges", int(n), kind=kind)

    def observe_row_retry(self, n: int = 1) -> None:
        """``n`` faulted rows split out of a continuing batch for retry
        while the survivors kept their run-state."""
        self.registry.inc("continuous.row_retries", int(n))

    def observe_lineage(self, tag: str, n: int = 1) -> None:
        """``n`` run-state lineage events of one kind (``join`` /
        ``regroup`` / ``coalesce`` / ``split_retry``) — the first-class
        form of the counts encoded in ``BatchRecord.lineage`` tags."""
        self.registry.inc("continuous.lineage", int(n), event=tag)

    def observe_quality(self, tau: float, quality_cost: Optional[float],
                        n: int = 1) -> None:
        """Realized τ (and predicted quality cost, when the entry carries
        a proxy→error map) of ``n`` requests served by one batch."""
        t = round(float(tau), 6)
        self.registry.inc("serve.realized_tau", n, tau=repr(t))
        if quality_cost is not None:
            for _ in range(int(n)):
                self.registry.observe("serve.quality_cost",
                                      float(quality_cost))

    def observe_batch(self, group: str, bucket: int,
                      compute_fraction: float, num_steps: int,
                      num_types: int) -> None:
        reg = self.registry
        reg.inc("serve.batches")
        reg.inc("serve.bucket_counts", bucket=int(bucket))
        reg.inc("serve.group_requests", int(bucket), group=group)
        evals = float(num_steps * num_types * bucket)
        reg.inc("serve.evals_total", evals)
        reg.inc("serve.evals_done", compute_fraction * evals)

    # -- durability ----------------------------------------------------------

    def observe_checkpoint(self, nbytes: int) -> None:
        """One boundary run-state snapshot written."""
        self.registry.inc("durable.checkpoints")
        self.registry.inc("durable.checkpoint_bytes", int(nbytes))

    def observe_checkpoint_error(self) -> None:
        """A checkpoint attempt failed and was swallowed (degrade, don't
        die: serving continues, the batch just loses restore coverage)."""
        self.registry.inc("durable.checkpoint_errors")

    def observe_snapshot_refused(self) -> None:
        """Recovery refused a snapshot (torn / tampered / provenance
        drift) and quarantined it — its requests replay from the start."""
        self.registry.inc("durable.snapshots_refused")

    def observe_recovery(self, restored_runs: int, restored_requests: int,
                         replayed: int, stale: int) -> None:
        reg = self.registry
        reg.inc("durable.recoveries")
        reg.inc("durable.restored_runs", int(restored_runs))
        reg.inc("durable.restored_requests", int(restored_requests))
        reg.inc("durable.replayed_requests", int(replayed))
        reg.inc("durable.snapshots_stale", int(stale))

    # -- registry-backed attribute view --------------------------------------
    # The pre-obs ServerMetrics exposed these as plain attributes; tests,
    # benchmarks, and the SLO/resilience layers read them — keep every one
    # as a property over the registry.

    @property
    def queue_waits(self) -> List[float]:
        return self.registry.samples("serve.queue_wait_s")

    @property
    def service_times(self) -> List[float]:
        return self.registry.samples("serve.service_s")

    @property
    def joined_queue_waits(self) -> List[float]:
        return self.registry.samples("serve.queue_wait_joined_s")

    @property
    def quality_costs(self) -> List[float]:
        return self.registry.samples("serve.quality_cost")

    @property
    def batches(self) -> int:
        return int(self.registry.counter("serve.batches"))

    @property
    def bucket_counts(self) -> Dict[int, int]:
        return {int(k): int(v) for k, v in
                self.registry.labeled("serve.bucket_counts",
                                      "bucket").items()}

    @property
    def group_requests(self) -> Dict[str, int]:
        return {k: int(v) for k, v in
                self.registry.labeled("serve.group_requests",
                                      "group").items()}

    @property
    def shed_total(self) -> int:
        return int(self.registry.counter_total("serve.shed"))

    @property
    def shed_reasons(self) -> Dict[str, int]:
        return {k: int(v) for k, v in
                self.registry.labeled("serve.shed", "reason").items()}

    @property
    def deferrals(self) -> int:
        return int(self.registry.counter("serve.deferrals"))

    @property
    def slo_total(self) -> int:
        return int(self.registry.counter("slo.with_deadline"))

    @property
    def slo_attained(self) -> int:
        return int(self.registry.counter("slo.attained"))

    @property
    def good(self) -> int:
        return int(self.registry.counter("slo.good"))

    @property
    def tau_counts(self) -> Dict[float, int]:
        return {float(k): int(v) for k, v in
                self.registry.labeled("serve.realized_tau", "tau").items()}

    @property
    def faults_total(self) -> int:
        return int(self.registry.counter_total("resilience.faults"))

    @property
    def fault_kinds(self) -> Dict[str, int]:
        return {k: int(v) for k, v in
                self.registry.labeled("resilience.faults", "kind").items()}

    @property
    def fault_groups(self) -> Dict[str, int]:
        return {k: int(v) for k, v in
                self.registry.labeled("resilience.fault_groups",
                                      "group").items()}

    @property
    def retries(self) -> int:
        return int(self.registry.counter("resilience.retries"))

    @property
    def requeued(self) -> int:
        return int(self.registry.counter("resilience.requeued"))

    @property
    def degraded(self) -> int:
        return int(self.registry.counter("resilience.degraded"))

    @property
    def rejects(self) -> Dict[str, int]:
        return {k: int(v) for k, v in
                self.registry.labeled("serve.rejects", "reason").items()}

    @property
    def checkpoints(self) -> int:
        return int(self.registry.counter("durable.checkpoints"))

    @property
    def checkpoint_bytes(self) -> int:
        return int(self.registry.counter("durable.checkpoint_bytes"))

    @property
    def checkpoint_errors(self) -> int:
        return int(self.registry.counter("durable.checkpoint_errors"))

    @property
    def snapshots_refused(self) -> int:
        return int(self.registry.counter("durable.snapshots_refused"))

    @property
    def recoveries(self) -> int:
        return int(self.registry.counter("durable.recoveries"))

    @property
    def restored_runs(self) -> int:
        return int(self.registry.counter("durable.restored_runs"))

    @property
    def restored_requests(self) -> int:
        return int(self.registry.counter("durable.restored_requests"))

    @property
    def replayed_requests(self) -> int:
        return int(self.registry.counter("durable.replayed_requests"))

    @property
    def joins(self) -> int:
        return int(self.registry.counter("continuous.joins"))

    @property
    def joined_requests(self) -> int:
        return int(self.registry.counter("continuous.joined_requests"))

    @property
    def regroups(self) -> int:
        return int(self.registry.counter("continuous.regroups"))

    @property
    def merges(self) -> int:
        return int(self.registry.counter_total("continuous.merges"))

    @property
    def row_retries(self) -> int:
        return int(self.registry.counter("continuous.row_retries"))

    @property
    def lineage_events(self) -> Dict[str, int]:
        return {k: int(v) for k, v in
                self.registry.labeled("continuous.lineage",
                                      "event").items()}

    # -- reporting -----------------------------------------------------------

    @property
    def requests(self) -> int:
        return len(self.queue_waits)

    def realized_compute_fraction(self) -> Optional[float]:
        total = self.registry.counter("serve.evals_total")
        if total == 0:
            return None
        return self.registry.counter("serve.evals_done") / total

    def report(self, compile_counts: Optional[Dict[str, int]] = None,
               program_budget: Optional[int] = None) -> Dict:
        """One JSON-safe snapshot.  Throughput is measured over the
        first-arrival → last-finish makespan (open-loop serving: arrival
        gaps count against the server, idle pre-warm time does not)."""
        requests = self.requests
        offered = requests + self.shed_total
        merges_by_kind = self.registry.labeled("continuous.merges",
                                               "kind")
        out: Dict = {
            "requests": requests,
            "batches": self.batches,
            "buckets": {str(b): c
                        for b, c in sorted(self.bucket_counts.items())},
            "per_group_requests": dict(sorted(self.group_requests.items())),
            "compute_fraction": self.realized_compute_fraction(),
            "shed": {"total": self.shed_total,
                     "reasons": dict(sorted(self.shed_reasons.items()))},
            "deferrals": self.deferrals,
        }
        # SLO attainment over deadline-carrying requests (shed ones count
        # as missed); goodput over *offered* traffic — throughput counts
        # everything finished, goodput only deadline-met work, so shedding
        # can never dress up as service
        out["slo"] = {
            "with_deadline": self.slo_total,
            "attained": self.slo_attained,
            "attainment": (self.slo_attained / self.slo_total
                           if self.slo_total else None),
            "good_requests": self.good,
            "offered": offered,
            "goodput_fraction": (self.good / offered if offered else None),
        }
        out["faults"] = {
            "total": self.faults_total,
            "kinds": dict(sorted(self.fault_kinds.items())),
            "groups": dict(sorted(self.fault_groups.items())),
            "retries": self.retries,
            "requeued": self.requeued,
            "degraded": self.degraded,
            "rejected_submissions": dict(sorted(self.rejects.items())),
        }
        out["continuous"] = {
            "joins": self.joins,
            "joined_requests": self.joined_requests,
            "regroups": self.regroups,
            "merges": self.merges,
            "join_merges": int(merges_by_kind.get("join", 0)),
            "coalesces": int(merges_by_kind.get("coalesce", 0)),
            "row_retries": self.row_retries,
            "lineage_events": dict(sorted(self.lineage_events.items())),
            "joined_queue_wait_s": _dist(self.joined_queue_waits),
        }
        out["durable"] = {
            "checkpoints": self.checkpoints,
            "checkpoint_bytes": self.checkpoint_bytes,
            "checkpoint_errors": self.checkpoint_errors,
            "snapshots_refused": self.snapshots_refused,
            "recoveries": self.recoveries,
            "restored_runs": self.restored_runs,
            "restored_requests": self.restored_requests,
            "replayed_requests": self.replayed_requests,
            "snapshots_stale": int(
                self.registry.counter("durable.snapshots_stale")),
        }
        out["realized_tau"] = {f"{t:g}": c for t, c in
                               sorted(self.tau_counts.items())}
        out["predicted_quality_cost"] = _dist(self.quality_costs)
        if requests:
            makespan = self.last_finish - self.first_arrival
            out["makespan_s"] = makespan
            out["throughput_rps"] = (requests / makespan
                                     if makespan > 0 else float("inf"))
            out["slo"]["goodput_rps"] = (self.good / makespan
                                         if makespan > 0 else float("inf"))
            out["queue_wait_s"] = _dist(self.queue_waits)
            out["service_s"] = _dist(self.service_times)
        if compile_counts is not None:
            out["compiles"] = dict(compile_counts)
        if program_budget is not None:
            out["program_budget"] = program_budget
        return out
