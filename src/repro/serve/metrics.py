"""Serving metrics: latency percentiles, throughput, compute, compiles.

Queue wait and service time are tracked **separately** (the old example
reported their sum under one shared submit timestamp, which degenerates to
queue position).  Realized compute fraction is the fraction of layer
evaluations actually executed — for static entries that equals the
schedule's compute fraction, for adaptive entries it comes from the run's
realized per-step decisions, weighted by batch size.  Compile counts are
injected by the engine from the executor's variant table
(``compiled_variant_count`` per kind, plus shape-specialized
``xla_program_count``) and reported against the program budget
``|buckets used| × |signature pool|``.

SLO accounting (the ``repro.slo`` layer feeds it): deadline **attainment**
over deadline-carrying requests, **goodput** (deadline-met work) vs
throughput over all *offered* traffic — shed and deferred requests are
explicit outcomes with reasons, counted in the denominator, never
silently dropped — plus the realized-τ histogram and predicted quality
cost under the elastic τ controller.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.serve.request import Request


def percentile(xs: Sequence[float], p: float) -> float:
    """Linear-interpolation percentile (numpy-free so fake-executor tests
    stay dependency-light).  ``p`` in [0, 100]."""
    if not xs:
        raise ValueError("percentile of an empty sequence")
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    rank = (p / 100.0) * (len(s) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (rank - lo))


def _dist(xs: List[float]) -> Dict[str, Optional[float]]:
    # empty-safe: shed-heavy scenarios legitimately produce zero-sample
    # distributions (e.g. every request of a group rejected) — report
    # them as null fields, never ZeroDivisionError/IndexError
    if not xs:
        return {"mean": None, "p50": None, "p95": None, "max": None,
                "n": 0}
    return {
        "mean": sum(xs) / len(xs),
        "p50": percentile(xs, 50),
        "p95": percentile(xs, 95),
        "max": max(xs),
        "n": len(xs),
    }


class ServerMetrics:
    """Accumulates per-request and per-batch observations; ``report()``
    renders one JSON-safe snapshot."""

    def __init__(self):
        self.queue_waits: List[float] = []
        self.service_times: List[float] = []
        self.first_arrival: Optional[float] = None
        self.last_finish: Optional[float] = None
        self.batches = 0
        self.bucket_counts: Dict[int, int] = {}
        self.group_requests: Dict[str, int] = {}
        self._evals_done = 0.0                # request-weighted layer evals
        self._evals_total = 0.0
        # SLO accounting: shed/deferred requests are first-class outcomes,
        # never silently dropped — they widen goodput's denominator
        self.shed_total = 0
        self.shed_reasons: Dict[str, int] = {}
        self.deferrals = 0
        self.slo_total = 0                    # requests carrying a deadline
        self.slo_attained = 0                 # ... that finished in time
        self.good = 0                         # finished ∧ deadline attained
        self.tau_counts: Dict[float, int] = {}    # realized-τ histogram
        self.quality_costs: List[float] = []  # predicted per-request cost
        # resilience accounting: every fault, retry, survivor re-queue,
        # ladder degradation, and rejected submission is a counted event
        self.faults_total = 0
        self.fault_kinds: Dict[str, int] = {}
        self.fault_groups: Dict[str, int] = {}
        self.retries = 0
        self.requeued = 0                     # healthy survivors re-queued
        self.degraded = 0                     # requests stepped down-ladder
        self.rejects: Dict[str, int] = {}     # submit-time rejections
        # continuous batching: boundary joins, mask-signature regroups,
        # opportunistic coalesces, and per-row retries (faulted rows split
        # out while survivors keep their run-state)
        self.joins = 0                        # chaser launches
        self.joined_requests = 0
        self.regroups = 0                     # signature-driven splits
        self.merges = 0                       # run-state merges
        self.row_retries = 0                  # rows split out for retry

    # -- observation ---------------------------------------------------------

    def observe_request(self, req: Request) -> None:
        if req.queue_wait is None or req.service_time is None:
            raise ValueError(f"request {req.rid} is missing timestamps")
        self.queue_waits.append(req.queue_wait)
        self.service_times.append(req.service_time)
        if self.first_arrival is None or req.arrival < self.first_arrival:
            self.first_arrival = req.arrival
        if self.last_finish is None or req.finished > self.last_finish:
            self.last_finish = req.finished
        deadline = getattr(req, "deadline", None)
        attained = deadline is None or req.finished <= deadline
        if deadline is not None:
            self.slo_total += 1
            self.slo_attained += int(attained)
        self.good += int(attained)

    def observe_shed(self, req: Request, reason: str, now: float) -> None:
        """A rejected request: counted against attainment and goodput
        (its deadline — if any — is definitionally missed)."""
        self.shed_total += 1
        self.shed_reasons[reason] = self.shed_reasons.get(reason, 0) + 1
        if getattr(req, "deadline", None) is not None:
            self.slo_total += 1
        if req.arrival is not None and (
                self.first_arrival is None
                or req.arrival < self.first_arrival):
            self.first_arrival = req.arrival

    def observe_defer(self, req: Request, now: float) -> None:
        self.deferrals += 1

    # -- resilience ----------------------------------------------------------

    def observe_fault(self, group: str, kind: str) -> None:
        """One micro-batch fault (NaN latent, stuck advance, injected
        error, …) — counted per kind and per serving group."""
        self.faults_total += 1
        self.fault_kinds[kind] = self.fault_kinds.get(kind, 0) + 1
        self.fault_groups[group] = self.fault_groups.get(group, 0) + 1

    def observe_retry(self, req: Request) -> None:
        self.retries += 1

    def observe_requeue(self, n: int = 1) -> None:
        """Healthy survivors of an aborted batch put back in the queue at
        their original arrival."""
        self.requeued += int(n)

    def observe_degrade(self, req: Request) -> None:
        """A faulted request stepped down the degradation ladder for its
        retry (rung → τ=0 → no_cache)."""
        self.degraded += 1

    def observe_reject(self, reason: str) -> None:
        """A submission rejected at the door with a reasoned outcome
        (``no_entry``, ``duplicate_rid``) instead of an engine-killing
        exception."""
        self.rejects[reason] = self.rejects.get(reason, 0) + 1

    # -- continuous batching -------------------------------------------------

    def observe_join(self, n: int = 1) -> None:
        """``n`` waiting requests joined an in-flight run at a boundary —
        their queue wait ends at the join launch, not at batch finish."""
        self.joins += 1
        self.joined_requests += int(n)

    def observe_regroup(self, n_subruns: int) -> None:
        """One in-flight batch split into ``n_subruns`` by realized mask
        signature at a chunk/segment boundary."""
        self.regroups += 1

    def observe_merge(self, n: int = 1) -> None:
        """``n`` run-state merges (chaser catch-up or coalesce)."""
        self.merges += int(n)

    def observe_row_retry(self, n: int = 1) -> None:
        """``n`` faulted rows split out of a continuing batch for retry
        while the survivors kept their run-state."""
        self.row_retries += int(n)

    def observe_quality(self, tau: float, quality_cost: Optional[float],
                        n: int = 1) -> None:
        """Realized τ (and predicted quality cost, when the entry carries
        a proxy→error map) of ``n`` requests served by one batch."""
        t = round(float(tau), 6)
        self.tau_counts[t] = self.tau_counts.get(t, 0) + n
        if quality_cost is not None:
            self.quality_costs.extend([float(quality_cost)] * n)

    def observe_batch(self, group: str, bucket: int,
                      compute_fraction: float, num_steps: int,
                      num_types: int) -> None:
        self.batches += 1
        self.bucket_counts[bucket] = self.bucket_counts.get(bucket, 0) + 1
        self.group_requests[group] = (self.group_requests.get(group, 0)
                                      + bucket)
        evals = float(num_steps * num_types * bucket)
        self._evals_total += evals
        self._evals_done += compute_fraction * evals

    # -- reporting -----------------------------------------------------------

    @property
    def requests(self) -> int:
        return len(self.queue_waits)

    def realized_compute_fraction(self) -> Optional[float]:
        if self._evals_total == 0:
            return None
        return self._evals_done / self._evals_total

    def report(self, compile_counts: Optional[Dict[str, int]] = None,
               program_budget: Optional[int] = None) -> Dict:
        """One JSON-safe snapshot.  Throughput is measured over the
        first-arrival → last-finish makespan (open-loop serving: arrival
        gaps count against the server, idle pre-warm time does not)."""
        offered = self.requests + self.shed_total
        out: Dict = {
            "requests": self.requests,
            "batches": self.batches,
            "buckets": {str(b): c
                        for b, c in sorted(self.bucket_counts.items())},
            "per_group_requests": dict(sorted(self.group_requests.items())),
            "compute_fraction": self.realized_compute_fraction(),
            "shed": {"total": self.shed_total,
                     "reasons": dict(sorted(self.shed_reasons.items()))},
            "deferrals": self.deferrals,
        }
        # SLO attainment over deadline-carrying requests (shed ones count
        # as missed); goodput over *offered* traffic — throughput counts
        # everything finished, goodput only deadline-met work, so shedding
        # can never dress up as service
        out["slo"] = {
            "with_deadline": self.slo_total,
            "attained": self.slo_attained,
            "attainment": (self.slo_attained / self.slo_total
                           if self.slo_total else None),
            "good_requests": self.good,
            "offered": offered,
            "goodput_fraction": (self.good / offered if offered else None),
        }
        out["faults"] = {
            "total": self.faults_total,
            "kinds": dict(sorted(self.fault_kinds.items())),
            "groups": dict(sorted(self.fault_groups.items())),
            "retries": self.retries,
            "requeued": self.requeued,
            "degraded": self.degraded,
            "rejected_submissions": dict(sorted(self.rejects.items())),
        }
        out["continuous"] = {
            "joins": self.joins,
            "joined_requests": self.joined_requests,
            "regroups": self.regroups,
            "merges": self.merges,
            "row_retries": self.row_retries,
        }
        out["realized_tau"] = {f"{t:g}": c for t, c in
                               sorted(self.tau_counts.items())}
        out["predicted_quality_cost"] = _dist(self.quality_costs)
        if self.requests:
            makespan = self.last_finish - self.first_arrival
            out["makespan_s"] = makespan
            out["throughput_rps"] = (self.requests / makespan
                                     if makespan > 0 else float("inf"))
            out["slo"]["goodput_rps"] = (self.good / makespan
                                         if makespan > 0 else float("inf"))
            out["queue_wait_s"] = _dist(self.queue_waits)
            out["service_s"] = _dist(self.service_times)
        if compile_counts is not None:
            out["compiles"] = dict(compile_counts)
        if program_budget is not None:
            out["program_budget"] = program_budget
        return out
