"""Admission + micro-batching.

Requests are grouped by **store entry** (their policy name) and emitted
in **power-of-two buckets**; the entry — schedule, plan, version — is
snapshotted atomically at batch formation, so a micro-batch always runs
one signature set and one version even across hot swaps.  Compiled
programs specialize on batch shape,
so admitting arbitrary tail sizes would compile one program set per size;
padding tails to the full batch (the old example's strategy) wastes the
padded rows' compute instead.  Power-of-two buckets are the middle ground:
a tail of 5 requests runs as a 4-batch plus a 1-batch, every row is a real
request, and the shape-specialized program count is bounded by
``log2(max_batch)+1`` buckets × the signature pool — the program-budget
math the engine's metrics report against.

Formation policy per group, evaluated oldest-request-first:

* a full ``max_batch`` bucket forms immediately;
* a partial bucket forms once the group's oldest ready request has waited
  ``max_wait`` (0 ⇒ greedy: partial buckets form as soon as the engine has
  capacity — lowest latency, more small-bucket programs);
* otherwise the group holds, accumulating arrivals.

Groups are drained round-robin so a busy policy cannot starve a quiet one.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.obs import NULL_TRACER
from repro.serve.request import Request, RequestQueue
from repro.serve.store import ArtifactStore, ServableEntry


def bucket_for(n: int, max_batch: int) -> int:
    """Largest power-of-two ≤ min(n, max_batch)."""
    if n < 1:
        raise ValueError(f"bucket_for needs n >= 1, got {n}")
    b = 1
    while b * 2 <= min(n, max_batch):
        b *= 2
    return b


def bucket_sizes(max_batch: int) -> Tuple[int, ...]:
    """The admissible bucket set {1, 2, 4, ..., max_batch}."""
    out, b = [], 1
    while b <= max_batch:
        out.append(b)
        b *= 2
    return tuple(out)


@dataclasses.dataclass
class MicroBatch:
    """A formed batch: compatible requests + the store entry (snapshotted
    at formation, so a hot swap never changes an already-formed batch)."""
    requests: Tuple[Request, ...]
    entry: ServableEntry
    formed_at: float

    @property
    def bucket(self) -> int:
        return len(self.requests)

    @property
    def group(self) -> str:
        return self.entry.name

    @property
    def rids(self) -> Tuple[int, ...]:
        return tuple(r.rid for r in self.requests)

    @property
    def seeds(self) -> Tuple[int, ...]:
        return tuple(r.seed for r in self.requests)

    @property
    def labels(self) -> Tuple[Optional[int], ...]:
        return tuple(r.label for r in self.requests)


class MicroBatcher:
    """Pulls ready requests from a :class:`RequestQueue` and forms
    :class:`MicroBatch` es against the current store entries."""

    def __init__(self, queue: RequestQueue, store: ArtifactStore, *,
                 max_batch: int = 8, max_wait: float = 0.0):
        if max_batch < 1 or (max_batch & (max_batch - 1)) != 0:
            raise ValueError(f"max_batch must be a power of two, got "
                             f"{max_batch}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.queue = queue
        self.store = store
        self.max_batch = max_batch
        self.max_wait = max_wait
        self._rr: List[str] = []              # round-robin group order
        #: observability hook; the engine installs its tracer so batch
        #: formation emits instant events on the engine track
        self.tracer = NULL_TRACER

    def _group_order(self, groups) -> List[str]:
        for g in sorted(groups):
            if g not in self._rr:
                self._rr.append(g)
        return [g for g in self._rr if g in groups]

    def next_batch(self, now: float) -> Optional[MicroBatch]:
        """Form and return one micro-batch, or None if no group is ready
        to form one at ``now``.  Unknown policy names raise KeyError —
        submission should have validated against the store.

        A group's requests resolve to a store entry through
        ``store.resolve_entry_for`` (for τ ladders: the active rung,
        clamped to each request's quality floor), and one micro-batch
        runs one entry — so the batch is the oldest resolvable request's
        rung plus every group-mate sharing it; other rungs' requests stay
        queued for the next formation pass.  Quality-infeasible requests
        (no admissible rung) are skipped here; the engine's SLO sweep
        sheds them with an explicit reason."""
        groups = self.queue.ready_groups(now)
        for g in self._group_order(groups):
            entry, eligible = None, []
            for r in self.queue.peek(g, now):
                e = self.store.resolve_entry_for(g, r)
                if e is None:
                    continue
                if entry is None:
                    entry = e
                    eligible = [r]
                elif e.name == entry.name:
                    eligible.append(r)
            if entry is None:
                continue
            n = len(eligible)
            if n >= self.max_batch:
                take = self.max_batch
            elif self.max_wait == 0.0 or (
                    now >= eligible[0].arrival + self.max_wait):
                # the expiry test must be the SAME float expression
                # ``arrival + max_wait`` that next_event() reports: under
                # a virtual clock the engine sleeps to exactly that value,
                # and ``now - arrival >= max_wait`` can round the other
                # way ((a+w)-a < w), freezing the clock in a livelock
                take = bucket_for(n, self.max_batch)
            else:
                continue
            reqs = tuple(self.queue.take_rids(
                g, [r.rid for r in eligible[:take]], now))
            # move the drained group to the back of the rotation
            self._rr.remove(g)
            self._rr.append(g)
            if self.tracer.enabled:
                self.tracer.instant(
                    "form", group=g, entry=entry.name, bucket=len(reqs),
                    rids=[r.rid for r in reqs],
                    oldest_wait_s=now - reqs[0].arrival)
            return MicroBatch(requests=reqs, entry=entry, formed_at=now)
        return None

    def take_join(self, now: float, entry: ServableEntry,
                  bucket: int) -> List[Request]:
        """Continuous feeder: lift up to ``k`` waiting requests that could
        *join* an in-flight run of ``entry`` whose current batch size is
        ``bucket`` — the largest ``k`` with both ``k`` and ``bucket + k``
        admissible power-of-two buckets (the joiners run as their own
        catch-up batch before merging, so *both* shapes must already be
        in the compiled set; for p2 buckets that means ``k == bucket``,
        i.e. a join doubles).  Candidates must resolve to the **same entry name
        and version** the run snapshotted at formation: a hot swap or a
        ladder move between formation and the boundary makes a request
        join-ineligible rather than silently running a stale (or wrong)
        artifact.  Returns ``[]`` when nothing fits; requests are taken in
        the queue's ``(-priority, arrival, rid)`` ready order."""
        sizes = set(bucket_sizes(self.max_batch))
        grown = [s for s in sizes if s > bucket and (s - bucket) in sizes]
        if not grown:
            return []                         # already at max_batch
        out: List[Request] = []
        src = None
        for g in self._group_order(self.queue.ready_groups(now)):
            for r in self.queue.peek(g, now):
                e = self.store.resolve_entry_for(g, r)
                if (e is None or e.name != entry.name
                        or e.version != entry.version):
                    continue
                out.append(r)
            if out:
                src = g
                break
        # keep the joined size on an admissible bucket: largest k with
        # bucket + k in the p2 set
        best = max((s - bucket for s in grown if s - bucket <= len(out)),
                   default=0)
        if best <= 0:
            return []
        return self.queue.take_rids(src, [r.rid for r in out[:best]], now)

    def next_event(self, now: float) -> Optional[float]:
        """Earliest future time at which a batch *could* form: the next
        arrival, or a held group's hold window expiring.  None when the
        queue is empty.

        The hold candidate is based on the group's oldest *resolvable*
        request — the same request whose arrival anchors next_batch()'s
        expiry test — so the time reported here is guaranteed to actually
        form a batch (quality-infeasible requests never expire a window;
        the engine's SLO sweep sheds them)."""
        candidates = []
        nxt = self.queue.next_arrival(now)
        if nxt is not None:
            candidates.append(nxt)
        for g in self.queue.ready_groups(now):
            for r in self.queue.peek(g, now):
                if self.store.resolve_entry_for(g, r) is not None:
                    candidates.append(max(now, r.arrival + self.max_wait))
                    break
        return min(candidates) if candidates else None
