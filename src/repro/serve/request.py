"""Requests, clocks, and the arrival queue.

A :class:`Request` is one generation job: a seed, an optional class label,
and the name of the :class:`~repro.serve.store.ArtifactStore` entry whose
schedule/plan should serve it.  Requests carry *real* arrival timestamps —
queue wait and service time are separate, measurable quantities (the old
``examples/serve_diffusion.py`` stamped every request with one shared
submit time, so its "latency" was just queue position).

Time comes from a :class:`Clock` so the whole serving stack runs in two
modes: :class:`WallClock` for real deployments, and :class:`VirtualClock`
for deterministic tests — a fake executor charges virtual seconds per
segment and the scheduler's decisions (batch formation, interleaving,
fairness) become exactly reproducible assertions.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Dict, List, Optional, Sequence


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------

class WallClock:
    """Monotonic real time; ``sleep_until`` actually sleeps."""

    def now(self) -> float:
        return time.monotonic()

    def sleep_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


class VirtualClock:
    """Deterministic test clock: time moves only when told to."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def sleep_until(self, t: float) -> None:
        self._now = max(self._now, float(t))

    def advance(self, dt: float) -> float:
        """Charge ``dt`` virtual seconds (fake executors call this to model
        per-segment compute cost)."""
        self._now += float(dt)
        return self._now


def poisson_arrivals(rate: float, n: int, rng, start: float = 0.0,
                     deadline_budget=None) -> List:
    """``n`` arrival timestamps of a Poisson process with ``rate`` req/s
    (i.i.d. exponential gaps) — the synthetic open-loop arrival trace the
    serving example and benchmark share.  ``rng`` is a seeded
    ``np.random.RandomState``/``Generator`` so traces are reproducible.

    With ``deadline_budget`` (a fixed relative budget in seconds, or a
    ``(lo, hi)`` uniform draw — the per-class deadline model of the SLO
    traces) each element becomes an ``(arrival, deadline)`` pair with the
    absolute deadline ``arrival + budget``; without it the return stays a
    plain arrival list, so existing callers are untouched."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    t = float(start)
    out = []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        if deadline_budget is None:
            out.append(t)
        else:
            b = deadline_budget
            if isinstance(b, (tuple, list)):
                b = float(rng.uniform(b[0], b[1]))
            out.append((t, t + float(b)))
    return out


# ---------------------------------------------------------------------------
# Requests
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One generation job.

    ``seed`` feeds the micro-batch PRNG key (see
    :func:`repro.serve.engine.batch_key`); ``policy`` names the store entry
    (artifact / calibration-free policy) that serves it; ``priority`` breaks
    ties ahead of arrival order (higher first).  ``arrival`` is stamped by
    the queue at submit time unless given explicitly (virtual-clock tests
    and replayed traces pass it).  ``slo`` optionally attaches a
    :class:`repro.slo.SLO` (deadline / quality floor / class label) —
    requests without one serve exactly as before."""
    rid: int
    seed: int
    policy: str
    label: Optional[int] = None
    priority: int = 0
    slo: Optional[object] = None              # repro.slo.SLO, if any
    arrival: Optional[float] = None
    started: Optional[float] = None           # micro-batch launch time
    finished: Optional[float] = None          # result materialized
    joined_at: Optional[float] = None         # boundary join, if any

    @property
    def queue_wait(self) -> Optional[float]:
        if self.started is None or self.arrival is None:
            return None
        return self.started - self.arrival

    @property
    def service_time(self) -> Optional[float]:
        if self.finished is None or self.started is None:
            return None
        return self.finished - self.started

    @property
    def joined(self) -> bool:
        """Whether this request entered service via a boundary join
        (chaser launch) rather than a fresh batch formation — the
        metrics layer keys its joiner-specific wait distribution on
        this."""
        return self.joined_at is not None

    @property
    def deadline(self) -> Optional[float]:
        return self.slo.deadline if self.slo is not None else None

    @property
    def max_tau(self) -> Optional[float]:
        """Quality floor: the largest SmoothCache τ this request accepts
        (None ⇒ any registered rung)."""
        return self.slo.max_tau if self.slo is not None else None

    def attained(self) -> bool:
        """Deadline attainment: a finished request without a deadline
        always attains; an unfinished (shed / in-flight) one never does."""
        if self.finished is None:
            return False
        return self.deadline is None or self.finished <= self.deadline


class RequestQueue:
    """Arrival-ordered request queue with per-policy grouping.

    Requests become *ready* once the clock passes their arrival timestamp;
    ready requests are handed out per policy group in ``(-priority,
    arrival, rid)`` order.  The queue never forms batches itself — that is
    :class:`~repro.serve.batcher.MicroBatcher`'s job."""

    def __init__(self, clock=None):
        self.clock = clock if clock is not None else WallClock()
        self._future: List = []               # heap of (arrival, tie, req)
        self._ready: Dict[str, List[Request]] = {}
        self._tie = itertools.count()

    def submit(self, req: Request) -> Request:
        if req.arrival is None:
            req.arrival = self.clock.now()
        heapq.heappush(self._future, (req.arrival, next(self._tie), req))
        return req

    def submit_many(self, reqs: Sequence[Request]) -> List[Request]:
        return [self.submit(r) for r in reqs]

    def _absorb(self, now: float) -> None:
        while self._future and self._future[0][0] <= now:
            _, _, req = heapq.heappop(self._future)
            group = self._ready.setdefault(req.policy, [])
            group.append(req)
            group.sort(key=lambda r: (-r.priority, r.arrival, r.rid))

    def ready_groups(self, now: Optional[float] = None) -> Dict[str, int]:
        """{policy name: number of ready requests} at time ``now``."""
        self._absorb(self.clock.now() if now is None else now)
        return {g: len(rs) for g, rs in self._ready.items() if rs}

    def peek(self, group: str, now: Optional[float] = None) -> List[Request]:
        self._absorb(self.clock.now() if now is None else now)
        return list(self._ready.get(group, ()))

    def take(self, group: str, n: int,
             now: Optional[float] = None) -> List[Request]:
        """Remove and return the ``n`` highest-priority/oldest ready
        requests of ``group``."""
        self._absorb(self.clock.now() if now is None else now)
        rs = self._ready.get(group, [])
        taken, self._ready[group] = rs[:n], rs[n:]
        return taken

    def take_rids(self, group: str, rids: Sequence[int],
                  now: Optional[float] = None) -> List[Request]:
        """Remove and return specific ready requests of ``group`` by rid,
        preserving ready order — how the batcher lifts a rung-compatible
        subset, and how the engine sheds/defer-removes one request
        without disturbing its neighbors.  Unknown rids are ignored."""
        self._absorb(self.clock.now() if now is None else now)
        want = set(rids)
        rs = self._ready.get(group, [])
        taken = [r for r in rs if r.rid in want]
        self._ready[group] = [r for r in rs if r.rid not in want]
        return taken

    def resubmit(self, req: Request, not_before: float) -> None:
        """Defer: re-enqueue an already-removed request so it becomes
        ready again at ``not_before``.  The original ``arrival`` stamp is
        deliberately untouched — queue-wait accounting keeps charging the
        full time since first arrival, so deferral cannot launder latency."""
        heapq.heappush(self._future,
                       (float(not_before), next(self._tie), req))

    def drain_all(self) -> List[Request]:
        """Remove and return every queued request (ready and future) —
        the engine's stall-shed path: when nothing queued can ever become
        schedulable, each drained request gets an explicit shed outcome
        instead of an engine-killing exception."""
        out = [req for _, _, req in sorted(self._future)]
        self._future = []
        for rs in self._ready.values():
            out.extend(rs)
        self._ready = {}
        return out

    def next_arrival(self, now: Optional[float] = None) -> Optional[float]:
        """Earliest not-yet-ready arrival timestamp (None when everything
        submitted has already arrived)."""
        self._absorb(self.clock.now() if now is None else now)
        return self._future[0][0] if self._future else None

    def __len__(self) -> int:
        return len(self._future) + sum(len(rs) for rs in
                                       self._ready.values())
