"""repro.resilience — fault taxonomy, recovery policy, artifact
integrity, and deterministic fault injection for the serving stack.

Layering: this package sits *below* ``repro.serve`` — nothing here
imports the engine, store, batcher, or slo layer.  The serving stack
imports from here (``serve.store`` uses :mod:`~repro.resilience.integrity`,
``serve.engine`` consumes :class:`BatchFault` and
:class:`ResiliencePolicy`); the chaos harness wraps executors and clocks
from the outside.
"""
from repro.resilience.faults import (ARTIFACT, INJECTED, KINDS, NAN_LATENT,
                                     STUCK_BATCH, BatchFault)
from repro.resilience.recovery import ResiliencePolicy, RetryPolicy
from repro.resilience.integrity import (HealthRegistry, payload_checksum,
                                        verify_payload)
from repro.resilience.chaos import (ChaosClock, ChaosExecutor, ChaosRun,
                                    FaultPlan, FaultSpec, corrupt_artifact)

__all__ = [
    "ARTIFACT", "INJECTED", "KINDS", "NAN_LATENT", "STUCK_BATCH",
    "BatchFault", "ResiliencePolicy", "RetryPolicy", "HealthRegistry",
    "payload_checksum", "verify_payload", "ChaosClock", "ChaosExecutor",
    "ChaosRun", "FaultPlan", "FaultSpec", "corrupt_artifact",
]
