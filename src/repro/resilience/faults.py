"""Typed fault taxonomy for the serving stack.

A :class:`BatchFault` is the one currency every fault source converts
into: the executor's numerical-health sentinels (NaN/Inf latents, runaway
accumulators — detected at chunk/segment boundaries, never per step), the
engine watchdog (an advance that blew its
:class:`~repro.slo.admission.ServiceCostModel` deadline), and the chaos
harness (:mod:`repro.resilience.chaos` raises them deliberately).  The
engine's recovery path consumes *only* this type — programming errors
still propagate, faults never do.

Fault kinds (the taxonomy the metrics/bench report against):

========== =====================================================
kind        meaning
========== =====================================================
nan_latent  a sample's latent (or the decision accumulator) went
            NaN/Inf — per-sample ``sample_flags`` isolate the rows
stuck_batch an advance exceeded its watchdog deadline — the whole
            run is considered dead, no per-sample isolation
injected    a fault raised by the chaos harness (or any executor
            wrapper) as an exception mid-advance
artifact    a corrupt / checksum-mismatched artifact (surfaced by
            the store's integrity layer, recorded in its registry)
========== =====================================================
"""
from __future__ import annotations

from typing import Optional, Tuple

#: canonical fault kinds (free-form kinds are allowed; these are the ones
#: the built-in sources emit and the benchmark taxonomy reports)
NAN_LATENT = "nan_latent"
STUCK_BATCH = "stuck_batch"
INJECTED = "injected"
ARTIFACT = "artifact"

KINDS = (NAN_LATENT, STUCK_BATCH, INJECTED, ARTIFACT)


class BatchFault(Exception):
    """A fault scoped to one in-flight micro-batch.

    ``sample_flags`` — per-row health (True = row is fine), aligned with
    the micro-batch's request order — isolates poisoned samples without
    bisection: flagged-healthy rows are *survivors* (their results are
    deliverable or they re-queue at their original arrival), flagged rows
    go down the degradation ladder.  ``None`` means the fault has no
    per-sample resolution (e.g. a stuck batch): every member survives the
    abort and re-queues.
    """

    def __init__(self, kind: str,
                 sample_flags: Optional[Tuple[bool, ...]] = None,
                 detail: str = ""):
        self.kind = str(kind)
        self.sample_flags = (tuple(bool(b) for b in sample_flags)
                             if sample_flags is not None else None)
        self.detail = detail
        msg = f"BatchFault({self.kind}"
        if self.sample_flags is not None:
            bad = [i for i, ok in enumerate(self.sample_flags) if not ok]
            msg += f", poisoned_rows={bad}"
        if detail:
            msg += f", {detail}"
        super().__init__(msg + ")")

    @property
    def poisoned_rows(self) -> Tuple[int, ...]:
        """Row indices flagged unhealthy (empty when the fault carries no
        per-sample resolution)."""
        if self.sample_flags is None:
            return ()
        return tuple(i for i, ok in enumerate(self.sample_flags) if not ok)
