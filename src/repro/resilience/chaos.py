"""Deterministic fault injection for the serving stack.

A :class:`FaultPlan` is a *seeded schedule* of faults keyed by batch
serial number (the order in which the engine launches runs): given the
same seed and the same trace, the same batches fault in the same way on
the same advance — chaos tests are exact, replayable assertions, not
flaky coin flips.  :class:`ChaosExecutor` wraps any executor (real or the
test fakes) and applies the plan at advance boundaries:

* ``nan_latent`` — poison one row's latent (a real ``jnp`` latent gets an
  actual NaN written into it so the executor's health sentinels must
  catch it; fake run states without latents get the row marked on the
  wrapper's health flags directly),
* ``stuck_batch`` — stall the clock past the engine watchdog's deadline,
* ``injected``  — raise a :class:`~repro.resilience.faults.BatchFault`
  mid-advance (models an executor-level crash the engine must absorb).

:class:`ChaosClock` independently slows a seeded fraction of virtual
advances (degraded-device weather), and :func:`corrupt_artifact` bit-rots
an artifact file on disk without updating its checksum — the store's
integrity layer must refuse it.

Nothing here imports the engine or the store: the harness is a pure
wrapper layer the benchmarks and tests compose from the outside.
"""
from __future__ import annotations

import dataclasses
import json
import random
import time
from typing import Dict, Optional

import numpy as np

from repro.resilience import faults
from repro.resilience.faults import BatchFault


# ---------------------------------------------------------------------------
# Fault schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault: strike ``kind`` on the ``chunk``-th advance
    (1-based) of a run.  ``row`` picks the poisoned sample for
    ``nan_latent`` (None ⇒ row 0); ``stall_s`` is the injected stall for
    ``stuck_batch``."""
    kind: str
    row: Optional[int] = None
    chunk: int = 1
    stall_s: float = 0.0

    def __post_init__(self):
        if self.chunk < 1:
            raise ValueError(f"chunk counts from 1, got {self.chunk}")


@dataclasses.dataclass
class FaultPlan:
    """Seeded per-batch fault schedule.

    ``for_batch(serial, bucket)`` draws (memoized — repeated calls agree)
    from ``random.Random((seed, serial))``: with probability ``nan_rate``
    a NaN-latent fault on a uniform row, then ``stuck_rate`` a stalled
    advance of ``stall_s``, then ``error_rate`` an injected exception;
    otherwise the batch runs clean.  Explicit ``faults[serial]`` entries
    override the draw — how a test targets exactly the first batch.
    Retries launch new runs with new serials, so a faulted request's
    re-run is (with high probability) clean — the recovery path, not the
    fault, is what gets exercised repeatedly."""
    seed: int = 0
    nan_rate: float = 0.0
    stuck_rate: float = 0.0
    error_rate: float = 0.0
    stall_s: float = 5.0
    max_chunk: int = 2                # faults strike on advance 1..max_chunk
    faults: Dict[int, FaultSpec] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for name in ("nan_rate", "stuck_rate", "error_rate"):
            v = getattr(self, name)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.nan_rate + self.stuck_rate + self.error_rate > 1.0 + 1e-12:
            raise ValueError("fault rates must sum to <= 1")
        if self.max_chunk < 1:
            raise ValueError(f"max_chunk must be >= 1, got {self.max_chunk}")
        self._memo: Dict[tuple, Optional[FaultSpec]] = {}

    @property
    def fault_rate(self) -> float:
        return self.nan_rate + self.stuck_rate + self.error_rate

    def for_batch(self, serial: int, bucket: int) -> Optional[FaultSpec]:
        key = (int(serial), int(bucket))
        if key in self._memo:
            return self._memo[key]
        spec = self.faults.get(int(serial))
        if spec is None and self.fault_rate > 0:
            # str seeds hash via sha512 — stable across processes and
            # Python versions (tuple seeding is deprecated + randomized)
            rng = random.Random(f"{self.seed}:{int(serial)}")
            u = rng.random()
            chunk = 1 + rng.randrange(self.max_chunk)
            if u < self.nan_rate:
                spec = FaultSpec(faults.NAN_LATENT,
                                 row=rng.randrange(max(1, bucket)),
                                 chunk=chunk)
            elif u < self.nan_rate + self.stuck_rate:
                spec = FaultSpec(faults.STUCK_BATCH, chunk=chunk,
                                 stall_s=self.stall_s)
            elif u < self.fault_rate:
                spec = FaultSpec(faults.INJECTED, chunk=chunk)
        self._memo[key] = spec
        return spec


class ChaosClock:
    """Clock wrapper that deterministically slows a seeded fraction of
    ``advance`` calls by ``slow_s`` — degraded-device weather for
    virtual-clock benches.  ``now``/``sleep_until`` pass through."""

    def __init__(self, inner, seed: int = 0, slow_rate: float = 0.0,
                 slow_s: float = 0.0):
        if not (0.0 <= slow_rate <= 1.0):
            raise ValueError(f"slow_rate must be in [0, 1], got {slow_rate}")
        self._inner = inner
        self.seed = seed
        self.slow_rate = slow_rate
        self.slow_s = slow_s
        self.slowed = 0                       # advances that got the tax
        self._n = 0

    def now(self) -> float:
        return self._inner.now()

    def sleep_until(self, t: float) -> None:
        self._inner.sleep_until(t)

    def advance(self, dt: float) -> float:
        self._n += 1
        if (self.slow_rate
                and random.Random(f"{self.seed}:{self._n}").random()
                < self.slow_rate):
            dt = float(dt) + self.slow_s
            self.slowed += 1
        return self._inner.advance(dt)


# ---------------------------------------------------------------------------
# Executor wrapper
# ---------------------------------------------------------------------------

class ChaosRun:
    """Run-state proxy: delegates everything to the wrapped state, tracks
    the advance count against the batch's :class:`FaultSpec`, and merges
    chaos-marked poisoned rows into the ``healthy`` flags the engine
    reads."""

    def __init__(self, inner, spec: Optional[FaultSpec], batch: int,
                 serial: int):
        self._inner = inner
        self._spec = spec
        self._batch = int(batch)
        self._serial = int(serial)
        self._advances = 0
        self._struck = False
        self._poisoned = set()

    def __getattr__(self, name):
        return getattr(self._inner, name)

    @property
    def healthy(self):
        inner = getattr(self._inner, "healthy", None)
        if not self._poisoned:
            return inner
        flags = (np.ones(self._batch, bool) if inner is None
                 else np.asarray(inner).astype(bool).copy())
        for r in self._poisoned:
            if 0 <= r < flags.shape[0]:
                flags[r] = False
        return flags


class ChaosExecutor:
    """Executor wrapper applying a :class:`FaultPlan` at advance
    boundaries.

    ``mutate_latent`` (default True) writes a real NaN into the run's
    latent when one exists — the wrapped executor's sentinels must then
    detect it (set ``mark_flags=False`` to test *only* that detection
    path).  ``mark_flags`` (default True) additionally marks the row on
    the proxy's health flags, which is what makes NaN faults visible on
    test fakes that carry no latents mid-run.  Everything not overridden
    here (``sample``, compile counters, ``supports_fused_adaptive``,
    ``host_sync_count`` …) delegates to the wrapped executor untouched.
    """

    def __init__(self, inner, plan: FaultPlan, clock=None, *,
                 mutate_latent: bool = True, mark_flags: bool = True):
        self._inner = inner
        self.plan = plan
        self.clock = clock
        self.mutate_latent = mutate_latent
        self.mark_flags = mark_flags
        self.serial = 0                       # runs launched so far
        self.injected: Dict[str, int] = {}    # kind → count actually struck

    def __getattr__(self, name):
        return getattr(self._inner, name)

    # -- run lifecycle -------------------------------------------------------

    def _wrap(self, rs, batch: int) -> ChaosRun:
        serial = self.serial
        self.serial += 1
        return ChaosRun(rs, self.plan.for_batch(serial, batch), batch,
                        serial)

    def start_run(self, params, key, batch, **kw):
        return self._wrap(self._inner.start_run(params, key, batch, **kw),
                          batch)

    def start_adaptive_run(self, params, key, batch, **kw):
        return self._wrap(
            self._inner.start_adaptive_run(params, key, batch, **kw), batch)

    def start_adaptive_fused_run(self, params, key, batch, **kw):
        return self._wrap(
            self._inner.start_adaptive_fused_run(params, key, batch, **kw),
            batch)

    def advance_run(self, params, rs: ChaosRun, **kw):
        rs._inner = self._inner.advance_run(params, rs._inner, **kw)
        rs._advances += 1
        self._strike(rs)
        return rs

    def advance_adaptive_run(self, params, rs: ChaosRun, **kw):
        rs._inner = self._inner.advance_adaptive_run(params, rs._inner,
                                                     **kw)
        rs._advances += 1
        self._strike(rs)
        return rs

    def advance_adaptive_fused(self, params, rs: ChaosRun, **kw):
        rs._inner = self._inner.advance_adaptive_fused(params, rs._inner,
                                                       **kw)
        rs._advances += 1
        self._strike(rs)
        return rs

    # -- split / merge (continuous batching) ---------------------------------

    def split_run(self, rs, groups):
        """Forward a run-state split through the proxy: the wrapped
        states are split for real, and each sub-run keeps the poisoned
        rows that landed in its group (remapped to sub-run indices).
        Sub-runs carry no pending :class:`FaultSpec` — an unstruck fault
        dies with the split; chaos plans key on launch serials, and a
        split is not a launch."""
        if not isinstance(rs, ChaosRun):
            return self._inner.split_run(rs, groups)
        subs = self._inner.split_run(rs._inner, groups)
        out = []
        for g, sub in zip(groups, subs):
            cr = ChaosRun(sub, None, len(g), rs._serial)
            cr._advances = rs._advances
            cr._struck = rs._struck
            cr._poisoned = {i for i, j in enumerate(g)
                            if j in rs._poisoned}
            out.append(cr)
        return out

    def merge_runs(self, runs):
        """Merge through the proxy; poisoned-row marks concatenate with
        the rows."""
        if not any(isinstance(r, ChaosRun) for r in runs):
            return self._inner.merge_runs(runs)
        inners = [r._inner if isinstance(r, ChaosRun) else r
                  for r in runs]
        merged = self._inner.merge_runs(inners)
        batches = [(r._batch if isinstance(r, ChaosRun)
                    else int(np.asarray(r.x).shape[0])) for r in runs]
        cr = ChaosRun(merged, None, sum(batches),
                      next(r._serial for r in runs
                           if isinstance(r, ChaosRun)))
        cr._advances = max(r._advances for r in runs
                           if isinstance(r, ChaosRun))
        cr._struck = True                     # never re-strike a merge
        off = 0
        pois = set()
        for r, b in zip(runs, batches):
            if isinstance(r, ChaosRun):
                pois |= {off + i for i in r._poisoned}
            off += b
        cr._poisoned = pois
        return cr

    # -- snapshot seams (durable serving) ------------------------------------

    def export_run(self, rs):
        """Unwrap the proxy and export the real run state.  Chaos
        bookkeeping (pending :class:`FaultSpec`, poisoned-row marks) is
        deliberately NOT serialized — a restart is a fresh process and
        the plan keys on launch serials, which a restore is not."""
        inner = rs._inner if isinstance(rs, ChaosRun) else rs
        return self._inner.export_run(inner)

    def import_run(self, params, kind, arrays, static, **kw):
        """Import through the wrapped executor, then re-wrap so the
        engine keeps seeing the proxy type it launched with.  The
        restored run carries no pending fault (same rationale as
        :meth:`split_run`)."""
        rs = self._inner.import_run(params, kind, arrays, static, **kw)
        return ChaosRun(rs, None, int(static["batch"]), -1)

    # -- fault application ---------------------------------------------------

    def _strike(self, rs: ChaosRun) -> None:
        spec = rs._spec
        if spec is None or rs._struck or rs._advances < spec.chunk:
            return
        rs._struck = True
        self.injected[spec.kind] = self.injected.get(spec.kind, 0) + 1
        if spec.kind == faults.INJECTED:
            raise BatchFault(faults.INJECTED,
                             detail=f"chaos plan, run serial {rs._serial}")
        if spec.kind == faults.STUCK_BATCH:
            adv = getattr(self.clock, "advance", None)
            if adv is not None:
                adv(spec.stall_s)
            else:                              # wall clock: really stall
                time.sleep(spec.stall_s)
            return
        if spec.kind == faults.NAN_LATENT:
            row = 0 if spec.row is None else int(spec.row) % rs._batch
            x = getattr(rs._inner, "x", None)
            if (self.mutate_latent and x is not None
                    and hasattr(x, "at")
                    and dataclasses.is_dataclass(rs._inner)):
                rs._inner = dataclasses.replace(
                    rs._inner, x=x.at[row].set(float("nan")))
            if self.mark_flags:
                rs._poisoned.add(row)
            return
        raise ValueError(f"unknown fault kind in plan: {spec.kind!r}")


# ---------------------------------------------------------------------------
# On-disk corruption
# ---------------------------------------------------------------------------

def corrupt_artifact(path, seed: int = 0):
    """Bit-rot an artifact file in place: perturb one numeric leaf of the
    JSON payload (seeded choice) *without* touching the stored checksum —
    exactly the corruption :func:`repro.resilience.integrity.verify_payload`
    exists to catch.  Returns ``path``."""
    with open(path) as f:
        obj = json.load(f)
    leaves = []

    def collect(container):
        items = (container.items() if isinstance(container, dict)
                 else enumerate(container) if isinstance(container, list)
                 else ())
        for k, v in items:
            if isinstance(v, bool):
                continue
            if isinstance(v, (int, float)):
                if k != "format_version":
                    leaves.append((container, k))
            elif isinstance(v, (dict, list)):
                collect(v)

    collect(obj)
    rng = random.Random(seed)
    if leaves:
        c, k = leaves[rng.randrange(len(leaves))]
        c[k] = float(c[k]) * 3.0 + 1.25
    else:
        obj["__chaos__"] = int(seed)
    with open(path, "w") as f:
        json.dump(obj, f)
    return path
