"""Degrade-don't-die recovery policy: bounded retries + the fault ladder.

The serving engine owns the mechanics (re-queueing survivors, resubmitting
faulted requests, terminal sheds); this module owns the *policy* knobs —
how many retries a faulted request gets, how long to back off between
attempts, and whether a retry also steps the request down the degradation
ladder (current rung → τ=0 → ``no_cache``, materialized by
:meth:`repro.serve.store.ArtifactStore.degraded_entry_name`).

Everything here is deterministic: backoff jitter is a pure function of
``(seed, rid, attempt)``, never of wall time or a global RNG, so a
virtual-clock replay of a faulty trace reproduces the exact same retry
schedule — the property the chaos tests assert.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter.

    ``delay(attempt, rid)`` (attempt counts from 1) returns
    ``base × factor^(attempt-1)`` scaled by a jitter factor drawn
    uniformly from ``[1-jitter, 1+jitter]`` — seeded per (rid, attempt),
    so the schedule is reproducible on both :class:`VirtualClock` and
    :class:`WallClock` runs."""
    max_retries: int = 2
    backoff_base: float = 0.05            # seconds before the first retry
    backoff_factor: float = 2.0
    jitter: float = 0.1                   # ± fraction of the delay
    seed: int = 0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{self.max_retries}")
        if self.backoff_base < 0 or self.backoff_factor < 1:
            raise ValueError("backoff needs base >= 0 and factor >= 1")
        if not (0 <= self.jitter < 1):
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")

    def delay(self, attempt: int, rid: int = 0) -> float:
        if attempt < 1:
            raise ValueError(f"attempt counts from 1, got {attempt}")
        d = self.backoff_base * self.backoff_factor ** (attempt - 1)
        if self.jitter:
            # str seed: stable sha512 path (tuple seeding is deprecated)
            u = random.Random(
                f"{self.seed}:{int(rid)}:{int(attempt)}").random()
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return d


@dataclasses.dataclass
class ResiliencePolicy:
    """Engine-wide fault handling configuration.

    Passing one to :class:`~repro.serve.engine.ServeEngine` turns the
    fault path on: health flags are read at every advance boundary,
    ``BatchFault`` s raised mid-advance are recovered instead of
    propagated, the stall guard sheds (reason ``stalled``) instead of
    raising, and — when ``watchdog_factor`` is set — an advance whose
    wall/virtual duration exceeds ``estimate × factor + floor`` is
    treated as a ``stuck_batch`` fault: the run is abandoned and every
    member re-queued at its original arrival.  The estimate comes from
    the engine's :class:`~repro.slo.admission.ServiceCostModel` keyed on
    the batch's ``(rung, bucket)``, the same key admission prices with —
    a ladder move or a regrouped bucket size gets its own deadline, not
    another shape's.  ``None`` (the engine default) keeps the exact
    pre-resilience behavior: zero health reads, zero overhead."""
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    #: advance deadline = cost_model.estimate(steps, rung, bucket) ×
    #: factor + floor; None disables the watchdog (health sentinels stay
    #: active)
    watchdog_factor: Optional[float] = None
    watchdog_floor_s: float = 1.0
    #: when a per-row fault hits a divisible run (the executor exposes
    #: ``split_run`` and the solver is deterministic), split the faulted
    #: rows out and let survivors *continue* with their run-state intact
    #: instead of abandoning the whole batch; faulted rows still follow
    #: the retry/degradation ladder.  False restores restart-everyone.
    split_retry: bool = True
    #: step faulted requests down the store's degradation ladder
    #: (current rung → τ=0 → no_cache) on each retry; False retries on
    #: the original entry
    degrade: bool = True
    #: consecutive engine-observed faults after which an entry is marked
    #: unhealthy in the store's registry (unresolvable at formation);
    #: None never trips
    entry_fault_threshold: Optional[int] = None

    def deadline(self, est_s: float) -> float:
        """Watchdog deadline for an advance with estimated service time
        ``est_s`` — the ``est × factor + floor`` formula lives here (the
        policy layer) so the engine only supplies the estimate.  Raises
        when the watchdog is disabled (``watchdog_factor=None``); callers
        gate on that, as the engine does."""
        if self.watchdog_factor is None:
            raise ValueError("watchdog disabled (watchdog_factor=None)")
        return float(est_s) * self.watchdog_factor + self.watchdog_floor_s

    def __post_init__(self):
        if self.watchdog_factor is not None and self.watchdog_factor <= 0:
            raise ValueError("watchdog_factor must be > 0 or None")
        if self.watchdog_floor_s < 0:
            raise ValueError("watchdog_floor_s must be >= 0")
        if (self.entry_fault_threshold is not None
                and self.entry_fault_threshold < 1):
            raise ValueError("entry_fault_threshold must be >= 1 or None")
