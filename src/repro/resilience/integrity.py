"""Artifact integrity + the store-level health registry.

Two small, dependency-free pieces the serving store builds on:

* **Content checksums** — :func:`payload_checksum` hashes the canonical
  JSON form of an artifact payload (sorted keys, checksum field
  excluded).  :meth:`CacheArtifact.to_json` embeds it and
  :meth:`CacheArtifact.from_json` verifies it, so every consumer of the
  serialization seam — ``ArtifactStore.add_artifact``, ``reload``,
  ``DiffusionPipeline.load_artifact`` — detects on-disk corruption with
  a precise error instead of serving a silently mangled schedule.
  Artifacts written before the checksum era (no ``checksum`` key) load
  unchanged.

* **HealthRegistry** — the store's fault ledger.  ``quarantine`` records
  a *failed hot-reload* (the bad file's reason; the old entry keeps
  serving, so quarantine never makes an entry unservable).
  ``report_fault`` counts engine-observed serving faults per entry and —
  past an optional threshold — marks the entry **unhealthy**:
  ``ArtifactStore.resolve_entry_for`` then returns ``None`` for it, so
  the batcher never forms another batch on it and the engine sheds its
  traffic with reason ``unhealthy_entry`` until ``mark_healthy`` clears
  it (e.g. after a successful reload).
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, Optional

#: payload key holding the content checksum (excluded from the hash)
CHECKSUM_KEY = "checksum"


def payload_checksum(payload: Dict) -> str:
    """sha256 over the canonical JSON form of ``payload`` with the
    ``checksum`` field excluded — stable across round-trips because both
    writer and verifier serialize with sorted keys."""
    d = {k: v for k, v in payload.items() if k != CHECKSUM_KEY}
    canon = json.dumps(d, sort_keys=True)
    return "sha256:" + hashlib.sha256(canon.encode("utf-8")).hexdigest()


def verify_payload(payload: Dict) -> None:
    """Raise ``ValueError`` when ``payload`` carries a checksum that does
    not match its content.  Payloads without one pass (pre-checksum
    artifacts load unchanged)."""
    stored = payload.get(CHECKSUM_KEY)
    if stored is None:
        return
    computed = payload_checksum(payload)
    if stored != computed:
        raise ValueError(
            f"artifact checksum mismatch: file says {stored!r}, content "
            f"hashes to {computed!r} — the artifact was corrupted on disk "
            "or in transit; re-export it from calibration")


class HealthRegistry:
    """Per-entry serving-health ledger (owned by the ArtifactStore)."""

    def __init__(self, fault_threshold: Optional[int] = None):
        self.fault_threshold = fault_threshold
        self._faults: Dict[str, int] = {}
        self._unhealthy: Dict[str, str] = {}      # name → reason
        self._quarantined: Dict[str, str] = {}    # name → reload failure

    # -- serving health ------------------------------------------------------

    def report_fault(self, name: str, kind: str = "fault") -> bool:
        """Count one engine-observed fault against ``name``; returns True
        when this report crossed the threshold and marked the entry
        unhealthy."""
        n = self._faults.get(name, 0) + 1
        self._faults[name] = n
        if (self.fault_threshold is not None
                and n >= self.fault_threshold
                and name not in self._unhealthy):
            self.mark_unhealthy(
                name, f"{n} serving faults (last: {kind}) reached the "
                f"threshold of {self.fault_threshold}")
            return True
        return False

    def mark_unhealthy(self, name: str, reason: str) -> None:
        self._unhealthy[name] = reason

    def mark_healthy(self, name: str) -> None:
        """Clear unhealthy status and the fault count (a fresh start —
        e.g. after a successful hot-reload)."""
        self._unhealthy.pop(name, None)
        self._faults.pop(name, None)

    def is_servable(self, name: str) -> bool:
        return name not in self._unhealthy

    def fault_count(self, name: str) -> int:
        return self._faults.get(name, 0)

    # -- reload quarantine ---------------------------------------------------

    def quarantine(self, name: str, reason: str) -> None:
        """Record a failed hot-reload of ``name`` (the replacement file
        was rejected; the old entry keeps serving — this is a ledger
        entry, not a serving state)."""
        self._quarantined[name] = reason

    def quarantine_reason(self, name: str) -> Optional[str]:
        return self._quarantined.get(name)

    def clear_quarantine(self, name: str) -> None:
        self._quarantined.pop(name, None)

    # -- reporting -----------------------------------------------------------

    def status(self, name: str) -> Dict:
        """One entry's ledger: servability, fault count, unhealthy /
        quarantine reasons (JSON-safe)."""
        return {
            "servable": self.is_servable(name),
            "faults": self.fault_count(name),
            "unhealthy_reason": self._unhealthy.get(name),
            "quarantined_reason": self._quarantined.get(name),
        }

    def report(self) -> Dict:
        return {
            "fault_counts": dict(sorted(self._faults.items())),
            "unhealthy": dict(sorted(self._unhealthy.items())),
            "quarantined": dict(sorted(self._quarantined.items())),
        }
