"""Synthetic data pipelines (no external datasets are available offline).

* token streams for the AR language backbones (zipf-ish unigram mix with
  planted n-gram structure so training loss actually decreases),
* structured image latents for diffusion training (Gaussian-blob scenes
  with class-dependent layout — class-conditional like DiT/ImageNet),
* text-conditioning memory stubs (the T5/CLAP/ViT carve-out of DESIGN.md §6),
* EnCodec-style codebook token grids for musicgen,
* ViT patch embeddings for the VLM prefix.

Deterministic per (seed, step): the pipeline is a pure function, so the
input pipeline is reproducible and shardable across data-parallel hosts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Token streams
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    seq_len: int
    batch: int
    num_codebooks: int = 1
    seed: int = 0

    def batch_at(self, step: int):
        """(tokens, targets) for LM training; planted bigram structure."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        shape = (self.batch, self.seq_len + 1)
        if self.num_codebooks > 1:
            shape = shape + (self.num_codebooks,)
        v = self.vocab_size
        base = jax.random.randint(k1, shape, 0, v)
        # plant structure: with p=0.5 the next token is (prev * 31 + 7) % v
        copy = (jnp.roll(base, 1, axis=1) * 31 + 7) % v
        mask = jax.random.bernoulli(k2, 0.5, shape)
        toks = jnp.where(mask, copy, base)
        return toks[:, :-1], toks[:, 1:]


# ---------------------------------------------------------------------------
# Diffusion latents: class-conditional Gaussian-blob scenes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlobLatents:
    """Class c places a blob at a class-dependent position with a
    class-dependent channel signature — learnable by a small DiT."""
    latent_shape: Tuple[int, ...]        # (H, W, C)
    num_classes: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        kl, kx, kn = jax.random.split(key, 3)
        h, w, c = self.latent_shape
        label = jax.random.randint(kl, (self.batch,), 0, self.num_classes)
        yy, xx = jnp.mgrid[0:h, 0:w]
        ang = 2 * jnp.pi * label.astype(jnp.float32) / max(self.num_classes, 1)
        cy = h / 2 + (h / 4) * jnp.sin(ang)
        cx = w / 2 + (w / 4) * jnp.cos(ang)
        d2 = ((yy[None] - cy[:, None, None]) ** 2
              + (xx[None] - cx[:, None, None]) ** 2)
        blob = jnp.exp(-d2 / (2.0 * (h / 8) ** 2))          # (B, H, W)
        sig = jnp.stack([jnp.cos(ang * (i + 1)) for i in range(c)], -1)
        x0 = blob[..., None] * sig[:, None, None, :]
        x0 = x0 + 0.05 * jax.random.normal(kx, x0.shape)
        return x0.astype(jnp.float32), label


@dataclasses.dataclass(frozen=True)
class CondLatents:
    """Text/audio/video-conditioned latents: memory stub + latent whose
    low-frequency content is a linear readout of the memory."""
    latent_shape: Tuple[int, ...]
    cond_dim: int
    cond_len: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        km, kp, kn = jax.random.split(key, 3)
        memory = jax.random.normal(km, (self.batch, self.cond_len, self.cond_dim))
        pooled = jnp.mean(memory, axis=1)                   # (B, D)
        n = int(np.prod(self.latent_shape))
        wkey = jax.random.PRNGKey(self.seed + 1)            # fixed readout
        w = jax.random.normal(wkey, (self.cond_dim, n)) / np.sqrt(self.cond_dim)
        x0 = (pooled @ w).reshape((self.batch,) + tuple(self.latent_shape))
        x0 = jnp.tanh(x0) + 0.05 * jax.random.normal(kn, x0.shape)
        return x0.astype(jnp.float32), memory


# ---------------------------------------------------------------------------
# Modality frontend stubs (DESIGN.md §6 carve-out)
# ---------------------------------------------------------------------------

def vit_patch_embeds(key, batch: int, num_patches: int, dim: int):
    """Precomputed ViT patch embeddings (InternViT / Llama-4 early fusion)."""
    return jax.random.normal(key, (batch, num_patches, dim)) * 0.02


def text_memory(key, batch: int, length: int, dim: int):
    """Precomputed T5-style text-encoder memory."""
    return jax.random.normal(key, (batch, length, dim)) * 0.02
