from repro.data.synthetic import (BlobLatents, CondLatents, TokenStream,  # noqa: F401
                                  text_memory, vit_patch_embeds)
