"""repro.durable — crash-safe serving: write-ahead request journal,
boundary run-state snapshots, and the kill–restart chaos harness.

Sits below ``repro.serve`` (like ``repro.resilience``): nothing here
imports the engine, the store, or the batcher.  ``ServeEngine`` wires
these pieces in when constructed with ``journal=``/``snapshot_dir=`` and
replays them in ``recover()``.
"""
from repro.durable.harness import (KillPlan, KillReport,  # noqa: F401
                                   crash, drain_with_kills)
from repro.durable.journal import (JournalState,  # noqa: F401
                                   RequestJournal, replay)
from repro.durable.snapshot import (FORMAT, SnapshotError,  # noqa: F401
                                    SnapshotStore, plan_hash)

__all__ = [
    "FORMAT",
    "JournalState",
    "KillPlan",
    "KillReport",
    "RequestJournal",
    "SnapshotError",
    "SnapshotStore",
    "crash",
    "drain_with_kills",
    "plan_hash",
    "replay",
]
