"""Write-ahead request journal — the engine's crash-surviving memory.

An append-only JSONL file of serving lifecycle events.  Each line is

    ``<sha256(body)[:12]> <canonical-json-body>\\n``

so a torn tail (the crash interrupted a write mid-line) is detected by
its checksum and skipped, never parsed into a half-event.  Writes are
*fsync-on-ack*: events that acknowledge something to a client (submit,
finish, shed) hit the disk before the engine proceeds, while high-rate
progress events (launch, boundary checkpoints, retries) are flushed to
the OS but not synced — losing one of those in a crash only costs a
little replay work, never a request.

Replay is a pure function of the file: :class:`JournalState` folds the
event stream into "what was submitted, what finished, what was shed,
what was mid-flight" — everything :meth:`ServeEngine.recover` needs to
re-admit pending requests at their original arrival and to answer
``outcome(rid)`` for requests that completed before the crash.

Pure stdlib on purpose: the journal must be writable/readable even when
the array stack (jax / msgpack) is broken — that is exactly when you
need it.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, List, Optional, Tuple

_CK_LEN = 12


def _line(body: Dict) -> bytes:
    js = json.dumps(body, sort_keys=True, separators=(",", ":"))
    ck = hashlib.sha256(js.encode("utf-8")).hexdigest()[:_CK_LEN]
    return f"{ck} {js}\n".encode("utf-8")


def _parse(raw: bytes) -> Optional[Dict]:
    """One journal line → event dict, or None when torn/corrupt."""
    try:
        text = raw.decode("utf-8")
        ck, js = text.rstrip("\n").split(" ", 1)
    except (UnicodeDecodeError, ValueError):
        return None
    if hashlib.sha256(js.encode("utf-8")).hexdigest()[:_CK_LEN] != ck:
        return None
    try:
        ev = json.loads(js)
    except json.JSONDecodeError:
        return None
    return ev if isinstance(ev, dict) and "ev" in ev else None


class RequestJournal:
    """Append-only, checksummed, fsync-on-ack event log.

    Reopening an existing file *seals* a torn tail first: if the last
    byte is not a newline, one is appended, so the interrupted line fails
    its checksum at replay instead of merging with the next append.
    """

    def __init__(self, path: str):
        self.path = str(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self.sealed_tail = False
        if os.path.exists(self.path) and os.path.getsize(self.path) > 0:
            with open(self.path, "rb") as f:
                f.seek(-1, os.SEEK_END)
                self.sealed_tail = f.read(1) != b"\n"
        self.appended = 0
        self.synced = 0
        self._f = open(self.path, "ab")
        if self.sealed_tail:
            self._f.write(b"\n")
            self._flush(sync=True)

    def append(self, ev: str, *, sync: bool = True, **fields) -> None:
        body = dict(fields, ev=str(ev))
        self._f.write(_line(body))
        self.appended += 1
        self._flush(sync)

    def append_many(self, records: List[Dict], *, sync: bool = True) -> None:
        """Batch-append pre-built ``{"ev": ..., ...}`` records with a
        single flush/fsync at the end — one disk sync covers a whole
        submit burst."""
        if not records:
            return
        for body in records:
            if "ev" not in body:
                raise ValueError("journal record needs an 'ev' field")
            self._f.write(_line(body))
            self.appended += 1
        self._flush(sync)

    def _flush(self, sync: bool) -> None:
        self._f.flush()
        if sync:
            os.fsync(self._f.fileno())
            self.synced += 1

    @property
    def closed(self) -> bool:
        return self._f.closed

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()


def replay(path: str) -> Tuple[List[Dict], int]:
    """Read a journal file → ``(events, skipped)``.  Undecodable or
    checksum-failing lines (torn tail, bit-rot) are skipped and counted,
    never raised — a journal read is a recovery path."""
    events: List[Dict] = []
    skipped = 0
    if not os.path.exists(path):
        return events, skipped
    with open(path, "rb") as f:
        for raw in f:
            if not raw.strip():
                continue
            ev = _parse(raw)
            if ev is None:
                skipped += 1
            else:
                events.append(ev)
    return events, skipped


@dataclasses.dataclass
class JournalState:
    """The event stream folded into recovery-relevant state.  Request
    ids keep whatever (JSON-safe) type the submitter used — they are
    record *values*, so ints stay ints across the round-trip."""

    submitted: Dict = dataclasses.field(default_factory=dict)
    done: Dict = dataclasses.field(default_factory=dict)
    shed: Dict = dataclasses.field(default_factory=dict)
    started: Dict = dataclasses.field(default_factory=dict)
    attempts: Dict = dataclasses.field(default_factory=dict)
    levels: Dict = dataclasses.field(default_factory=dict)
    checkpoints: Dict[int, Dict] = dataclasses.field(default_factory=dict)
    events: List[Dict] = dataclasses.field(default_factory=list)
    skipped: int = 0

    @classmethod
    def replay(cls, path: str) -> "JournalState":
        st = cls()
        st.events, st.skipped = replay(path)
        for ev in st.events:
            kind = ev.get("ev")
            if kind == "submit":
                st.submitted[ev["rid"]] = ev
            elif kind == "launch":
                for rid in ev.get("rids", ()):
                    st.started[rid] = float(ev.get("t", 0.0))
            elif kind == "checkpoint":
                st.checkpoints[int(ev["serial"])] = ev
            elif kind == "finish":
                for rid in ev.get("rids", ()):
                    st.done[rid] = float(ev.get("t", 0.0))
            elif kind == "shed":
                st.shed[ev["rid"]] = (str(ev.get("reason", "shed")),
                                      float(ev.get("t", 0.0)))
            elif kind == "retry":
                rid = ev["rid"]
                st.attempts[rid] = int(ev.get("attempt", 0))
                if rid in st.submitted and "policy" in ev:
                    st.submitted[rid] = dict(st.submitted[rid],
                                             policy=ev["policy"])
                if "level" in ev:
                    st.levels[rid] = int(ev["level"])
            # recover / restore / unknown events are informational
        return st

    def pending(self) -> Dict[str, Dict]:
        """Submit records with no terminal verdict — what a restarted
        engine must finish (from a snapshot or from the start)."""
        return {rid: rec for rid, rec in self.submitted.items()
                if rid not in self.done and rid not in self.shed}
