"""Run-state snapshot store — boundary checkpoints of in-flight batches.

A :class:`SnapshotStore` owns a directory of ``run-<seq>.ckpt`` files,
each one in-flight batch serialized through ``repro.checkpoint.io``
(msgpack header + raw numpy body, staged ``.tmp`` + ``os.replace`` so a
crash never publishes a half-written file).  ``seq`` is globally
monotone — scanned from the directory on open, so it keeps rising across
restarts and "newest snapshot" is a filename comparison.

The snapshot *meta* carries the full provenance stamp (entry
name/version, schedule fingerprint, plan hash, artifact checksum, step,
request ids/seeds, lineage) plus its own content checksum via
``repro.resilience.integrity.payload_checksum``; :meth:`load` verifies
format and checksum and raises :class:`SnapshotError` otherwise —
recovery treats any refusal as "quarantine this file and replay the
requests from the start", mirroring the store's artifact quarantine.

Snapshots are best-effort by design: they are **not** fsynced (a torn
snapshot is detected and quarantined, and the row-keys determinism
contract makes replay-from-start bit-identical), and at most one live
file exists per batch serial (a new boundary checkpoint replaces the
previous one; a finished/faulted/regrouped batch drops its file).

checkpoint.io is imported lazily so that engines running *without*
durability never require msgpack.
"""
from __future__ import annotations

import hashlib
import os
import re
from typing import Dict, Iterable, List, Tuple

from repro.resilience.integrity import CHECKSUM_KEY, payload_checksum

#: snapshot format tag — bumped when the meta schema changes shape
FORMAT = "repro.durable/1"

_NAME_RE = re.compile(r"^run-(\d+)\.ckpt$")


class SnapshotError(ValueError):
    """A snapshot file was refused (bad format tag, meta checksum
    mismatch, or the underlying checkpoint refused to load)."""


def plan_hash(plan) -> str:
    """Short content hash of an execution plan's canonical JSON — part of
    the provenance stamp that guards restore against entry drift."""
    js = plan.to_json()
    return "sha256:" + hashlib.sha256(js.encode("utf-8")).hexdigest()[:16]


class SnapshotStore:
    def __init__(self, dirpath: str):
        self.dir = str(dirpath)
        os.makedirs(self.dir, exist_ok=True)
        self._seq = 0
        for name in os.listdir(self.dir):
            m = _NAME_RE.match(name)
            if m:
                self._seq = max(self._seq, int(m.group(1)))
        self._files: Dict[int, str] = {}      # batch serial → live path

    # -- writing -------------------------------------------------------------

    def save(self, serial: int, arrays, meta: Dict) -> Tuple[str, int]:
        """Write a boundary checkpoint for batch ``serial``; returns
        ``(filename, nbytes)``.  The previous snapshot of the same serial
        (if any) is removed only after the new file is published, so a
        crash between the two leaves the older-but-valid file behind —
        recovery's newest-first scan with rid dedup handles both."""
        from repro.checkpoint import io as ckpt_io
        self._seq += 1
        name = f"run-{self._seq}.ckpt"
        path = os.path.join(self.dir, name)
        meta = dict(meta, seq=self._seq, format=FORMAT)
        meta[CHECKSUM_KEY] = payload_checksum(meta)
        ckpt_io.save(path, arrays, meta)
        old = self._files.get(int(serial))
        if old and old != path:
            self.discard(old)
        self._files[int(serial)] = path
        return name, os.path.getsize(path)

    def drop(self, serial: int) -> None:
        """The batch left flight (finished, faulted, merged away,
        regrouped, split for retry) — its snapshot is obsolete."""
        path = self._files.pop(int(serial), None)
        if path:
            self.discard(path)

    def adopt(self, serial: int, path: str) -> None:
        """Track a restored snapshot as ``serial``'s live file so the
        next boundary checkpoint (or finish) supersedes it."""
        self._files[int(serial)] = str(path)

    # -- reading -------------------------------------------------------------

    def scan(self) -> List[str]:
        """All snapshot paths on disk, newest sequence first."""
        found = []
        for name in os.listdir(self.dir):
            m = _NAME_RE.match(name)
            if m:
                found.append((int(m.group(1)), os.path.join(self.dir, name)))
        return [p for _, p in sorted(found, reverse=True)]

    def load(self, path: str):
        """Read + verify one snapshot → ``(arrays, meta)``.  Raises
        :class:`SnapshotError` for a wrong format tag or a meta whose
        checksum disagrees with its content; the underlying
        ``CheckpointError`` (torn body, bad magic …) propagates as
        itself."""
        from repro.checkpoint import io as ckpt_io
        arrays, meta = ckpt_io.restore(path)
        if meta.get("format") != FORMAT:
            raise SnapshotError(
                f"snapshot {os.path.basename(path)} has format "
                f"{meta.get('format')!r}, expected {FORMAT!r}")
        from repro.resilience.integrity import verify_payload
        try:
            verify_payload(meta)
        except ValueError as e:
            raise SnapshotError(
                f"snapshot {os.path.basename(path)} meta checksum "
                f"mismatch: {e}") from e
        return arrays, meta

    # -- disposal ------------------------------------------------------------

    def discard(self, path: str) -> None:
        """Remove a superseded/stale snapshot (quietly — a racing unlink
        is fine, the file is garbage either way)."""
        try:
            os.unlink(path)
        except OSError:
            pass

    def quarantine(self, path: str) -> str:
        """Refused snapshot: move it aside (``.quarantined`` suffix) so
        the next recovery scan skips it but a human can inspect it.
        Returns the original basename (ledger key)."""
        name = os.path.basename(path)
        try:
            os.replace(path, path + ".quarantined")
        except OSError:
            self.discard(path)
        return name

    def live(self) -> Iterable[int]:
        return tuple(self._files)
