"""Kill–restart chaos harness: crash the engine at scheduler-tick
boundaries and prove the durable layer loses nothing.

A :class:`KillPlan` is the durability sibling of
``repro.resilience.chaos.FaultPlan``: a *seeded schedule* of process
kills keyed by the global scheduler-tick count, memoized so repeated
queries agree and explicit tick overrides let a test strike exactly
where it wants.  :func:`drain_with_kills` then runs an engine the way
``run_until_drained`` would — but whenever the plan says so it "crashes"
the process (drops the engine on the floor, closing only the journal
file handle the way the OS would), builds a fresh engine via the
caller's factory, and calls ``recover()`` on it.  The tick counter is
global across incarnations, so a kill schedule spans restarts.

Nothing here imports the engine: the harness duck-types it (``step`` /
``queue`` / ``batcher`` / ``clock`` / ``results`` / ``recover``), the
same contract ``run_until_drained`` relies on, so benchmarks can drive
the real ``ServeEngine`` or a virtual-clock fake identically.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Dict, FrozenSet, Optional

_STALL_SPINS = 64    # mirror the engine's own no-progress guard


@dataclasses.dataclass
class KillPlan:
    """Seeded schedule of engine kills at scheduler-tick boundaries.

    ``should_kill(tick)`` draws (memoized) from
    ``random.Random(f"{seed}:{tick}")`` with probability ``kill_rate``;
    explicit ``kills`` ticks override the draw; ``max_kills`` bounds the
    total so a high rate cannot livelock a drain."""

    seed: int = 0
    kill_rate: float = 0.0
    kills: FrozenSet[int] = frozenset()
    max_kills: Optional[int] = None

    def __post_init__(self):
        if not (0.0 <= self.kill_rate <= 1.0):
            raise ValueError(
                f"kill_rate must be in [0, 1], got {self.kill_rate}")
        self.kills = frozenset(int(t) for t in self.kills)
        self.killed = 0
        self._memo: Dict[int, bool] = {}

    def should_kill(self, tick: int) -> bool:
        t = int(tick)
        if self.max_kills is not None and self.killed >= self.max_kills:
            return False
        hit = self._memo.get(t)
        if hit is None:
            if t in self.kills:
                hit = True
            elif self.kill_rate > 0:
                # str seeds hash stably across processes (same idiom as
                # FaultPlan) — kill schedules replay exactly
                hit = (random.Random(f"{self.seed}:{t}").random()
                       < self.kill_rate)
            else:
                hit = False
            self._memo[t] = hit
        if hit:
            self.killed += 1
        return hit


@dataclasses.dataclass
class KillReport:
    """What a killed-and-restarted drain did end to end."""
    restarts: int
    ticks: int
    delivered: Dict
    engine: object     # the final incarnation (for metrics/journal asserts)


def crash(engine) -> None:
    """Simulate a process death: the engine object is abandoned with no
    shutdown courtesy — only its journal file handle is closed, which is
    what the OS would do to the fd anyway.  In-flight run states, queue
    contents, and results that were never journaled are *gone*; that is
    the point."""
    j = getattr(engine, "journal", None)
    if j is not None and not j.closed:
        j.close()


def drain_with_kills(factory: Callable[[], object], plan: KillPlan, *,
                     max_restarts: int = 64,
                     max_ticks: int = 100000) -> KillReport:
    """Drain an engine to empty while ``plan`` kills it at tick
    boundaries.  ``factory()`` must build a *fresh* engine over the same
    journal path / snapshot dir (that is what makes recovery real).

    Results delivered before each crash are collected first — a real
    client would have received them (the finish was journaled + fsynced
    before delivery), so they count; everything still in flight at the
    kill must be re-delivered by a later incarnation."""
    eng = factory()
    eng.recover()
    delivered: Dict = {}
    restarts = 0
    ticks = 0
    stalls = 0
    while ticks < max_ticks:
        progressed = eng.step()
        if progressed:
            stalls = 0
            ticks += 1
            if plan.should_kill(ticks):
                delivered.update(eng.results)
                crash(eng)
                restarts += 1
                if restarts > max_restarts:
                    raise RuntimeError(
                        f"kill plan exceeded max_restarts={max_restarts}")
                eng = factory()
                eng.recover()
            continue
        if len(eng.queue) == 0:
            break
        now = eng.clock.now()
        t = eng.batcher.next_event(now)
        if t is None:
            raise RuntimeError(
                f"durability drain stalled: {len(eng.queue)} queued "
                "requests but no next event")
        if t <= now:
            stalls += 1
            if stalls > _STALL_SPINS:
                raise RuntimeError(
                    "durability drain made no progress across "
                    f"{_STALL_SPINS} scheduler passes")
            continue
        stalls = 0
        eng.clock.sleep_until(t)
    else:
        raise RuntimeError(f"durability drain hit max_ticks={max_ticks}")
    delivered.update(eng.results)
    return KillReport(restarts=restarts, ticks=ticks, delivered=delivered,
                      engine=eng)
