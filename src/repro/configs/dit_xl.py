"""DiT-XL/2 256×256 — the paper's primary image model
[Peebles & Xie, Scalable Diffusion Models with Transformers].

28 adaLN-zero blocks, d_model=1152, 16 heads, d_ff=4608, patch=2 over
32×32×4 SD-VAE latents (256 tokens), class-conditional (1000 ImageNet
classes) with classifier-free guidance.
"""
from repro.config import AttentionSpec, BlockSpec, MLPSpec, ModelConfig, Stage
from repro.configs.common import smoke_variant

D = 1152


def _block():
    return BlockSpec(
        mixer=AttentionSpec(num_heads=16, num_kv_heads=16, head_dim=72,
                            causal=False, pos_emb="none"),
        ffn=MLPSpec(d_ff=4608, activation="gelu_tanh", gated=False),
        norm="layernorm", adaln=True)


def full() -> ModelConfig:
    return ModelConfig(
        name="dit-xl-256",
        d_model=D, vocab_size=0, task="diffusion",
        stages=(Stage(unit=(_block(),), repeat=28),),
        norm="layernorm", pos_emb="sinusoidal",
        latent_shape=(32, 32, 4), patch=2, num_classes=1000,
        citation="arXiv:2212.09748 (DiT); SmoothCache §3.1")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128, unit_repeats=2)
