"""gemma2-9b [dense] — alternating local/global attention, logit softcaps,
pre+post norms [arXiv:2408.00118].

42 layers, d_model=3584, 16 heads (GQA kv=8, head_dim=256), d_ff=14336,
vocab=256000.  Unit = (local w=4096, global) × 21.
"""
from repro.config import AttentionSpec, BlockSpec, MLPSpec, ModelConfig, Stage
from repro.configs.common import smoke_variant

D = 3584


def _block(window):
    return BlockSpec(
        mixer=AttentionSpec(num_heads=16, num_kv_heads=8, head_dim=256,
                            window=window, causal=True, logit_softcap=50.0,
                            rope_theta=10000.0),
        ffn=MLPSpec(d_ff=14336, activation="gelu_tanh", gated=True),
        norm="rmsnorm", post_norm=True)


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        d_model=D, vocab_size=256_000,
        stages=(Stage(unit=(_block(4096), _block(None)), repeat=21),),
        norm="rmsnorm", tie_embeddings=True, embed_scale=True,
        logit_softcap=30.0, max_seq_len=8192,
        long_context="swa",   # global layers become w=swa_window for long_500k
        citation="arXiv:2408.00118")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128)
