"""qwen3-14b [dense] — GQA with per-head q/k RMSNorm [hf:Qwen/Qwen3-8B].

40 layers, d_model=5120, 40 heads (GQA kv=8, head_dim=128), d_ff=17408,
vocab=151936, SwiGLU, RMSNorm, RoPE theta=1e6.
"""
from repro.config import AttentionSpec, BlockSpec, MLPSpec, ModelConfig, Stage
from repro.configs.common import smoke_variant

D = 5120


def _block():
    return BlockSpec(
        mixer=AttentionSpec(num_heads=40, num_kv_heads=8, head_dim=128,
                            causal=True, qk_norm=True, rope_theta=1e6),
        ffn=MLPSpec(d_ff=17408, activation="silu", gated=True),
        norm="rmsnorm")


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b",
        d_model=D, vocab_size=151_936,
        stages=(Stage(unit=(_block(),), repeat=40),),
        norm="rmsnorm", max_seq_len=32_768, long_context="swa",
        citation="hf:Qwen/Qwen3-8B")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128, unit_repeats=2)
