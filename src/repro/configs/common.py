"""Shared helpers for architecture configs, incl. the smoke-test reducer."""
from __future__ import annotations

import dataclasses

from repro.config import (AttentionSpec, BlockSpec, MLPSpec, ModelConfig,
                          MoESpec, RGLRUSpec, SSMSpec, Stage)


def _shrink_mixer(m, d_model):
    if m is None:
        return None
    if isinstance(m, AttentionSpec):
        heads = 4 if m.num_heads >= 4 else m.num_heads
        kv = max(1, heads * m.num_kv_heads // m.num_heads)
        kw = dict(num_heads=heads, num_kv_heads=kv, head_dim=d_model // heads)
        if m.kind == "mla":
            kw.update(q_lora_rank=(64 if m.q_lora_rank else None),
                      kv_lora_rank=64, rope_head_dim=16, nope_head_dim=32,
                      v_head_dim=32)
        if m.window is not None:
            kw["window"] = min(m.window, 16)
        return dataclasses.replace(m, **kw)
    if isinstance(m, SSMSpec):
        return dataclasses.replace(m, d_state=16, head_dim=16, chunk=8)
    return dataclasses.replace(m, num_heads=2)


def _shrink_ffn(f, d_model):
    if f is None:
        return None
    if isinstance(f, MoESpec):
        return dataclasses.replace(
            f, num_experts=min(4, f.num_experts), top_k=min(2, f.top_k),
            d_ff=max(32, d_model), num_shared=min(1, f.num_shared),
            d_ff_shared=(max(32, d_model) if f.num_shared else 0))
    return dataclasses.replace(f, d_ff=2 * d_model)


def smoke_variant(cfg: ModelConfig, d_model: int = 128,
                  unit_repeats: int = 1) -> ModelConfig:
    """Reduced same-family variant: ≤2-ish layers (one unit per stage),
    d_model ≤ 512, ≤4 experts — runs a CPU forward/train step fast."""
    assert d_model <= 512
    stages = []
    for st in cfg.stages:
        unit = tuple(
            dataclasses.replace(
                b, mixer=_shrink_mixer(b.mixer, d_model),
                cross=_shrink_mixer(b.cross, d_model),
                ffn=_shrink_ffn(b.ffn, d_model))
            for b in st.unit)
        stages.append(Stage(unit=unit, repeat=min(unit_repeats, st.repeat)))
    return cfg.replace(
        name=cfg.name + "-smoke", d_model=d_model,
        vocab_size=min(cfg.vocab_size, 512) if cfg.vocab_size else cfg.vocab_size,
        stages=tuple(stages), max_seq_len=min(cfg.max_seq_len, 256),
        cond_dim=min(cfg.cond_dim, 64) if cfg.cond_dim else 0,
        num_prefix_embeds=min(cfg.num_prefix_embeds, 8),
        latent_shape=_shrink_latent(cfg.latent_shape),
        swa_window=16, dtype="float32")


def _shrink_latent(shape):
    if not shape:
        return ()
    if len(shape) == 3:         # (H, W, C) image latents
        return (8, 8, shape[-1])
    if len(shape) == 4:         # (T, H, W, C) video latents
        return (4, 8, 8, shape[-1])
    return (16, shape[-1])      # (L, C) audio latents
