"""minicpm3-4b [dense] — MLA attention in a small dense model
[hf:openbmb/MiniCPM3-4B].

62 layers, d_model=2560, 40 heads (MLA: q_lora=768, kv_lora=256,
nope=64, rope=32, v=64), d_ff=6400, vocab=73448.
"""
from repro.config import AttentionSpec, BlockSpec, MLPSpec, ModelConfig, Stage
from repro.configs.common import smoke_variant

D = 2560


def _block():
    return BlockSpec(
        mixer=AttentionSpec(kind="mla", num_heads=40, causal=True,
                            q_lora_rank=768, kv_lora_rank=256,
                            rope_head_dim=32, nope_head_dim=64,
                            v_head_dim=64),
        ffn=MLPSpec(d_ff=6400, activation="silu", gated=True),
        norm="rmsnorm")


def full() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        d_model=D, vocab_size=73_448,
        stages=(Stage(unit=(_block(),), repeat=62),),
        norm="rmsnorm", tie_embeddings=True,
        max_seq_len=32_768, long_context="swa",
        citation="hf:openbmb/MiniCPM3-4B")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128, unit_repeats=2)
