"""OpenSora-v1.2-like STDiT — the paper's text-to-video model
[arXiv:2412.20404 Open-Sora; SmoothCache §3.1].

28 (spatial, temporal) block pairs, d_model=1152, 16 heads; every block has
self-attn + cross-attn (T5 text memory, stubbed) + FFN, giving the paper's
6 SmoothCache layer types: {s_attn, s_xattn, s_ffn, t_attn, t_xattn, t_ffn}.
Latents: (16, 32, 32, 4) = 2 s of 480p-ish video after VAE, patch (1,2,2)
→ T=16 frames × S=256 spatial tokens.
"""
from repro.config import AttentionSpec, BlockSpec, MLPSpec, ModelConfig, Stage
from repro.configs.common import smoke_variant

D = 1152


def _block(pattern, tag):
    return BlockSpec(
        mixer=AttentionSpec(num_heads=16, num_kv_heads=16, head_dim=72,
                            causal=False, pattern=pattern, rope_theta=10000.0),
        cross=AttentionSpec(num_heads=16, num_kv_heads=16, head_dim=72,
                            cross=True, causal=False, pos_emb="none"),
        ffn=MLPSpec(d_ff=4608, activation="gelu_tanh", gated=False),
        norm="layernorm", adaln=True, type_tag=tag)


def full() -> ModelConfig:
    return ModelConfig(
        name="opensora-v12",
        d_model=D, vocab_size=0, task="diffusion",
        stages=(Stage(unit=(_block("spatial", "s_"), _block("temporal", "t_")),
                      repeat=28),),
        norm="layernorm",
        latent_shape=(16, 32, 32, 4), patch=2, cond_dim=D,
        citation="SmoothCache §3.1; Open-Sora v1.2")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128)
