"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060].

48 layers, d_model=2048, ssm_state=128, vocab=50280, expand=2 (d_inner=4096),
head_dim=64 (64 SSD heads), no separate FFN (folded into the mixer).
"""
from repro.config import BlockSpec, ModelConfig, SSMSpec, Stage
from repro.configs.common import smoke_variant

D = 2048


def _block():
    return BlockSpec(
        mixer=SSMSpec(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=128),
        ffn=None, norm="rmsnorm")


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        d_model=D, vocab_size=50_280,
        stages=(Stage(unit=(_block(),), repeat=48),),
        norm="rmsnorm", tie_embeddings=True,
        max_seq_len=8192, long_context="native",
        citation="arXiv:2405.21060")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128, unit_repeats=2)
