"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 attn:recurrent
pattern [arXiv:2402.19427 Griffin / RecurrentGemma].

26 layers, d_model=2560, 10 heads (MQA kv=1), d_ff=7680, vocab=256000,
local attention window 2048.  Layer pattern: (rec, rec, attn) × 8 + (rec, rec).
"""
from repro.config import (AttentionSpec, BlockSpec, MLPSpec, ModelConfig,
                          RGLRUSpec, Stage)
from repro.configs.common import smoke_variant

D = 2560


def _rec_block():
    return BlockSpec(
        mixer=RGLRUSpec(num_heads=10, conv_width=4, expand=1),
        ffn=MLPSpec(d_ff=7680, activation="gelu_tanh", gated=True),
        norm="rmsnorm")


def _attn_block():
    return BlockSpec(
        mixer=AttentionSpec(num_heads=10, num_kv_heads=1, head_dim=256,
                            window=2048, causal=True, rope_theta=10000.0),
        ffn=MLPSpec(d_ff=7680, activation="gelu_tanh", gated=True),
        norm="rmsnorm")


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        d_model=D, vocab_size=256_000,
        stages=(Stage(unit=(_rec_block(), _rec_block(), _attn_block()), repeat=8),
                Stage(unit=(_rec_block(), _rec_block()), repeat=1)),
        norm="rmsnorm", tie_embeddings=True, embed_scale=True,
        max_seq_len=8192, long_context="native",
        citation="arXiv:2402.19427")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128)
