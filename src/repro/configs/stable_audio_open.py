"""Stable-Audio-Open-like DiT — the paper's text-to-audio model
[arXiv:2407.14358; SmoothCache §3.1].

24 blocks, d_model=1536, 24 heads, each with self-attn + cross-attn
(T5 text memory, stubbed) + gated FFN — the paper's 3 SmoothCache types
{attn, xattn, ffn}.  Latents: (216, 64) ≈ 10 s at 21.5 Hz × 64 channels
from the (stubbed) audio VAE.
"""
from repro.config import AttentionSpec, BlockSpec, MLPSpec, ModelConfig, Stage
from repro.configs.common import smoke_variant

D = 1536


def _block():
    return BlockSpec(
        mixer=AttentionSpec(num_heads=24, num_kv_heads=24, head_dim=64,
                            causal=False, rope_theta=10000.0),
        cross=AttentionSpec(num_heads=24, num_kv_heads=24, head_dim=64,
                            cross=True, causal=False, pos_emb="none"),
        ffn=MLPSpec(d_ff=6144, activation="silu", gated=True),
        norm="layernorm", adaln=True)


def full() -> ModelConfig:
    return ModelConfig(
        name="stable-audio-open",
        d_model=D, vocab_size=0, task="diffusion",
        stages=(Stage(unit=(_block(),), repeat=24),),
        norm="layernorm",
        latent_shape=(216, 64), patch=1, cond_dim=768,
        citation="SmoothCache §3.1; arXiv:2407.14358")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128, unit_repeats=2)
