"""deepseek-v3-671b [moe] — MLA + 1 shared / 256 routed top-8 experts + MTP
[arXiv:2412.19437].

61 layers, d_model=7168, 128 heads (MLA), routed expert d_ff=2048,
vocab=129280.  First 3 layers are dense (d_ff=18432); layers 4–61 are MoE
(256 routed top-8 + 1 shared expert, sigmoid router with selection bias,
routed scaling 2.5).  MTP depth 1 (one extra predict-ahead head).
"""
from repro.config import (AttentionSpec, BlockSpec, MLPSpec, ModelConfig,
                          MoESpec, Stage)
from repro.configs.common import smoke_variant

D = 7168


def _mla():
    return AttentionSpec(kind="mla", num_heads=128, causal=True,
                         q_lora_rank=1536, kv_lora_rank=512,
                         rope_head_dim=64, nope_head_dim=128, v_head_dim=128)


def _dense_block():
    return BlockSpec(mixer=_mla(),
                     ffn=MLPSpec(d_ff=18432, activation="silu", gated=True),
                     norm="rmsnorm")


def _moe_block():
    return BlockSpec(
        mixer=_mla(),
        ffn=MoESpec(num_experts=256, top_k=8, d_ff=2048, num_shared=1,
                    d_ff_shared=2048, router="sigmoid", router_scale=2.5,
                    norm_topk=True, aux_loss_weight=1e-4),
        norm="rmsnorm")


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        d_model=D, vocab_size=129_280,
        stages=(Stage(unit=(_dense_block(),), repeat=3),
                Stage(unit=(_moe_block(),), repeat=58)),
        norm="rmsnorm", max_seq_len=32_768, mtp_depth=1,
        long_context="swa",   # MLA latent cache also viable; see DESIGN.md §5
        citation="arXiv:2412.19437")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128)
