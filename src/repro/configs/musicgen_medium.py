"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens
with cross-attention to text conditioning [arXiv:2306.05284].

48 layers, d_model=1536, 24 heads (kv=24, MHA), d_ff=6144, vocab=2048 per
codebook, 4 codebooks (delay pattern handled by the data pipeline).
The EnCodec codec and T5 text encoder are STUBS — `input_specs()` provides
token ids and precomputed text-memory embeddings (DESIGN.md §6).
"""
from repro.config import AttentionSpec, BlockSpec, MLPSpec, ModelConfig, Stage
from repro.configs.common import smoke_variant

D = 1536


def _block():
    return BlockSpec(
        mixer=AttentionSpec(num_heads=24, num_kv_heads=24, head_dim=64,
                            causal=True, pos_emb="none"),
        cross=AttentionSpec(num_heads=24, num_kv_heads=24, head_dim=64,
                            cross=True, causal=False, pos_emb="none"),
        ffn=MLPSpec(d_ff=6144, activation="gelu", gated=False),
        norm="layernorm")


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        d_model=D, vocab_size=2048, num_codebooks=4,
        stages=(Stage(unit=(_block(),), repeat=48),),
        norm="layernorm", pos_emb="sinusoidal",
        cond_dim=D,                      # T5 memory projected to d_model (stub)
        max_seq_len=4096, long_context="swa",
        citation="arXiv:2306.05284")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128, unit_repeats=2)
