"""internvl2-1b [vlm] — InternViT-300M + Qwen2-0.5B-style language backbone
[arXiv:2404.16821].

Language decoder: 24 layers, d_model=896, 14 heads (GQA kv=2, head_dim=64),
d_ff=4864, vocab=151655, QKV bias.  The InternViT vision encoder + MLP
projector are STUBS — `input_specs()` provides 256 precomputed patch
embeddings per image prepended to the token sequence (DESIGN.md §6).
"""
from repro.config import AttentionSpec, BlockSpec, MLPSpec, ModelConfig, Stage
from repro.configs.common import smoke_variant

D = 896


def _block():
    return BlockSpec(
        mixer=AttentionSpec(num_heads=14, num_kv_heads=2, head_dim=64,
                            causal=True, qkv_bias=True, rope_theta=1e6),
        ffn=MLPSpec(d_ff=4864, activation="silu", gated=True),
        norm="rmsnorm")


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        d_model=D, vocab_size=151_655,
        stages=(Stage(unit=(_block(),), repeat=24),),
        norm="rmsnorm", tie_embeddings=True,
        num_prefix_embeds=256,           # ViT patch embeddings (stub)
        max_seq_len=8192, long_context="swa",
        citation="arXiv:2404.16821")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128, unit_repeats=2)
