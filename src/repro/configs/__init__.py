"""Architecture config registry: ``get(name)`` → module with full()/smoke().

The 10 assigned architectures plus the paper's own three DiT families.
"""
from __future__ import annotations

import importlib

REGISTRY = {
    # --- assigned pool ---
    "recurrentgemma-2b":          "repro.configs.recurrentgemma_2b",
    "gemma2-9b":                  "repro.configs.gemma2_9b",
    "mamba2-1.3b":                "repro.configs.mamba2_1p3b",
    "musicgen-medium":            "repro.configs.musicgen_medium",
    "qwen3-14b":                  "repro.configs.qwen3_14b",
    "qwen2.5-14b":                "repro.configs.qwen2_5_14b",
    "deepseek-v3-671b":           "repro.configs.deepseek_v3_671b",
    "minicpm3-4b":                "repro.configs.minicpm3_4b",
    "internvl2-1b":               "repro.configs.internvl2_1b",
    "llama4-maverick-400b-a17b":  "repro.configs.llama4_maverick_400b",
    # --- the paper's own models ---
    "dit-xl-256":                 "repro.configs.dit_xl",
    "opensora-v12":               "repro.configs.opensora_v12",
    "stable-audio-open":          "repro.configs.stable_audio_open",
}

ASSIGNED = [k for k in REGISTRY if k not in
            ("dit-xl-256", "opensora-v12", "stable-audio-open")]
PAPER_MODELS = ["dit-xl-256", "opensora-v12", "stable-audio-open"]


def get_module(name: str):
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return importlib.import_module(REGISTRY[name])


def get(name: str, variant: str = "full"):
    mod = get_module(name)
    return getattr(mod, variant)()
