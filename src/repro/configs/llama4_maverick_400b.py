"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + shared
expert, interleaved dense/MoE, chunked local attention with NoPE globals,
early-fusion multimodal [hf:meta-llama/Llama-4-Scout-17B-16E family].

48 layers, d_model=5120, 40 heads (GQA kv=8, head_dim=128), expert
d_ff=8192 (dense layers d_ff=16384), vocab=202048.  Unit of 4 layers:
3 × chunked-local (w=8192, RoPE) + 1 × global (NoPE); MoE on every 2nd
layer (interleave step 2).  Vision early fusion is a STUB — precomputed
patch embeddings are prepended (DESIGN.md §6).
"""
from repro.config import (AttentionSpec, BlockSpec, MLPSpec, ModelConfig,
                          MoESpec, Stage)
from repro.configs.common import smoke_variant

D = 5120


def _attn(window, rope=True):
    return AttentionSpec(num_heads=40, num_kv_heads=8, head_dim=128,
                         window=window, causal=True,
                         pos_emb="rope" if rope else "none",
                         rope_theta=500_000.0)


def _moe():
    return MoESpec(num_experts=128, top_k=1, d_ff=8192, num_shared=1,
                   d_ff_shared=8192, router="sigmoid", norm_topk=False,
                   aux_loss_weight=1e-3)


def _dense():
    return MLPSpec(d_ff=16384, activation="silu", gated=True)


def full() -> ModelConfig:
    unit = (
        BlockSpec(mixer=_attn(8192), ffn=_dense(), norm="rmsnorm"),
        BlockSpec(mixer=_attn(8192), ffn=_moe(), norm="rmsnorm"),
        BlockSpec(mixer=_attn(8192), ffn=_dense(), norm="rmsnorm"),
        BlockSpec(mixer=_attn(None, rope=False), ffn=_moe(), norm="rmsnorm"),
    )
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        d_model=D, vocab_size=202_048,
        stages=(Stage(unit=unit, repeat=12),),
        norm="rmsnorm", num_prefix_embeds=256,
        max_seq_len=32_768, long_context="swa",
        citation="hf:meta-llama/Llama-4-Scout-17B-16E")


def smoke() -> ModelConfig:
    return smoke_variant(full(), d_model=128)
