"""Checkpoint IO: msgpack + raw numpy buffers (no orbax offline).

Layout: a single ``.ckpt`` file holding a msgpack header (treedef paths,
shapes, dtypes, offsets) followed by the concatenated raw array bytes.
Host-gathered save / restore; under pjit the caller re-shards on load via
``jax.device_put(tree, shardings)``.
"""
from __future__ import annotations

import io
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

MAGIC = b"REPROCKPT1"


def _flatten_with_paths(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out += _flatten_with_paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _flatten_with_paths(v, f"{prefix}/{i}")
    elif tree is None:
        out.append((prefix, None))
    else:
        out.append((prefix, tree))
    return out


def save(path: str, tree: Any, metadata: Dict | None = None) -> None:
    pairs = _flatten_with_paths(tree)
    header = {"meta": metadata or {}, "entries": [], "kinds": _kinds(tree)}
    payload = io.BytesIO()
    for name, arr in pairs:
        if arr is None:
            header["entries"].append({"name": name, "none": True})
            continue
        a = np.asarray(jax.device_get(arr))
        off = payload.tell()
        payload.write(a.tobytes())
        header["entries"].append({
            "name": name, "shape": list(a.shape), "dtype": str(a.dtype),
            "offset": off, "none": False})
    hb = msgpack.packb(header)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(len(hb).to_bytes(8, "little"))
        f.write(hb)
        f.write(payload.getvalue())
    os.replace(tmp, path)


def _kinds(tree):
    """Minimal structure spec so restore can rebuild tuples vs lists."""
    if isinstance(tree, dict):
        return {"t": "dict", "c": {k: _kinds(v) for k, v in tree.items()}}
    if isinstance(tree, tuple):
        return {"t": "tuple", "c": [_kinds(v) for v in tree]}
    if isinstance(tree, list):
        return {"t": "list", "c": [_kinds(v) for v in tree]}
    if tree is None:
        return {"t": "none"}
    return {"t": "leaf"}


def restore(path: str):
    """Returns (tree, metadata)."""
    with open(path, "rb") as f:
        magic = f.read(len(MAGIC))
        assert magic == MAGIC, f"bad checkpoint magic in {path}"
        hlen = int.from_bytes(f.read(8), "little")
        header = msgpack.unpackb(f.read(hlen))
        body = f.read()
    leaves = {}
    for e in header["entries"]:
        if e.get("none"):
            leaves[e["name"]] = None
            continue
        dt = np.dtype(e["dtype"])
        n = int(np.prod(e["shape"])) if e["shape"] else 1
        a = np.frombuffer(body, dt, count=n, offset=e["offset"])
        leaves[e["name"]] = jnp.asarray(a.reshape(e["shape"]))
    tree = _rebuild(header["kinds"], leaves, "")
    return tree, header["meta"]


def _rebuild(kind, leaves, prefix):
    t = kind["t"]
    if t == "dict":
        return {k: _rebuild(v, leaves, f"{prefix}/{k}")
                for k, v in kind["c"].items()}
    if t == "tuple":
        return tuple(_rebuild(v, leaves, f"{prefix}/{i}")
                     for i, v in enumerate(kind["c"]))
    if t == "list":
        return [_rebuild(v, leaves, f"{prefix}/{i}")
                for i, v in enumerate(kind["c"])]
    if t == "none":
        return None
    return leaves[prefix]
