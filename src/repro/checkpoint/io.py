"""Checkpoint IO: msgpack + raw numpy buffers (no orbax offline).

Layout: ``MAGIC`` + 8-byte little-endian header length + msgpack header
(treedef paths, shapes, dtypes, offsets, declared body length + sha256)
followed by the concatenated raw array bytes.  Host-gathered save /
restore; under pjit the caller re-shards on load via
``jax.device_put(tree, shardings)``.

Robustness contract (the durable-serving layer builds on it):

* writes are atomic — the file is staged as ``.tmp`` and published with
  ``os.replace``, so a crashed writer never leaves a half-written file
  under the real name;
* reads are *refusals, not garbage*: a bad magic, an unreadable header, a
  torn/truncated body (shorter than the header-declared length, or an
  entry reaching past the end), or a body whose sha256 disagrees with the
  header all raise :class:`CheckpointError` — never a bare ``assert``
  (which vanishes under ``python -O``) and never a silently-short
  ``np.frombuffer`` read.
"""
from __future__ import annotations

import hashlib
import io
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp

try:
    import msgpack
except ImportError:                            # pragma: no cover
    msgpack = None                             # gated in _require_msgpack
import numpy as np

MAGIC = b"REPROCKPT1"


class CheckpointError(ValueError):
    """A checkpoint file was refused: wrong magic, truncated/torn, or its
    content checksum disagrees with the header.  Callers (e.g. snapshot
    recovery) treat this as "quarantine and fall back", never as data."""


def _require_msgpack() -> None:
    if msgpack is None:                        # pragma: no cover
        raise CheckpointError(
            "checkpoint IO needs the msgpack package, which is not "
            "installed in this environment")


def _flatten_with_paths(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out += _flatten_with_paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _flatten_with_paths(v, f"{prefix}/{i}")
    elif tree is None:
        out.append((prefix, None))
    else:
        out.append((prefix, tree))
    return out


def save(path: str, tree: Any, metadata: Dict | None = None) -> None:
    _require_msgpack()
    pairs = _flatten_with_paths(tree)
    header = {"meta": metadata or {}, "entries": [], "kinds": _kinds(tree)}
    payload = io.BytesIO()
    for name, arr in pairs:
        if arr is None:
            header["entries"].append({"name": name, "none": True})
            continue
        a = np.asarray(jax.device_get(arr))
        off = payload.tell()
        payload.write(a.tobytes())
        header["entries"].append({
            "name": name, "shape": list(a.shape), "dtype": str(a.dtype),
            "offset": off, "none": False})
    body = payload.getvalue()
    # declared length + content hash: restore() detects torn writes and
    # bit-rot instead of returning silently-short frombuffer reads
    header["body_len"] = len(body)
    header["body_sha256"] = hashlib.sha256(body).hexdigest()
    hb = msgpack.packb(header)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(len(hb).to_bytes(8, "little"))
        f.write(hb)
        f.write(body)
    os.replace(tmp, path)


def _kinds(tree):
    """Minimal structure spec so restore can rebuild tuples vs lists."""
    if isinstance(tree, dict):
        return {"t": "dict", "c": {k: _kinds(v) for k, v in tree.items()}}
    if isinstance(tree, tuple):
        return {"t": "tuple", "c": [_kinds(v) for v in tree]}
    if isinstance(tree, list):
        return {"t": "list", "c": [_kinds(v) for v in tree]}
    if tree is None:
        return {"t": "none"}
    return {"t": "leaf"}


def _read_header(f, path: str) -> Dict:
    magic = f.read(len(MAGIC))
    if magic != MAGIC:
        raise CheckpointError(f"bad checkpoint magic in {path}")
    raw = f.read(8)
    if len(raw) < 8:
        raise CheckpointError(f"truncated checkpoint header in {path}")
    hlen = int.from_bytes(raw, "little")
    hb = f.read(hlen)
    if len(hb) < hlen:
        raise CheckpointError(f"truncated checkpoint header in {path}")
    try:
        header = msgpack.unpackb(hb)
    except Exception as e:
        raise CheckpointError(
            f"corrupt checkpoint header in {path}: "
            f"{type(e).__name__}: {e}") from e
    if not isinstance(header, dict) or "entries" not in header \
            or "kinds" not in header:
        raise CheckpointError(f"malformed checkpoint header in {path}")
    return header


def read_meta(path: str) -> Dict:
    """Read only the metadata dict — magic + header are verified, the
    (possibly large) array body is not touched.  Recovery scans use this
    to order/filter snapshots before paying for a full restore."""
    _require_msgpack()
    with open(path, "rb") as f:
        header = _read_header(f, path)
    return header.get("meta", {})


def restore(path: str):
    """Returns ``(tree, metadata)``.  Refuses (with
    :class:`CheckpointError`) files whose magic/header is unreadable,
    whose body is shorter than the header declares (torn write), whose
    entries reach past the body, or whose body sha256 disagrees with the
    header (bit-rot / tamper).  Length and hash checks tolerate
    pre-``body_len`` files, which simply lack the declared fields."""
    _require_msgpack()
    with open(path, "rb") as f:
        header = _read_header(f, path)
        body = f.read()
    declared = header.get("body_len")
    if declared is not None and len(body) != int(declared):
        raise CheckpointError(
            f"torn checkpoint {path}: body is {len(body)} bytes, header "
            f"declares {declared}")
    want_sha = header.get("body_sha256")
    if want_sha is not None:
        got = hashlib.sha256(body).hexdigest()
        if got != want_sha:
            raise CheckpointError(
                f"checkpoint {path} failed its content checksum "
                f"(sha256 {got[:12]}… != declared {str(want_sha)[:12]}…)")
    leaves = {}
    for e in header["entries"]:
        if e.get("none"):
            leaves[e["name"]] = None
            continue
        try:
            dt = np.dtype(e["dtype"])
        except TypeError as exc:
            raise CheckpointError(
                f"checkpoint {path}: entry {e.get('name')!r} has invalid "
                f"dtype {e.get('dtype')!r}") from exc
        n = int(np.prod(e["shape"])) if e["shape"] else 1
        need = int(e["offset"]) + n * dt.itemsize
        if need > len(body):
            # pre-body_len files can still tear — per-entry bounds catch it
            raise CheckpointError(
                f"torn checkpoint {path}: entry {e['name']!r} needs bytes "
                f"up to {need}, body has {len(body)}")
        a = np.frombuffer(body, dt, count=n, offset=e["offset"])
        leaves[e["name"]] = jnp.asarray(a.reshape(e["shape"]))
    tree = _rebuild(header["kinds"], leaves, "")
    return tree, header.get("meta", {})


def _rebuild(kind, leaves, prefix):
    t = kind["t"]
    if t == "dict":
        return {k: _rebuild(v, leaves, f"{prefix}/{k}")
                for k, v in kind["c"].items()}
    if t == "tuple":
        return tuple(_rebuild(v, leaves, f"{prefix}/{i}")
                     for i, v in enumerate(kind["c"]))
    if t == "list":
        return [_rebuild(v, leaves, f"{prefix}/{i}")
                for i, v in enumerate(kind["c"])]
    if t == "none":
        return None
    return leaves[prefix]
