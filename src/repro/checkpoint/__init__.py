from repro.checkpoint.io import (CheckpointError, read_meta,  # noqa: F401
                                 restore, save)
