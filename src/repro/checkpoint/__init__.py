from repro.checkpoint.io import restore, save  # noqa: F401
