"""AdamW with decoupled weight decay and global-norm clipping (from scratch;
no optax in this environment).  State and updates are plain pytrees, so the
optimizer composes with pjit sharding (moments inherit the param sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    schedule: Optional[Callable] = None      # step → lr multiplier


def init_state(params):
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(jnp.zeros_like, params),
        "nu": jax.tree.map(jnp.zeros_like, params),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule is not None else 1.0)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (jax.tree.unflatten(treedef, new_p),
            {"step": step,
             "mu": jax.tree.unflatten(treedef, new_m),
             "nu": jax.tree.unflatten(treedef, new_v)},
            {"grad_norm": gnorm, "lr": lr})


# ---------------------------------------------------------------------------
# LR schedules (step → multiplier)
# ---------------------------------------------------------------------------

def cosine_schedule(warmup: int, total: int, final_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(1.0, warmup)
        prog = jnp.clip((s - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return f


def constant_schedule():
    return lambda step: jnp.ones((), jnp.float32)
