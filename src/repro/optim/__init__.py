from repro.optim.adamw import (AdamWConfig, apply_updates, cosine_schedule,  # noqa: F401
                               constant_schedule, global_norm, init_state)
