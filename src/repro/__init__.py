"""repro — SmoothCache on TPU: multi-pod JAX DiT framework."""
__version__ = "0.1.0"
