"""Optional sharding-constraint context for model internals.

Model code calls ``constrain(x, "batch", None, "model", ...)`` with symbolic
axes; outside a ``use(mesh)`` context this is a no-op (CPU tests, examples),
inside it becomes ``with_sharding_constraint`` with divisibility-checked
axes.  This is how the launcher pins the Megatron-style activation layout
(batch over pod+data; heads or sequence over model) without threading mesh
objects through every layer.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_shard_ctx",
                                                      default=None)


@contextlib.contextmanager
def use(mesh):
    tok = _CTX.set(mesh)
    try:
        yield
    finally:
        _CTX.reset(tok)


def active() -> bool:
    return _CTX.get() is not None


def mesh():
    return _CTX.get()


def _fit(m, dim: int, sym):
    if sym is None:
        return None
    axes = (tuple(a for a in ("pod", "data") if a in m.axis_names)
            if sym == "batch" else
            ((sym,) if isinstance(sym, str) else tuple(sym)))
    axes = tuple(a for a in axes if a in m.axis_names)
    n = 1
    for a in axes:
        n *= m.shape[a]
    if n <= 1 or dim % n != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def constrain(x, *syms):
    """Constrain ``x`` (ndim == len(syms)) when a mesh context is active."""
    m = _CTX.get()
    if m is None or x is None:
        return x
    assert x.ndim == len(syms), f"{x.shape} vs {syms}"
    spec = P(*[_fit(m, x.shape[i], s) for i, s in enumerate(syms)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec))
