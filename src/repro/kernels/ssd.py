"""Pallas TPU kernel for the Mamba-2 SSD chunked scan [arXiv:2405.21060].

TPU adaptation: the SSD algorithm is already a chunked formulation
(quadratic intra-chunk matmuls — MXU work — plus a linear inter-chunk state
recurrence).  We map (batch, head) onto parallel grid axes and the chunk
axis onto the innermost sequential axis, carrying the (P, N) state in VMEM
scratch — the TPU analogue of the paper's warp-level GPU pipelining.  Chunk
length and the (P, N) = (head_dim, d_state) tile are picked so all operands
of the three chunk matmuls sit in VMEM at MXU-aligned shapes.

Validated against two independent oracles (chunked + sequential) in
``repro.kernels.ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hT_ref, state_ref,
                *, chunk: int, num_chunks: int):
    z = pl.program_id(2)

    @pl.when(z == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)         # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)          # (Q,)
    a = a_ref[0]                                      # scalar decay rate
    b = b_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)

    da = -dt * a                                      # (Q,) log-decays
    cum = jnp.cumsum(da)                              # inclusive cumsum
    total = cum[-1]

    # intra-chunk: decay[q, s] = exp(cum[q] − cum[s]) for s ≤ q
    diff = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
           <= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0))
    decay = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * decay * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += (c · exp(cum)) @ stateᵀ
    c_in = c * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(c_in, state_ref[...],
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: h ← exp(total)·h + Σ_q dt_q exp(total − cum_q) x_q b_qᵀ
    w = dt * jnp.exp(total - cum)                     # (Q,)
    upd = jax.lax.dot_general(x * w[:, None], b, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = jnp.exp(total) * state_ref[...] + upd

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(z == num_chunks - 1)
    def _emit_state():
        hT_ref[0, 0, ...] = state_ref[...]


def ssd(x, dt, a, b, c, *, chunk: int = 128, interpret: bool = False):
    """x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, G, N).
    Returns (y (B, L, H, P), hT (B, H, P, N))."""
    bs, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    chunk = min(chunk, l)
    if l % chunk:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = x.shape[1]
    num_chunks = lp // chunk
    grid = (bs, h, num_chunks)

    kern = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=num_chunks)
    y, hT = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, z: (bi, z, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, z: (bi, z, hi)),
            pl.BlockSpec((1,), lambda bi, hi, z: (hi,)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, z, _rep=rep: (bi, z, hi // _rep, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, z, _rep=rep: (bi, z, hi // _rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, z: (bi, z, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, z: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs, lp, h, p), x.dtype),
            jax.ShapeDtypeStruct((bs, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a.astype(jnp.float32), b, c)
    return y[:, :l], hT
