"""Pallas TPU flash attention with GQA, causal/sliding-window masking and
Gemma-2 logit soft-capping.

TPU adaptation of the paper's attention hot spot (SmoothCache Fig. 5: attn
is ~half the DiT compute): online-softmax blocking sized for VMEM, with the
q/k block shapes kept at MXU-friendly multiples of 128 (the systolic array
contraction width).  Grid = (batch·heads, q-blocks, k-blocks); the k axis is
the innermost (sequential) dimension so the (bq, d) accumulator lives in
VMEM scratch across k iterations.

Validated against ``repro.kernels.ref.flash_attention_ref`` in interpret
mode (this container has no TPU); on device the same code lowers through
``pl.pallas_call`` unchanged.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -2.0e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: Optional[int],
                 softcap: Optional[float], bq: int, bk: int, num_kb: int,
                 lk_actual: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0].astype(jnp.float32)                 # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    i = pl.program_id(1)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = kpos < lk_actual            # mask zero-padded keys
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    # fully-masked rows: exp(NEG_INF - NEG_INF) = 1 would poison l; zero them
    p = jnp.where(ok, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(j == num_kb - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Lq, H, D); k, v: (B, Lk, KV, D) → (B, Lq, H, D).

    Pads Lq/Lk up to block multiples (mask keeps padding inert for causal
    self-attention where Lq == Lk positions align)."""
    b, lq, h, d = q.shape
    lk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    bq = min(block_q, max(8, lq))
    bk = min(block_k, max(8, lk))
    lq_p = -(-lq // bq) * bq
    lk_p = -(-lk // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, lq_p - lq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, lk_p - lk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, lk_p - lk), (0, 0), (0, 0)))
    # (B, L, H, D) → (B*H, L, D) head-major layout for the grid
    qh = qp.transpose(0, 2, 1, 3).reshape(b * h, lq_p, d)
    kh = kp.transpose(0, 2, 1, 3).reshape(b * kv, lk_p, d)
    vh = vp.transpose(0, 2, 1, 3).reshape(b * kv, lk_p, d)

    num_kb = lk_p // bk
    grid = (b * h, lq_p // bq, num_kb)

    def q_idx(bh, i, j):
        return (bh, i, 0)

    def kv_idx(bh, i, j):
        return ((bh // h) * kv + (bh % h) // g, j, 0)

    kern = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, num_kb=num_kb, lk_actual=lk)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_idx),
            pl.BlockSpec((1, bk, d), kv_idx),
            pl.BlockSpec((1, bk, d), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_idx),
        out_shape=jax.ShapeDtypeStruct((b * h, lq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh)
    out = out.reshape(b, h, lq_p, d).transpose(0, 2, 1, 3)
    return out[:, :lq]
