"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None):
    """q: (B, Lq, H, D); k, v: (B, Lk, KV, D) with H % KV == 0.
    Full-precision softmax attention — the oracle for the Pallas kernel."""
    b, lq, h, d = q.shape
    lk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qr = q.reshape(b, lq, kv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qr, k).astype(jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(lq)[:, None]
    kpos = jnp.arange(lk)[None, :]
    ok = jnp.ones((lq, lk), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(b, lq, h, d)


def ssd_ref(x, dt, a, b, c, chunk: int = 64, h0=None):
    """Mamba-2 SSD oracle — see repro.models.ssm.ssd_chunked."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, a, b, c, chunk, h0)


def ssd_sequential_ref(x, dt, a, b, c):
    """O(L) sequential recurrence — independent second oracle for SSD."""
    bs, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    ch = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(-dtf * a[None, None, :])                   # (B,L,H)

    def step(state, inp):
        xt, dtt, dect, bt, ct = inp
        state = state * dect[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt, bt)
        yt = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, yt

    init = jnp.zeros((bs, h, p, n), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          decay.transpose(1, 0, 2), bh.transpose(1, 0, 2, 3),
          ch.transpose(1, 0, 2, 3))
    hT, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hT
