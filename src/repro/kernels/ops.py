"""Jit'd public wrappers around the Pallas kernels.

On TPU the kernels lower natively through ``pl.pallas_call``; everywhere
else (this CPU container, unit tests) they execute in interpret mode, which
runs the kernel body in Python per grid cell — bit-accurate to the TPU
blocking, just slow.  ``REPRO_KERNEL_INTERPRET=0/1`` overrides detection.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ssd as _ssd


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale, block_q=block_q,
                               block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a, b, c, *, chunk: int = 128,
        interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    return _ssd.ssd(x, dt, a, b, c, chunk=chunk, interpret=interpret)
