"""Pallas TPU kernels for the compute hot spots the paper caches
(attention / FFN dominate DiT compute — Fig. 5) plus the Mamba-2 SSD scan.

Each kernel ships with ops.py (jit'd wrapper, interpret-mode fallback off
TPU) and ref.py (pure-jnp oracles used by the allclose test sweeps).
"""
from repro.kernels import flash_attention, ops, ref, ssd  # noqa: F401
