"""Serializable calibration artifacts.

A :class:`CacheArtifact` bundles everything needed to *reproduce* a caching
schedule without re-running calibration: the per-type mean error curves, the
resolved schedule, and provenance (architecture, solver, step count, policy
hyperparameters).  Serving loads the artifact and goes straight to compiled
sampling; curves are stored at full float64 precision (Python ``repr`` floats
are shortest-roundtrip) so a reload rebuilds the *bit-identical* schedule —
verified by ``tests/test_cache_api.py``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.cache import registry
from repro.cache.policy import CachePolicy
from repro.core import plan as plan_lib
from repro.core.schedule import Schedule
from repro.resilience.integrity import (CHECKSUM_KEY, payload_checksum,
                                        verify_payload)

# v2: adds the optional ``adaptive`` payload (tau + fitted proxy→error map
# + candidate pool provenance); v3: embeds a content checksum (verified on
# load — on-disk corruption fails loudly instead of serving a mangled
# schedule) and encodes ±Inf curve values explicitly ("Infinity" /
# "-Infinity" strings; NaN stays null).  v1/v2 artifacts load unchanged.
FORMAT_VERSION = 3

_UNSET = object()


@dataclass
class CacheArtifact:
    """Calibration curves + resolved schedule + provenance."""
    arch: str                                 # ModelConfig.name
    solver: str                               # Solver.name
    num_steps: int
    policy: Dict                              # CachePolicy.to_config()
    curves: Dict[str, np.ndarray]             # {type: (S, K+1) float64}
    schedule: Optional[Schedule] = None       # resolved skip masks
    plan: Optional[Dict] = None               # ExecutionPlan.to_jsonable()
    adaptive: Optional[Dict] = None           # tau, proxy_map, pool, k_max
    meta: Dict = field(default_factory=dict)  # calib_batch, k_max, cfg_scale…

    # -- resolution ----------------------------------------------------------

    def resolve(self, policy: Optional[CachePolicy] = None) -> Schedule:
        """Rebuild the schedule from the stored curves — with the stored
        policy by default, or any other policy against the same curves."""
        p = registry.get(policy) if policy is not None \
            else registry.from_config(self.policy)
        types = sorted(self.curves) if self.curves else \
            list(self.schedule.skip) if self.schedule else []
        return p.build(types, self.num_steps,
                       self.curves if self.curves else None)

    def execution_plan(self) -> Optional[plan_lib.ExecutionPlan]:
        """The pre-analyzed segmentation/liveness plan, when stored — a
        serving process hands it straight to the executor instead of
        re-deriving it.  Validated against the stored schedule; a stale
        plan (fingerprint mismatch) is discarded and re-analyzed."""
        if self.plan is not None:
            p = plan_lib.ExecutionPlan.from_jsonable(self.plan)
            if (self.schedule is None
                    or p.schedule_fingerprint
                    == plan_lib.schedule_fingerprint(self.schedule)):
                return p
        if self.schedule is not None:
            return plan_lib.analyze(self.schedule)
        return None

    # -- validation ----------------------------------------------------------

    def validate_for(self, *, arch: Optional[str] = None,
                     solver: Optional[str] = None,
                     num_steps: Optional[int] = None,
                     cfg_scale=_UNSET, policy=None) -> None:
        """Strict serving-side compatibility check: raise ``ValueError``
        when this artifact cannot serve the given deployment (wrong
        architecture, solver/step count, guidance strength, or — for
        adaptive artifacts — mismatched runtime decision parameters).

        This is the single validation seam shared by
        :meth:`DiffusionPipeline.load_artifact` and the serving
        :class:`~repro.serve.store.ArtifactStore` hot-reload path, so a
        live swap can never admit an artifact a fresh load would reject.
        Pass only the facts you want checked; ``cfg_scale`` is compared
        only when the artifact recorded one (legacy artifacts without the
        key are tolerated)."""
        # diverged calibration: an ±Inf mean-error entry means the curve
        # fit blew up — such a schedule must never serve (NaN entries are
        # legitimate: lag k > step s is structurally unmeasurable)
        for t, c in sorted(self.curves.items()):
            if np.isinf(np.asarray(c)).any():
                raise ValueError(
                    f"artifact curve for layer type {t!r} contains "
                    "non-finite (±Inf) mean-error values — the "
                    "calibration diverged; recalibrate before serving")
        if arch is not None and self.arch != arch:
            raise ValueError(f"artifact was calibrated on {self.arch!r}, "
                             f"pipeline runs {arch!r}")
        if ((solver is not None and self.solver != solver)
                or (num_steps is not None and self.num_steps != num_steps)):
            raise ValueError(
                f"artifact solver {self.solver}x{self.num_steps} != "
                f"pipeline {solver}x{num_steps}")
        # the curves depend on guidance strength; legacy artifacts
        # without the key are tolerated, a recorded mismatch is not
        if (cfg_scale is not _UNSET and "cfg_scale" in self.meta
                and self.meta["cfg_scale"] != cfg_scale):
            raise ValueError(
                f"artifact was calibrated at "
                f"cfg_scale={self.meta['cfg_scale']}, pipeline runs "
                f"cfg_scale={cfg_scale}")
        # adaptive provenance: the runtime rule must use the artifact's
        # decision parameters, not whatever the consumer was typo'd with
        if self.adaptive and policy is not None \
                and getattr(policy, "name", None) == "adaptive":
            for k, mine in (("tau", policy.tau), ("k_max", policy.k_max)):
                if k in self.adaptive and self.adaptive[k] != mine:
                    raise ValueError(
                        f"artifact's adaptive policy has {k}="
                        f"{self.adaptive[k]}, pipeline policy has "
                        f"{k}={mine}")
        # the stacked device representation (what the fused sampling
        # program evaluates) must agree with the fitted proxy map — a
        # mismatch means the payload was edited or mispaired
        if (self.adaptive and self.adaptive.get("proxy_map_stacked")
                and self.adaptive.get("proxy_map")):
            from repro.core import calibration as calibration_lib
            stk = self.adaptive["proxy_map_stacked"]
            pm = calibration_lib.ProxyMap.from_jsonable(
                self.adaptive["proxy_map"])
            try:
                a, b = pm.stacked(stk.get("types", []))
            except KeyError as e:
                raise ValueError(
                    f"artifact's stacked proxy-map types {stk.get('types')} "
                    f"are not covered by its fitted coefficients: {e}")
            if (not np.allclose(a, np.asarray(stk.get("a"), np.float32))
                    or not np.allclose(b, np.asarray(stk.get("b"),
                                                     np.float32))):
                raise ValueError(
                    "artifact's stacked proxy-map coefficients do not "
                    "match its fitted proxy_map — the adaptive payload "
                    "was edited or mispaired")
        # the stored pool must be the one this schedule derives —
        # a mismatch means the payload was edited or mispaired
        if (self.adaptive and "pool" in self.adaptive
                and self.schedule is not None):
            derived = [list(sig.live_in) for sig in
                       plan_lib.mask_lattice(self.schedule)]
            if self.adaptive["pool"] != derived:
                raise ValueError(
                    f"artifact's adaptive pool "
                    f"{self.adaptive['pool']} does not match the "
                    f"stored schedule's mask lattice {derived}")

    # -- (de)serialization ---------------------------------------------------

    def to_json(self) -> str:
        def enc(v):
            # NaN (lag k > step s entries) → null; ±Inf → explicit string
            # tags (strict JSON has no Infinity literal, and
            # ``allow_nan=False`` would otherwise die with an opaque
            # ValueError); finite floats round-trip exactly via
            # shortest-roundtrip repr
            if np.isnan(v):
                return None
            if np.isinf(v):
                return "Infinity" if v > 0 else "-Infinity"
            return v

        def rows(c):
            return [[enc(v) for v in row]
                    for row in np.asarray(c, np.float64).tolist()]
        payload = {
            "format_version": FORMAT_VERSION,
            "arch": self.arch,
            "solver": self.solver,
            "num_steps": self.num_steps,
            "policy": self.policy,
            "curves": {t: rows(c) for t, c in sorted(self.curves.items())},
            "schedule": (json.loads(self.schedule.to_json())
                         if self.schedule is not None else None),
            "plan": self.plan,
            "adaptive": self.adaptive,
            "meta": self.meta,
        }
        # content checksum over the canonical payload — from_json verifies
        # it, so every load/reload path detects on-disk corruption
        payload[CHECKSUM_KEY] = payload_checksum(payload)
        return json.dumps(payload, sort_keys=True, allow_nan=False)

    @staticmethod
    def from_json(s: str) -> "CacheArtifact":
        d = json.loads(s)
        ver = d.get("format_version", 0)
        if ver > FORMAT_VERSION:
            raise ValueError(f"artifact format v{ver} is newer than this "
                             f"code (v{FORMAT_VERSION})")
        # integrity first: a checksum-carrying payload that does not hash
        # to its own checksum is corrupt — refuse before interpreting any
        # field (pre-v3 payloads without a checksum pass through)
        verify_payload(d)
        sch = d.get("schedule")

        def val(v, t):
            if v is None:
                return np.nan
            if isinstance(v, str):
                if v == "Infinity":
                    return np.inf
                if v == "-Infinity":
                    return -np.inf
                raise ValueError(
                    f"artifact curve for layer type {t!r} contains "
                    f"unrecognized value {v!r} — expected a float, null "
                    "(NaN), or \"Infinity\"/\"-Infinity\"")
            return float(v)

        def arr(c, t):
            return np.asarray([[val(v, t) for v in row] for row in c],
                              np.float64)
        return CacheArtifact(
            arch=d["arch"], solver=d["solver"], num_steps=d["num_steps"],
            policy=d["policy"],
            curves={t: arr(c, t) for t, c in d.get("curves", {}).items()},
            schedule=(Schedule.from_json(json.dumps(sch))
                      if sch is not None else None),
            plan=d.get("plan"),
            adaptive=d.get("adaptive"),
            meta=d.get("meta", {}))

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @staticmethod
    def load(path: str) -> "CacheArtifact":
        with open(path) as f:
            return CacheArtifact.from_json(f.read())

    # -- convenience ---------------------------------------------------------

    def summary(self) -> str:
        p = registry.from_config(self.policy)
        rows = [f"CacheArtifact(arch={self.arch}, solver={self.solver}, "
                f"steps={self.num_steps}, policy={p.spec()})"]
        if self.schedule is not None:
            rows.append(self.schedule.summary())
        return "\n".join(rows)

    def at_tau(self, tau: float) -> "CacheArtifact":
        """Copy of an adaptive artifact re-targeted at another τ rung.

        Everything that costs compilation or calibration is *shared* —
        curves, schedule, plan, proxy→error map, candidate pool — and only
        the runtime threshold changes (in both the stored policy config
        and the adaptive payload, so ``validate_for`` stays consistent).
        This is the τ-ladder seam: the fused adaptive program takes τ as a
        traced scalar argument, so every rung built this way serves from
        the same compiled programs."""
        if not self.adaptive:
            raise ValueError("at_tau needs an artifact with an adaptive "
                             "payload (calibrated under an adaptive "
                             "policy)")
        tau = float(tau)
        if tau < 0:
            raise ValueError(f"tau must be >= 0, got {tau}")
        pol = dict(self.policy)
        if pol.get("name") not in ("adaptive", "teacache"):
            raise ValueError(
                f"at_tau needs an adaptive stored policy, artifact has "
                f"{pol.get('name')!r}")
        pol["tau"] = tau
        return dataclasses.replace(
            self, policy=pol, adaptive={**self.adaptive, "tau": tau})

    def with_schedule(self, schedule: Schedule) -> "CacheArtifact":
        return dataclasses.replace(
            self, schedule=schedule,
            plan=plan_lib.analyze(schedule).to_jsonable())
