"""repro.cache — first-class caching strategies for diffusion transformers.

Quickstart::

    from repro import cache
    from repro.core import solvers

    policy = cache.get("smoothcache:alpha=0.18")          # or cache.SmoothCache(0.18)
    pipe = cache.DiffusionPipeline(cfg, solvers.ddim(50), policy,
                                   cfg_scale=1.5)
    artifact = pipe.calibrate(params, key, batch=10,
                              cond_args={"label": labels})
    artifact.save("dit_xl_ddim50.cache.json")             # serving reloads this
    images = pipe.generate(params, key2, batch=32, label=labels)

See ``policy.py`` for the policy zoo and ``registry.py`` for the spec
grammar (flat ``name:k=v,...`` or nested ``per_type(attn=...,ffn=...)``).
"""
from repro.cache.artifact import CacheArtifact  # noqa: F401
from repro.cache.pipeline import DiffusionPipeline, Pipeline  # noqa: F401
from repro.cache.policy import (  # noqa: F401
    AdaptivePolicy, BudgetedSmoothCache, CachePolicy, NoCache, PerLayerType,
    SmoothCache, StaticInterval)
from repro.cache.registry import from_config, get, names, register  # noqa: F401
