"""First-class caching policies.

A :class:`CachePolicy` is a declarative description of *how to decide which
sampler steps recompute which layer types*.  Policies are pure objects: they
hold hyperparameters (α, interval, compute budget, per-type composition) and
turn calibration error curves into a static :class:`~repro.core.schedule.Schedule`
via :meth:`build`.  The stateful parts — running the calibration pass, caching
compiled variants — live in the executor / pipeline, so a policy can be
constructed from a string (``repro.cache.get("smoothcache:alpha=0.18")``),
serialized into a :class:`~repro.cache.artifact.CacheArtifact`, and shipped to
a serving fleet without ever touching model code.

Implemented policies
--------------------
``NoCache``               every step computes every layer (baseline).
``StaticInterval(n)``     FORA [arXiv:2407.01425]: compute every n-th step.
``SmoothCache(alpha)``    paper Eq. 4 greedy thresholding of error curves.
``BudgetedSmoothCache``   α searched so the schedule hits a target compute
                          fraction (paper §2.2 "brief linear search").
``PerLayerType``          different sub-policy per layer type — the
                          Δ-DiT [arXiv:2406.01125] / CorGi block-tailored
                          direction, expressed compositionally.
``AdaptivePolicy``        TeaCache-style input-adaptive runtime rule over a
                          static base policy: the base schedule defines the
                          precompiled candidate pool, a calibrated
                          proxy→error map + threshold τ decide per step and
                          per input what to reuse (τ=0 ⇒ the static
                          schedule, bit-identically).
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core import schedule as schedule_lib
from repro.core.schedule import Schedule


class CachePolicy(abc.ABC):
    """Protocol: ``prepare(executor, params, key) -> Schedule`` + metadata.

    Subclasses implement :meth:`build` (curves → schedule); :meth:`prepare`
    is the convenience driver that runs a calibration pass first when the
    policy needs one.
    """

    #: registry name; set by subclasses
    name: str = "policy"
    #: does :meth:`build` need calibration error curves?
    requires_calibration: bool = False
    #: calibration lag horizon this policy needs (max cache age it may use)
    k_max: int = 3

    @abc.abstractmethod
    def build(self, types: Sequence[str], num_steps: int,
              curves: Optional[Mapping[str, np.ndarray]] = None) -> Schedule:
        """Resolve the static schedule for the given layer types / step count.
        ``curves[t]`` is the (S, K+1) mean L1-relative error curve when the
        policy is calibration-based; calibration-free policies ignore it."""

    def to_config(self) -> Dict:
        """JSON-safe ``{"name": ..., **hyperparams}`` (round-trips through
        :func:`repro.cache.registry.from_config`)."""
        return {"name": self.name}

    def spec(self) -> str:
        """Canonical registry spec string for this policy."""
        cfg = self.to_config()
        args = ",".join(f"{k}={v}" for k, v in sorted(cfg.items())
                        if k != "name")
        return cfg["name"] + (f":{args}" if args else "")

    def prepare(self, executor, params=None, key=None, *,
                curves: Optional[Mapping[str, np.ndarray]] = None,
                calib_batch: int = 8, cond_args: Optional[Dict] = None
                ) -> Schedule:
        """Resolve a schedule for ``executor``; runs a calibration pass when
        the policy needs curves and none were supplied."""
        types = executor.cfg.layer_types()
        num_steps = executor.solver.num_steps
        if self.requires_calibration and curves is None:
            if params is None or key is None:
                raise ValueError(
                    f"policy {self.spec()!r} needs calibration curves; pass "
                    "curves= or (params, key) so prepare() can calibrate")
            from repro.core import calibration
            curves, _, _ = calibration.calibrate(
                executor, params, key, calib_batch,
                cond_args=cond_args, k_max=self.k_max)
        return self.build(types, num_steps, curves)

    def __repr__(self):
        return f"{type(self).__name__}({self.spec()!r})"

    def __eq__(self, other):
        return (type(self) is type(other)
                and self.to_config() == other.to_config())

    def __hash__(self):
        return hash((type(self).__name__, tuple(sorted(
            (k, str(v)) for k, v in self.to_config().items()))))


# ---------------------------------------------------------------------------
# Calibration-free policies
# ---------------------------------------------------------------------------

class NoCache(CachePolicy):
    """Baseline: compute everything at every step."""
    name = "none"
    k_max = 0

    def build(self, types, num_steps, curves=None) -> Schedule:
        return schedule_lib.no_cache(types, num_steps)


class StaticInterval(CachePolicy):
    """FORA-style static caching: compute every ``n``-th step, reuse in
    between, uniformly across all layer types."""
    name = "static"

    def __init__(self, n: int = 2):
        if n < 1:
            raise ValueError(f"StaticInterval needs n >= 1, got {n}")
        self.n = int(n)
        self.k_max = max(self.n - 1, 1)

    def build(self, types, num_steps, curves=None) -> Schedule:
        return schedule_lib.fora(types, num_steps, self.n)

    def to_config(self):
        return {"name": self.name, "n": self.n}


# ---------------------------------------------------------------------------
# Calibration-based policies
# ---------------------------------------------------------------------------

def _check_curves(curves, num_steps: int, k_max: int, name: str):
    """Reject curves that would silently produce a different schedule than
    the policy asks for: wrong step count, or a lag horizon smaller than
    the policy's k_max (smoothcache() would quietly clamp it)."""
    for t, err in curves.items():
        if err.shape[0] != num_steps:
            raise ValueError(
                f"{name}: calibration curves for {t!r} cover {err.shape[0]} "
                f"steps but the solver runs {num_steps}; recalibrate with "
                "this solver")
        if err.shape[1] - 1 < k_max:
            raise ValueError(
                f"{name}: curves for {t!r} were calibrated with "
                f"k_max={err.shape[1] - 1} < policy k_max={k_max}; "
                "recalibrate with the larger horizon")

class SmoothCache(CachePolicy):
    """Paper Eq. 4: greedy α-thresholding of the calibration error curves."""
    name = "smoothcache"
    requires_calibration = True

    def __init__(self, alpha: float = 0.18, k_max: int = 3):
        self.alpha = float(alpha)
        self.k_max = int(k_max)

    def build(self, types, num_steps, curves=None) -> Schedule:
        if curves is None:
            raise ValueError("SmoothCache.build needs calibration curves")
        _check_curves(curves, num_steps, self.k_max, self.name)
        return schedule_lib.smoothcache(curves, self.alpha, self.k_max)

    def to_config(self):
        return {"name": self.name, "alpha": self.alpha, "k_max": self.k_max}


class BudgetedSmoothCache(CachePolicy):
    """SmoothCache with α chosen by bisection so the schedule computes
    ~``target`` of all layer evaluations (declarative compute budgets —
    'give me the best schedule at 50% compute')."""
    name = "budget"
    requires_calibration = True

    def __init__(self, target: float = 0.5, k_max: int = 3):
        if not 0.0 < target <= 1.0:
            raise ValueError(f"target compute fraction must be in (0, 1], "
                             f"got {target}")
        self.target = float(target)
        self.k_max = int(k_max)

    def build(self, types, num_steps, curves=None) -> Schedule:
        if curves is None:
            raise ValueError("BudgetedSmoothCache.build needs calibration "
                             "curves")
        _check_curves(curves, num_steps, self.k_max, self.name)
        alpha = schedule_lib.alpha_for_budget(curves, self.target, self.k_max)
        sch = schedule_lib.smoothcache(curves, alpha, self.k_max)
        return dataclasses.replace(sch, name=f"budget_{self.target:g}")

    def to_config(self):
        return {"name": self.name, "target": self.target, "k_max": self.k_max}


# ---------------------------------------------------------------------------
# Input-adaptive runtime policy
# ---------------------------------------------------------------------------

class AdaptivePolicy(CachePolicy):
    """Input-adaptive runtime caching over a static ``base`` policy.

    The base policy's schedule is resolved offline as usual; it defines the
    *candidate signature pool* (the mask lattice over its ever-skipped
    types — see :func:`repro.core.plan.mask_lattice`) and the static
    fallback.  At runtime the executor's ``sample_adaptive`` path maps a
    cheap per-step proxy signal (relative L1 change of the latent) through
    a calibrated proxy→error map and reuses each layer type while the
    error accumulated since its last compute stays below ``tau``,
    dispatching among the pool's precompiled programs — so per-input
    schedules never trigger per-step compilation.

    ``tau=0`` disables the runtime rule and reproduces the base schedule
    bit-identically; larger ``tau`` grants each cache run a larger
    estimated-error budget (more reuse on easy inputs, earlier recompute
    on hard ones).  Calibration-free bases (e.g. ``static``) still require
    calibration: the proxy→error map is fitted from the same pass.
    """
    name = "adaptive"
    requires_calibration = True

    def __init__(self, base: Union[str, Dict, CachePolicy] = "smoothcache",
                 tau: float = 0.05, k_max: Optional[int] = None):
        from repro.cache import registry   # late: registry imports policy
        self.base = registry.get(base)
        if isinstance(self.base, AdaptivePolicy):
            raise ValueError("adaptive policies do not nest")
        if tau < 0:
            raise ValueError(f"tau must be >= 0, got {tau}")
        self.tau = float(tau)
        self._k_max_override = None if k_max is None else int(k_max)
        self.k_max = (self.base.k_max if k_max is None else int(k_max))
        if self.k_max < 1:
            raise ValueError(
                f"adaptive k_max must be >= 1, got {self.k_max}"
                + ("" if k_max is not None else
                   f" from base {self.base.spec()!r}")
                + " — k_max=0 would compile the whole candidate pool yet "
                "never reuse a cache entry (silently behaving like "
                "no_cache), and negative values are nonsense")

    def build(self, types, num_steps, curves=None) -> Schedule:
        """The *static* base schedule — the adaptive runtime's fallback and
        the source of its candidate pool."""
        return self.base.build(
            types, num_steps,
            curves if self.base.requires_calibration else None)

    def to_config(self):
        cfg = {"name": self.name, "base": self.base.to_config(),
               "tau": self.tau}
        if self._k_max_override is not None:
            cfg["k_max"] = self._k_max_override
        return cfg

    def spec(self) -> str:
        s = self.base.spec()
        base = s.replace(":", "(", 1) + ")" if ":" in s else s
        spec = f"adaptive:base={base},tau={self.tau:g}"
        if self._k_max_override is not None:
            spec += f",k_max={self._k_max_override}"
        return spec


# ---------------------------------------------------------------------------
# Composition
# ---------------------------------------------------------------------------

class PerLayerType(CachePolicy):
    """Block-tailored composite: a different sub-policy per layer type
    (e.g. aggressive caching for ``mlp``, conservative for ``attn`` — the
    Δ-DiT / CorGi observation that blocks tolerate very different reuse).

    ``policies`` maps layer-type name → sub-policy; types not listed fall
    back to ``default`` (NoCache unless overridden).
    """
    name = "per_type"

    def __init__(self, policies: Mapping[str, CachePolicy],
                 default: Optional[CachePolicy] = None):
        self.policies = dict(policies)
        self.default = default if default is not None else NoCache()
        subs = list(self.policies.values()) + [self.default]
        self.requires_calibration = any(p.requires_calibration for p in subs)
        self.k_max = max(p.k_max for p in subs)

    def build(self, types, num_steps, curves=None) -> Schedule:
        skip: Dict[str, np.ndarray] = {}
        for t in types:
            p = self.policies.get(t, self.default)
            sub_curves = None
            if p.requires_calibration:
                if curves is None or t not in curves:
                    raise ValueError(
                        f"per-type sub-policy {p.spec()!r} for layer type "
                        f"{t!r} needs calibration curves for that type")
                sub_curves = {t: curves[t]}
            sub = p.build([t], num_steps, sub_curves)
            if sub.num_steps != num_steps or len(sub.skip[t]) != num_steps:
                raise ValueError(
                    f"per-type sub-policy {p.spec()!r} for {t!r} produced a "
                    f"{sub.num_steps}-step schedule; expected {num_steps}")
            skip[t] = np.asarray(sub.skip[t], bool)
        return Schedule(skip, num_steps, name=self.spec())

    def to_config(self):
        return {"name": self.name,
                "policies": {t: p.to_config()
                             for t, p in sorted(self.policies.items())},
                "default": self.default.to_config()}

    def spec(self) -> str:
        def paren(p: CachePolicy) -> str:
            # nested specs use the parenthesized form: name(k=v,...)
            s = p.spec()
            return s.replace(":", "(", 1) + ")" if ":" in s else s
        inner = ",".join(f"{t}={paren(p)}"
                         for t, p in sorted(self.policies.items()))
        if not isinstance(self.default, NoCache):
            inner += ("," if inner else "") + f"default={paren(self.default)}"
        return f"per_type({inner})"
