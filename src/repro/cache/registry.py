"""String-spec registry for cache policies.

``get("smoothcache:alpha=0.18")`` turns a declarative spec into a
:class:`~repro.cache.policy.CachePolicy`.  Two equivalent grammars:

* flat:    ``name`` or ``name:k=v,k=v``      (CLI-friendly)
* nested:  ``name(k=v,k=v)`` where a value may itself be a spec —
           ``per_type(attn=smoothcache(alpha=0.1),ffn=static(n=2))``

``register`` adds new policies (future PRs: TeaCache-style dynamic
policies, learned schedules, ...) without touching any callsite.
"""
from __future__ import annotations

from typing import Callable, Dict, Union

from repro.cache import policy as P

_REGISTRY: Dict[str, Callable[..., P.CachePolicy]] = {}


def register(name: str, *aliases: str):
    """Decorator registering a policy factory under ``name`` (+ aliases)."""
    def deco(factory):
        for n in (name,) + aliases:
            key = n.lower()
            if key in _REGISTRY:
                raise ValueError(f"cache policy {key!r} already registered")
            _REGISTRY[key] = factory
        return factory
    return deco


def names():
    return sorted(_REGISTRY)


# -- built-ins ---------------------------------------------------------------

register("none", "no_cache", "nocache")(P.NoCache)
register("static", "static_interval", "fora")(P.StaticInterval)
register("smoothcache", "smooth_cache")(P.SmoothCache)
register("budget", "budgeted", "budgeted_smoothcache")(P.BudgetedSmoothCache)


@register("per_type", "per-type", "composite")
def _per_type(default=None, **policies) -> P.PerLayerType:
    coerce = lambda v: get(v) if isinstance(v, (str, dict)) else v
    return P.PerLayerType({t: coerce(p) for t, p in policies.items()},
                          default=coerce(default) if default is not None
                          else None)


@register("adaptive", "teacache")
def _adaptive(base="smoothcache", tau=0.05, k_max=None) -> P.AdaptivePolicy:
    # base may be a nested spec string, a to_config() dict, or a policy;
    # k_max (cache-age cap, default: the base's) is validated >= 1 in
    # AdaptivePolicy — "adaptive:...,k_max=0" must fail loudly, not
    # compile the whole pool and silently never reuse
    if isinstance(tau, (list, tuple)):
        raise ValueError(
            f"tau={list(tau)} is a τ-ladder spec — one policy per rung, "
            "not a single policy; expand it with "
            "registry.expand_ladder(spec) or register it via "
            "ArtifactStore.add_ladder()")
    return P.AdaptivePolicy(base=base, tau=tau, k_max=k_max)


# -- spec parsing ------------------------------------------------------------

def _split_top(s: str, sep: str = ","):
    """Split on ``sep`` at paren/bracket depth 0 (brackets delimit list
    values — the τ-ladder grammar's ``tau=[0.0,0.05,0.2]``)."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([":
            depth += 1
        elif ch in ")]":
            depth -= 1
            if depth < 0:
                raise ValueError(f"unbalanced {ch!r} in spec {s!r}")
        if ch == sep and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if depth != 0:
        raise ValueError(f"unbalanced '(' or '[' in spec {s!r}")
    if cur or out:
        out.append("".join(cur))
    return [p.strip() for p in out if p.strip()]


def _coerce(v: str):
    """Typed coercion: list > nested spec > bool > int > float > str."""
    if v.startswith("[") and v.endswith("]"):
        inner = v[1:-1].strip()
        return [_coerce(p) for p in _split_top(inner)] if inner else []
    if "(" in v or v.lower() in _REGISTRY:
        return get(v)
    low = v.lower()
    if low in ("true", "false"):
        return low == "true"
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    return v


def parse(spec: str):
    """``spec`` → (name, kwargs)."""
    spec = spec.strip()
    # a spec is parenthesized only when "(" opens the *top-level* arg list,
    # i.e. precedes any ":" — a flat spec may carry parenthesized nested
    # values ("per_type:attn=smoothcache(alpha=0.1)") whose "(" belongs to
    # the value, not the grammar
    i_par, i_col = spec.find("("), spec.find(":")
    if i_par != -1 and (i_col == -1 or i_par < i_col):
        if not spec.endswith(")"):
            raise ValueError(f"malformed policy spec {spec!r}")
        name, inner = spec.split("(", 1)
        args = _split_top(inner[:-1])
    elif ":" in spec:
        name, argstr = spec.split(":", 1)
        args = _split_top(argstr)
    else:
        name, args = spec, []
    kwargs = {}
    for a in args:
        if "=" not in a:
            raise ValueError(f"policy arg {a!r} in {spec!r} is not k=v")
        k, v = a.split("=", 1)
        kwargs[k.strip()] = _coerce(v.strip())
    return name.strip().lower(), kwargs


def get(spec: Union[str, dict, P.CachePolicy]) -> P.CachePolicy:
    """Resolve a policy from a spec string, a ``to_config()`` dict, or pass
    an already-constructed policy through unchanged."""
    if isinstance(spec, P.CachePolicy):
        return spec
    if isinstance(spec, dict):
        return from_config(spec)
    name, kwargs = parse(spec)
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown cache policy {name!r}; registered: {names()}")
    return _REGISTRY[name](**kwargs)


def expand_ladder(spec: str):
    """Expand a τ-ladder spec into one adaptive policy per rung.

    ``"adaptive:base=smoothcache(alpha=0.18),tau=[0.0,0.05,0.2]"`` →
    three :class:`~repro.cache.policy.AdaptivePolicy` instances sharing
    base (and ``k_max``), with strictly ascending τ values.  The rungs of
    a ladder serve the *same* artifact — same schedule, proxy map, and
    candidate pool (``ArtifactStore.add_ladder`` validates that) — so the
    τ values are the only thing this grammar varies."""
    name, kwargs = parse(spec)
    if name not in ("adaptive", "teacache"):
        raise ValueError(
            f"a τ ladder is rungs of one adaptive policy; got {name!r} "
            f"in {spec!r}")
    taus = kwargs.pop("tau", None)
    if not isinstance(taus, (list, tuple)) or not taus:
        raise ValueError(
            f"τ-ladder spec needs tau=[v0,v1,...] with at least one "
            f"rung, got tau={taus!r} in {spec!r}")
    taus = [float(t) for t in taus]
    if sorted(taus) != taus or len(set(taus)) != len(taus):
        raise ValueError(
            f"ladder taus must be strictly ascending, got {taus}")
    return [_REGISTRY[name](tau=t, **kwargs) for t in taus]


def from_config(cfg: dict) -> P.CachePolicy:
    """Inverse of ``CachePolicy.to_config()`` (used by CacheArtifact)."""
    cfg = dict(cfg)
    name = cfg.pop("name").lower()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown cache policy {name!r}; registered: {names()}")
    if name in ("per_type", "per-type", "composite"):
        subs = {t: from_config(c) for t, c in cfg.pop("policies", {}).items()}
        default = cfg.pop("default", None)
        return P.PerLayerType(
            subs, default=from_config(default) if default else None)
    return _REGISTRY[name](**cfg)
