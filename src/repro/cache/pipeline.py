"""`DiffusionPipeline` — the one-object facade over calibrate → schedule →
execute.

Callsites used to hand-wire the SmoothCache loop::

    ex = SmoothCacheExecutor(cfg, solver, cfg_scale=1.5)
    curves, _, _ = calibration.calibrate(ex, params, key, 8, cond_args=...)
    sch = schedule.smoothcache(curves, 0.18, k_max=3)
    x = ex.sample_compiled(params, key2, batch, schedule=sch, label=...)

With the facade the same flow is::

    pipe = DiffusionPipeline(cfg, solver, policy="smoothcache:alpha=0.18",
                             cfg_scale=1.5)
    pipe.calibrate(params, key, batch=8, cond_args=...)   # → CacheArtifact
    x = pipe.generate(params, key2, batch, label=...)

and the calibration result is a serializable :class:`CacheArtifact`, so a
serving process does ``pipe.load_artifact(path)`` and never recalibrates.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Union

import numpy as np

from repro.cache import registry
from repro.cache.artifact import CacheArtifact
from repro.cache.policy import AdaptivePolicy, CachePolicy
from repro.core import calibration as calibration_lib
from repro.core import plan as plan_lib
from repro.core import solvers as solvers_lib
from repro.core.executor import SmoothCacheExecutor
from repro.core.schedule import Schedule

_UNSET = object()


class DiffusionPipeline:
    """Owns an executor + a :class:`CachePolicy` + (optionally) a resolved
    :class:`CacheArtifact`, and exposes calibrate/generate."""

    def __init__(self, cfg, solver, policy: Union[str, dict, CachePolicy]
                 = "none", *, cfg_scale: Optional[float] = None,
                 use_flash: bool = False, jit: bool = True):
        if isinstance(solver, str):
            raise TypeError(
                f"solver must be a Solver object, e.g. "
                f"solvers.{solver}(num_steps); got the string {solver!r}")
        self.policy = registry.get(policy)
        self.executor = SmoothCacheExecutor(
            cfg, solver, cfg_scale=cfg_scale, use_flash=use_flash, jit=jit)
        self.artifact: Optional[CacheArtifact] = None
        self.per_sample: Optional[Dict[str, np.ndarray]] = None
        self._schedule: Optional[Schedule] = None
        self._plan: Optional[plan_lib.ExecutionPlan] = None
        self._proxy_map: Optional[calibration_lib.ProxyMap] = None

    # -- introspection -------------------------------------------------------

    @property
    def cfg(self):
        return self.executor.cfg

    @property
    def solver(self) -> solvers_lib.Solver:
        return self.executor.solver

    @property
    def schedule(self) -> Optional[Schedule]:
        """The resolved schedule, if calibration/preparation has run."""
        return self._schedule

    @property
    def plan(self) -> Optional[plan_lib.ExecutionPlan]:
        """Segmentation/liveness analysis of the resolved schedule (loaded
        from the artifact when serving, derived once otherwise)."""
        if self._plan is None and self._schedule is not None:
            self._plan = self.executor.plan_for(self._schedule)
        return self._plan

    @property
    def proxy_map(self) -> Optional[calibration_lib.ProxyMap]:
        """Fitted proxy→error map (adaptive policies): set by
        ``calibrate()`` or reloaded by ``load_artifact()``."""
        return self._proxy_map

    def summary(self) -> str:
        head = (f"DiffusionPipeline({self.cfg.name}, {self.solver.name}"
                f"x{self.solver.num_steps}, policy={self.policy.spec()})")
        if self._schedule is not None:
            return head + "\n" + self._schedule.summary()
        return head

    # -- calibration ---------------------------------------------------------

    def calibrate(self, params, key, batch: int = 8, *,
                  cond_args: Optional[Dict] = None,
                  k_max: Optional[int] = None) -> CacheArtifact:
        """Run one uncached calibration pass (paper uses 10 samples), resolve
        the policy's schedule, and return a serializable artifact.  Also
        stores per-sample curves on ``self.per_sample`` for CI analysis."""
        k = k_max if k_max is not None else max(self.policy.k_max, 1)
        rec = calibration_lib.calibrate_record(
            self.executor, params, key, batch, cond_args=cond_args, k_max=k)
        curves = rec.curves
        self.per_sample = rec.per_sample
        sch = self.policy.build(self.cfg.layer_types(),
                                self.solver.num_steps,
                                curves if self.policy.requires_calibration
                                else None)
        self._plan = self.executor.plan_for(sch)
        adaptive = None
        if isinstance(self.policy, AdaptivePolicy):
            self._proxy_map = rec.proxy_map
            pool = plan_lib.mask_lattice(sch)
            # the device representation the fused program evaluates:
            # per-type (a, b) stacked float32 in pool-type order — shipped
            # explicitly so a serving process can audit/consume the exact
            # coefficients the runtime rule will see
            pool_types = sorted({t for sig in pool for t in sig.live_in})
            coeff_a, coeff_b = rec.proxy_map.stacked(pool_types)
            adaptive = {
                "tau": self.policy.tau,
                "k_max": self.policy.k_max,
                "proxy_map": rec.proxy_map.to_jsonable(),
                "proxy_map_stacked": {
                    "types": pool_types,
                    "a": [float(v) for v in coeff_a],
                    "b": [float(v) for v in coeff_b],
                },
                "pool": [list(sig.live_in) for sig in pool],
            }
        self.artifact = CacheArtifact(
            arch=self.cfg.name, solver=self.solver.name,
            num_steps=self.solver.num_steps,
            policy=self.policy.to_config(), curves=curves, schedule=sch,
            plan=self._plan.to_jsonable(), adaptive=adaptive,
            meta={"calib_batch": batch, "k_max": k,
                  "cfg_scale": self.executor.cfg_scale,
                  # under CFG only the conditioned half of the doubled
                  # [cond; uncond] batch enters the curves
                  "calib_cfg_half": "cond" if rec.cfg_halved else None})
        self._schedule = sch
        return self.artifact

    def prepare(self, params=None, key=None, *, calib_batch: int = 8,
                cond_args: Optional[Dict] = None) -> Schedule:
        """Resolve the schedule without building an artifact — calibrates
        only if the policy needs curves and no artifact is loaded."""
        if self._schedule is not None:
            return self._schedule
        if self.policy.requires_calibration and self.artifact is None:
            if params is None or key is None:
                raise ValueError(
                    f"policy {self.policy.spec()!r} needs calibration; pass "
                    "(params, key) to prepare() or load_artifact() first")
            self.calibrate(params, key, calib_batch, cond_args=cond_args)
            return self._schedule
        curves = self.artifact.curves if self.artifact is not None else None
        self._schedule = self.policy.prepare(self.executor, curves=curves)
        self._plan = None                     # re-derived lazily via .plan
        return self._schedule

    def schedule_for(self, policy: Union[str, dict, CachePolicy]) -> Schedule:
        """Resolve *another* policy against this pipeline's calibration
        curves (benchmark sweeps: many α / budgets, one calibration)."""
        p = registry.get(policy)
        curves = self.artifact.curves if self.artifact is not None else None
        return p.prepare(self.executor, curves=curves)

    # -- artifact round-trip -------------------------------------------------

    def save_artifact(self, path: str) -> str:
        if self.artifact is None:
            raise ValueError("no artifact: run calibrate() first")
        return self.artifact.save(path)

    def load_artifact(self, path_or_artifact: Union[str, CacheArtifact],
                      *, strict: bool = True) -> CacheArtifact:
        """Adopt a saved artifact: serving skips calibration entirely.  The
        stored schedule is used verbatim when present; otherwise it is
        re-resolved from the stored curves with this pipeline's policy."""
        art = (path_or_artifact if isinstance(path_or_artifact, CacheArtifact)
               else CacheArtifact.load(path_or_artifact))
        if strict:
            # single validation seam shared with repro.serve.ArtifactStore
            art.validate_for(
                arch=self.cfg.name, solver=self.solver.name,
                num_steps=self.solver.num_steps,
                cfg_scale=self.executor.cfg_scale,
                policy=self.policy if isinstance(self.policy, AdaptivePolicy)
                else None)
        self.artifact = art
        if art.adaptive and art.adaptive.get("proxy_map"):
            self._proxy_map = calibration_lib.ProxyMap.from_jsonable(
                art.adaptive["proxy_map"])
        self._schedule = (art.schedule if art.schedule is not None
                          else art.resolve(self.policy))
        # serving reloads the pre-analyzed plan instead of re-deriving it
        self._plan = (art.execution_plan() if art.schedule is not None
                      else plan_lib.analyze(self._schedule))
        return art

    # -- generation ----------------------------------------------------------

    def generate(self, params, key, batch: int, *, label=None, memory=None,
                 schedule=_UNSET, compiled: bool = True,
                 return_decisions: bool = False):
        """Sample a batch under the pipeline's schedule.  ``schedule=`` (a
        Schedule, a policy spec, or None for the uncached baseline)
        overrides per-call; ``compiled=True`` uses the segmented-plan
        executor path (one compiled program per unique mask/liveness
        signature, reusing the pipeline's pre-analyzed plan).

        Adaptive policies route transparently to the executor's fused
        adaptive path when the solver is scannable (``sample_adaptive_fused``:
        the whole decision+dispatch loop in one donated device program,
        zero per-step host syncs), falling back to the host-dispatched
        ``sample_adaptive`` loop otherwise — both produce identical
        decision sequences; pass ``return_decisions=True`` to also get
        the realized per-step skip sets.  An explicit ``schedule=``
        override, or ``compiled=False``, falls back to the static paths."""
        if schedule is _UNSET:
            sch = self._schedule
            if sch is None and self.policy.requires_calibration:
                raise ValueError(
                    f"policy {self.policy.spec()!r} needs calibration — run "
                    "calibrate()/load_artifact() before generate()")
            if sch is None:
                sch = self.policy.build(self.cfg.layer_types(),
                                        self.solver.num_steps)
                self._schedule = sch
            if isinstance(self.policy, AdaptivePolicy) and compiled:
                if self.policy.tau > 0 and self._proxy_map is None:
                    raise ValueError(
                        f"policy {self.policy.spec()!r} needs a calibrated "
                        "proxy map — run calibrate()/load_artifact() before "
                        "generate()")
                sampler = (self.executor.sample_adaptive_fused
                           if self.executor.supports_fused_adaptive
                           else self.executor.sample_adaptive)
                return sampler(
                    params, key, batch, schedule=sch, tau=self.policy.tau,
                    proxy_map=self._proxy_map, k_max=self.policy.k_max,
                    label=label, memory=memory,
                    return_decisions=return_decisions)
        elif schedule is None or isinstance(schedule, Schedule):
            sch = schedule
        else:
            sch = self.schedule_for(schedule)
        if return_decisions:
            raise ValueError("return_decisions is only meaningful on the "
                             "adaptive path (no schedule= override, "
                             "compiled=True)")
        if compiled:
            # route through the lazy property: after prepare() reset
            # _plan, and when serving from an artifact, this is what
            # hands the pre-analyzed plan to the executor instead of
            # silently re-deriving it
            plan = self.plan if (sch is not None
                                 and sch is self._schedule) else None
            return self.executor.sample_compiled(
                params, key, batch, schedule=sch, label=label, memory=memory,
                plan=plan)
        return self.executor.sample(params, key, batch, schedule=sch,
                                    label=label, memory=memory)

    def compute_fraction(self) -> float:
        """Mean fraction of layer evaluations actually computed."""
        if self._schedule is None:
            return 1.0
        return float(np.mean([self._schedule.compute_fraction(t)
                              for t in self._schedule.skip]))


#: short alias used in docs/examples
Pipeline = DiffusionPipeline
