"""Configuration schema for the repro framework.

Every architecture (the paper's own DiT families and the 10 assigned
backbones) is described by a `ModelConfig` built from small frozen spec
dataclasses.  The stack is a sequence of *stages*; each stage is a repeated
*unit* of block specs.  Repetition maps onto `jax.lax.scan` with stacked
params, which keeps the lowered HLO compact enough that 512-device GSPMD
compiles finish on a single host core.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple, Union


# ---------------------------------------------------------------------------
# Mixer specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttentionSpec:
    """Multi-head attention: GQA/MQA/MHA or MLA (DeepSeek-style latent KV)."""
    kind: str = "gqa"                    # "gqa" | "mla"
    num_heads: int = 8
    num_kv_heads: int = 8                # ignored for MLA
    head_dim: int = 64
    window: Optional[int] = None         # sliding-window size; None = full
    causal: bool = True
    cross: bool = False                  # cross-attention (memory from cond)
    qk_norm: bool = False                # per-head RMSNorm on q,k (qwen3)
    qkv_bias: bool = False               # qwen2.5
    logit_softcap: Optional[float] = None  # gemma2: 50.0
    pos_emb: str = "rope"                # "rope" | "none"
    rope_theta: float = 10000.0
    # factorized video attention (OpenSora STDiT-style): None|"spatial"|"temporal"
    pattern: Optional[str] = None
    # --- MLA only ---
    q_lora_rank: Optional[int] = None    # None: full-rank q projection
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    @property
    def q_dim(self) -> int:
        if self.kind == "mla":
            return self.num_heads * (self.nope_head_dim + self.rope_head_dim)
        return self.num_heads * self.head_dim

    @property
    def o_in_dim(self) -> int:
        if self.kind == "mla":
            return self.num_heads * self.v_head_dim
        return self.num_heads * self.head_dim


@dataclass(frozen=True)
class SSMSpec:
    """Mamba-2 SSD mixer [arXiv:2405.21060]."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128                     # SSD chunk length
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclass(frozen=True)
class RGLRUSpec:
    """RG-LRU recurrent mixer from Griffin/RecurrentGemma [arXiv:2402.19427]."""
    num_heads: int = 8                   # block-diagonal gate projections
    conv_width: int = 4
    expand: int = 1                      # lru width = expand * d_model (RG uses 1x on 2b? actually 2560->lru 2560)
    c_constant: float = 8.0


# ---------------------------------------------------------------------------
# Feed-forward specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MLPSpec:
    d_ff: int = 2048
    activation: str = "silu"             # "silu" | "gelu" | "gelu_tanh"
    gated: bool = True                   # GLU variant (SwiGLU/GeGLU)


@dataclass(frozen=True)
class MoESpec:
    """Routed mixture-of-experts FFN with optional shared experts."""
    num_experts: int = 8
    top_k: int = 2
    d_ff: int = 2048                     # per routed expert
    num_shared: int = 0
    d_ff_shared: int = 0
    activation: str = "silu"
    gated: bool = True
    router: str = "softmax"              # "softmax" | "sigmoid" (dsv3)
    router_scale: float = 1.0            # dsv3 routed_scaling_factor 2.5
    aux_loss_weight: float = 0.0
    norm_topk: bool = True               # renormalize top-k weights
    capacity_factor: float = 0.0         # 0 => dense dispatch (einsum over experts)


FFNSpec = Union[MLPSpec, MoESpec]
MixerSpec = Union[AttentionSpec, SSMSpec, RGLRUSpec]


# ---------------------------------------------------------------------------
# Block / stage / model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BlockSpec:
    """One residual block: (norm → mixer → +res) [→ (norm → xattn → +res)]
    [→ (norm → ffn → +res)].

    `mixer=None` is allowed (FFN-only block).  `ffn=None` is used for Mamba-2
    blocks, which fold the FFN into the mixer.
    """
    mixer: Optional[MixerSpec] = None
    cross: Optional[AttentionSpec] = None
    ffn: Optional[FFNSpec] = None
    norm: str = "rmsnorm"                # "rmsnorm" | "layernorm"
    post_norm: bool = False              # gemma2: extra norm after branch
    adaln: bool = False                  # DiT-style adaLN-zero conditioning
    type_tag: str = ""                   # SmoothCache type prefix ("s_"/"t_")

    def branch_names(self) -> Tuple[str, ...]:
        out = []
        if self.mixer is not None:
            out.append("mixer")
        if self.cross is not None:
            out.append("cross")
        if self.ffn is not None:
            out.append("ffn")
        return tuple(out)

    def branch_types(self) -> Tuple[str, ...]:
        """SmoothCache layer *types* for each branch (paper's set S)."""
        out = []
        if self.mixer is not None:
            if isinstance(self.mixer, AttentionSpec):
                out.append(self.type_tag + "attn")
            elif isinstance(self.mixer, SSMSpec):
                out.append(self.type_tag + "ssm")
            else:
                out.append(self.type_tag + "rglru")
        if self.cross is not None:
            out.append(self.type_tag + "xattn")
        if self.ffn is not None:
            out.append(self.type_tag + "ffn")
        return tuple(out)


@dataclass(frozen=True)
class Stage:
    """`repeat` copies of `unit` (a tuple of BlockSpecs), scanned when >1."""
    unit: Tuple[BlockSpec, ...]
    repeat: int = 1

    @property
    def num_layers(self) -> int:
        return len(self.unit) * self.repeat


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab_size: int
    stages: Tuple[Stage, ...] = ()
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    pos_emb: str = "none"                # additive absolute pos emb: "none"|"sinusoidal"
    max_seq_len: int = 8192
    logit_softcap: Optional[float] = None   # gemma2 final softcap 30.0
    embed_scale: bool = False            # gemma: scale embeddings by sqrt(d)
    # multi-codebook token IO (musicgen): K codebooks share the embedding sum
    num_codebooks: int = 1
    # prepended continuous embeddings (VLM patches / audio frames); 0 = none
    num_prefix_embeds: int = 0
    # DeepSeek-style multi-token prediction depth (extra training head)
    mtp_depth: int = 0
    # diffusion-task configs: latent input instead of tokens
    task: str = "lm"                     # "lm" | "diffusion"
    latent_shape: Tuple[int, ...] = ()   # diffusion: per-sample latent shape
    patch: int = 1                       # diffusion image patch size
    cond_dim: int = 0                    # cross-attention memory width
    num_classes: int = 0                 # label conditioning (DiT-XL)
    # long-context policy for long_500k: "native" (ssm/hybrid) | "swa" | None
    long_context: Optional[str] = None
    swa_window: int = 8192
    dtype: str = "bfloat16"
    citation: str = ""

    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.stages)

    def blocks(self):
        """Iterate (stage_idx, rep_idx, block_idx_in_unit, BlockSpec) in order."""
        for si, st in enumerate(self.stages):
            for r in range(st.repeat):
                for bi, b in enumerate(st.unit):
                    yield si, r, bi, b

    def layer_types(self) -> Tuple[str, ...]:
        """All SmoothCache-eligible layer types present in the model."""
        types = []
        for st in self.stages:
            for b in st.unit:
                for t in b.branch_types():
                    if t not in types:
                        types.append(t)
        return tuple(types)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input-shape presets (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapePreset:
    name: str
    seq_len: int
    global_batch: int
    program: str                         # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapePreset("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapePreset("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  ShapePreset("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapePreset("long_500k",  524_288,    1, "decode"),
}
