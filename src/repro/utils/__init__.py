from repro.utils import flops  # noqa: F401
