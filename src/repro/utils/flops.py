"""Analytic MACs/FLOPs accounting per architecture config.

Used by the paper-table benchmarks (TMACs columns of Tables 1–3, compute
composition of Fig. 5) and cross-checked against the compiled-HLO analyzer
(launch/hlo_analysis.py) in tests.  MACs = multiply-accumulates (the
paper's unit); FLOPs = 2·MACs.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.config import (AttentionSpec, BlockSpec, MLPSpec, ModelConfig,
                          MoESpec, RGLRUSpec, SSMSpec)


def attn_macs(spec: AttentionSpec, d_model: int, lq: int, lk: int,
              cond_dim: int = 0) -> float:
    """Per-sequence MACs for one attention layer (projections + scores)."""
    if spec.kind == "mla":
        h = spec.num_heads
        qd = h * (spec.nope_head_dim + spec.rope_head_dim)
        m = 0.0
        if spec.q_lora_rank:
            m += lq * d_model * spec.q_lora_rank + lq * spec.q_lora_rank * qd
        else:
            m += lq * d_model * qd
        m += lk * d_model * (spec.kv_lora_rank + spec.rope_head_dim)
        m += lk * spec.kv_lora_rank * h * (spec.nope_head_dim + spec.v_head_dim)
        eff_lk = min(lk, spec.window) if spec.window else lk
        m += h * lq * eff_lk * (spec.nope_head_dim + spec.rope_head_dim)  # scores
        m += h * lq * eff_lk * spec.v_head_dim                            # AV
        m += lq * h * spec.v_head_dim * d_model                           # out
        return m
    h, kv, dh = spec.num_heads, spec.num_kv_heads, spec.head_dim
    kv_in = cond_dim if (spec.cross and cond_dim) else d_model
    m = lq * d_model * h * dh                 # q proj
    m += 2 * lk * kv_in * kv * dh             # k, v proj
    eff_lk = min(lk, spec.window) if (spec.window and not spec.cross) else lk
    m += h * lq * eff_lk * dh * 2             # scores + AV
    m += lq * h * dh * d_model                # out proj
    return m


def ffn_macs(spec, d_model: int, l: int) -> float:
    if isinstance(spec, MoESpec):
        per_tok = d_model * spec.d_ff * (3 if spec.gated else 2) * spec.top_k
        per_tok += d_model * spec.num_experts     # router
        if spec.num_shared:
            fs = spec.d_ff_shared or spec.d_ff * spec.num_shared
            per_tok += d_model * fs * (3 if spec.gated else 2)
        return l * per_tok
    return l * d_model * spec.d_ff * (3 if spec.gated else 2)


def mixer_macs(spec, d_model: int, lq: int, lk: int) -> float:
    if isinstance(spec, AttentionSpec):
        return attn_macs(spec, d_model, lq, lk)
    if isinstance(spec, SSMSpec):
        d_inner = spec.expand * d_model
        n_heads = d_inner // spec.head_dim
        gn = spec.n_groups * spec.d_state
        in_dim = 2 * d_inner + 2 * gn + n_heads
        m = lq * d_model * in_dim
        m += lq * (d_inner + 2 * gn) * spec.d_conv          # conv
        # SSD: intra-chunk (L·Q·(N+P)) + states (L·N·P)
        q = spec.chunk
        m += lq * q * n_heads * (spec.d_state + spec.head_dim)
        m += 2 * lq * n_heads * spec.head_dim * spec.d_state
        m += lq * d_inner * d_model                         # out proj
        return m
    # RG-LRU
    w = spec.expand * d_model
    hd = w // spec.num_heads
    m = 2 * lq * d_model * w                # in_x + gate
    m += lq * w * spec.conv_width
    m += 2 * lq * w * hd                    # block-diag gates
    m += lq * w * 4                         # recurrence elementwise
    m += lq * w * d_model                   # out
    return m


def block_macs_by_branch(b: BlockSpec, d_model: int, lq: int, lk: int,
                         cond_dim: int, cond_len: int) -> Dict[str, float]:
    out = {}
    names = b.branch_names()
    types = b.branch_types()
    for name, t in zip(names, types):
        if name == "mixer":
            out[t] = out.get(t, 0.0) + mixer_macs(b.mixer, d_model, lq, lk)
        elif name == "cross":
            out[t] = out.get(t, 0.0) + attn_macs(b.cross, d_model, lq,
                                                 cond_len, cond_dim)
        else:
            out[t] = out.get(t, 0.0) + ffn_macs(b.ffn, d_model, lq)
    return out


def model_macs_by_type(cfg: ModelConfig, seq_len: int, *,
                       cond_len: int = 64,
                       video_shape=None) -> Dict[str, float]:
    """Per-forward-pass MACs per SmoothCache layer type (one sample).

    Factorized video attention (OpenSora): a "spatial" mixer runs T
    independent length-S sequences, a "temporal" one runs S of length T;
    all other branches see the full T·S tokens."""
    total: Dict[str, float] = {}
    for st in cfg.stages:
        for b in st.unit:
            macs = block_macs_by_branch(b, cfg.d_model, seq_len, seq_len,
                                        cfg.cond_dim, cond_len)
            if (isinstance(b.mixer, AttentionSpec) and b.mixer.pattern
                    and video_shape):
                t, s = video_shape
                mixer_t = b.branch_types()[0]
                if b.mixer.pattern == "spatial":
                    macs[mixer_t] = t * mixer_macs(b.mixer, cfg.d_model, s, s)
                else:
                    macs[mixer_t] = s * mixer_macs(b.mixer, cfg.d_model, t, t)
            for k, v in macs.items():
                total[k] = total.get(k, 0.0) + st.repeat * v
    return total


def non_block_macs(cfg: ModelConfig, seq_len: int) -> float:
    """Embedding/head/patch machinery (the non-cacheable remainder)."""
    m = 0.0
    if cfg.task == "lm":
        m += seq_len * cfg.d_model * cfg.vocab_size * max(1, cfg.num_codebooks)
    else:
        import numpy as np
        tok_dim = int(np.prod(cfg.latent_shape[-1:])) * cfg.patch ** 2
        m += 2 * seq_len * cfg.d_model * tok_dim
        m += cfg.d_model * cfg.d_model * 2          # t-embed MLP etc.
    return m


def sampler_tmacs(cfg: ModelConfig, schedule, seq_len: int, batch: int, *,
                  cfg_scale: Optional[float] = None, cond_len: int = 64,
                  video_shape=None) -> float:
    """Total TMACs for a full diffusion sampling run under a SmoothCache
    schedule (paper Tables 1–3 unit: 1e12 MACs)."""
    per_type = model_macs_by_type(cfg, seq_len, cond_len=cond_len,
                                  video_shape=video_shape)
    eff_batch = batch * (2 if cfg_scale is not None else 1)
    total = 0.0
    for t, macs in per_type.items():
        frac = schedule.compute_fraction(t) if schedule is not None else 1.0
        total += macs * frac * schedule.num_steps if schedule is not None \
            else macs
    other = non_block_macs(cfg, seq_len) * (schedule.num_steps if schedule else 1)
    return (total + other) * eff_batch / 1e12
