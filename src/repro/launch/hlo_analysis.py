"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE — a 58-layer
``lax.scan`` is undercounted 58× (verified in EXPERIMENTS.md §Dry-run
methodology).  This module parses the optimized HLO and walks the call
graph, multiplying loop bodies by their trip count:

  * FLOPs: every ``dot`` op contributes 2 × |result| × |contraction dims|
    (XLA's own convention, validated against a plain matmul);
  * bytes: every top-level op (fusion boundaries) contributes its RESULT
    bytes, plus entry parameters once — a post-fusion HBM-traffic model
    (each intermediate is written once and read by consumers; counting
    results + args avoids double-counting producer/consumer pairs);
  * collective bytes: ring-model ICI traffic per op kind (see
    launch/roofline.py), now multiplied through loops.

Trip counts come from the largest integer constant in the while condition
computation — exact for ``lax.scan``-generated loops.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[^\s]+))\s+"
    r"([\w\-]+)\(")
_CALLED_RE = re.compile(
    r"(?:to_apply|condition|body|calls)=%?([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = bytes_ = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str
    called: List[str]


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Op]] = {}
        self.types: Dict[str, str] = {}
        self._entry: Optional[str] = None
        self._memo: Dict = {}
        self._parse(hlo_text)

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            stripped = raw.strip()
            is_hdr = (not raw.startswith(" ") and stripped.endswith("{")
                      and "->" in stripped and "=" not in stripped.split("(")[0])
            if is_hdr:
                hdr = _COMP_HDR_RE.match(stripped)
                if hdr:
                    cur = hdr.group(1)
                    self.comps[cur] = []
                    if stripped.startswith("ENTRY"):
                        self._entry = cur
                    continue
            if cur is None:
                continue
            m = _OP_RE.match(raw)
            if not m:
                continue
            name, type_str, opcode = m.group(1), m.group(2), m.group(3)
            called = _CALLED_RE.findall(raw)
            self.comps[cur].append(Op(name, type_str, opcode, raw, called))
            self.types[name] = type_str

    # -- per-op costs -------------------------------------------------------

    def _dot_flops(self, op: Op) -> float:
        _, line = op.type_str, op.line
        out_elems, _ = _shape_elems_bytes(op.type_str)
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        ops_m = _OPERAND_RE.findall(line.split("(", 1)[1])
        if not ops_m:
            return 0.0
        lhs_type = self.types.get(ops_m[0], "")
        sm = _SHAPE_RE.search(lhs_type)
        if sm is None:
            return 0.0
        dims = [int(d) for d in sm.group(2).split(",") if d]
        contract = 1
        if mc and mc.group(1):
            for i in mc.group(1).split(","):
                idx = int(i)
                if idx < len(dims):
                    contract *= dims[idx]
        return 2.0 * out_elems * contract

    _PURE_CONVERT_OPS = {"convert", "bitcast", "copy", "reshape",
                         "parameter", "broadcast", "constant"}

    def _op_bytes(self, op: Op) -> float:
        if op.opcode == "fusion":
            for c in op.called:
                comp_ops = self.comps.get(c, [])
                local = {o.name: o.type_str for o in comp_ops}
                # in-place dynamic-update-slice: traffic = the update slice,
                # not the whole aliased buffer
                for inner in comp_ops:
                    if inner.opcode == "dynamic-update-slice":
                        args = _OPERAND_RE.findall(
                            inner.line.split("(", 1)[1])
                        if len(args) >= 2:
                            ts = local.get(args[1]) or self.types.get(args[1])
                            if ts:
                                _, b = _shape_elems_bytes(ts)
                                return float(b)
                # pure dtype-conversion fusions exist because XLA:CPU has no
                # native bf16 GEMM and legalizes to f32 with materialized
                # converts; a bf16-native backend (TPU MXU) reads the source
                # directly — count at the NARROWER width (≈ the real read)
                if comp_ops and all(o.opcode in self._PURE_CONVERT_OPS
                                    for o in comp_ops):
                    in_b = [
                        _shape_elems_bytes(o.type_str)[1]
                        for o in comp_ops if o.opcode == "parameter"]
                    _, out_b = _shape_elems_bytes(op.type_str)
                    if in_b:
                        return float(min(max(in_b), out_b))
        _, out_b = _shape_elems_bytes(op.type_str)
        return float(out_b)

    def _coll_bytes(self, op: Op) -> Tuple[str, float]:
        kind = next(k for k in COLLECTIVES if op.opcode.startswith(k))
        _, nbytes = _shape_elems_bytes(op.type_str)
        rg = re.search(r"replica_groups=\{([^}]*)\}", op.line)
        n = 2
        if rg:
            first = rg.group(1).split("}")[0].lstrip("{")
            n = max(2, len([x for x in first.split(",") if x.strip()]))
        else:
            rg2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.line)
            if rg2:
                n = max(2, int(rg2.group(2)))
        frac = (n - 1) / n
        if kind == "all-reduce":
            traffic = 2.0 * nbytes * frac
        elif kind == "collective-permute":
            traffic = float(nbytes)
        else:
            traffic = nbytes * frac
        return kind, traffic

    def _trip_count(self, cond_comp: str) -> int:
        consts = []
        for op in self.comps.get(cond_comp, []):
            consts += [int(c) for c in _CONST_RE.findall(op.line)]
        return max(consts) if consts else 1

    # -- walk ---------------------------------------------------------------

    def analyze_comp(self, name: str, *, top: bool,
                     entry: bool = False) -> Totals:
        _memo = self._memo
        key = (name, top, entry)
        if key in _memo:
            return _memo[key]
        t = Totals()
        for op in self.comps.get(name, []):
            oc = op.opcode
            if oc == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                trips = self._trip_count(cond) if cond else 1
                if body:
                    # entry=False: a loop body's parameter is the carried
                    # tuple (weights+caches) — counting it per trip inflated
                    # decode bytes ~20x (§Perf-3); per-iteration reads are
                    # captured by the slice/fusion ops inside the body
                    t.add(self.analyze_comp(body, top=top), trips)
            elif oc in ("fusion", "call"):
                for c in op.called:
                    t.add(self.analyze_comp(c, top=False))
                if top:
                    t.bytes += self._op_bytes(op)
            elif oc == "dot":
                t.flops += self._dot_flops(op)
                if top:
                    t.bytes += self._op_bytes(op)
            elif any(oc.startswith(k) for k in COLLECTIVES):
                if oc.endswith("-done"):
                    continue
                kind, traffic = self._coll_bytes(op)
                t.coll[kind] = t.coll.get(kind, 0.0) + traffic
                t.coll["total"] = t.coll.get("total", 0.0) + traffic
            elif oc == "conditional":
                for c in op.called:
                    t.add(self.analyze_comp(c, top=top))
            elif oc == "parameter":
                if entry:                        # loop-carried tuples are
                    t.bytes += self._op_bytes(op)  # NOT re-read per trip
            elif top and oc not in ("constant", "tuple",
                                    "get-tuple-element", "bitcast"):
                t.bytes += self._op_bytes(op)
        _memo[key] = t
        return t

    def entry_totals(self) -> Totals:
        assert self._entry, "no ENTRY computation found"
        return self.analyze_comp(self._entry, top=True, entry=True)


def analyze(hlo_text: str) -> Totals:
    return HloAnalysis(hlo_text).entry_totals()


def top_contributors(hlo_text: str, n: int = 15, kind: str = "bytes"):
    """Largest per-op contributions (bytes or flops), trip-multiplied —
    the §Perf profiling view of a compiled dry-run."""
    h = HloAnalysis(hlo_text)
    rows = []

    def walk(comp, mult, top):
        for op in h.comps.get(comp, []):
            oc = op.opcode
            if oc == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                trips = h._trip_count(cm.group(1)) if cm else 1
                if bm:
                    walk(bm.group(1), mult * trips, top)
            elif oc in ("fusion", "call"):
                if kind == "flops":
                    for c in op.called:
                        walk(c, mult, False)
                if top and kind == "bytes":
                    rows.append((h._op_bytes(op) * mult, op.opcode, op.name,
                                 op.type_str[:60]))
            elif oc == "dot":
                if kind == "flops":
                    rows.append((h._dot_flops(op) * mult, "dot", op.name,
                                 op.type_str[:60]))
                elif top:
                    rows.append((h._op_bytes(op) * mult, "dot", op.name,
                                 op.type_str[:60]))
            elif any(oc.startswith(k) for k in COLLECTIVES) and kind == "coll":
                if not oc.endswith("-done"):
                    _, traffic = h._coll_bytes(op)
                    rows.append((traffic * mult, oc, op.name,
                                 op.type_str[:60]))
            elif top and kind == "bytes" and oc not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast"):
                rows.append((h._op_bytes(op) * mult, oc, op.name,
                             op.type_str[:60]))

    walk(h._entry, 1.0, True)
    rows.sort(reverse=True)
    return rows[:n]
