"""Production mesh definitions (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state — device counts are locked on first jax initialization, and only
``launch/dryrun.py`` (which sets XLA_FLAGS before any import) should ever
see 512 placeholder devices.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per chip, one direction)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (CPU tests / examples): 1×N mesh."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Axes the global batch is sharded over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_chips(mesh) -> int:
    return mesh.devices.size
