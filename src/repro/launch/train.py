"""Training driver.

Runs real steps on whatever devices exist (CPU smoke / TPU pod with the
production mesh), with checkpointing and the synthetic token pipeline:

    python -m repro.launch.train --arch qwen3-14b --variant smoke \
        --steps 50 --batch 8 --seq 128 --ckpt /tmp/q3.ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint, configs, shardctx
from repro.data import TokenStream, text_memory, vit_patch_embeds
from repro.launch import programs, sharding
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 production mesh (TPU pods)")
    ap.add_argument("--moe-strategy", default="dense",
                    choices=["dense", "gshard"])
    args = ap.parse_args()

    cfg = configs.get(args.arch, args.variant)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    print(f"[train] {cfg.name}: {cfg.num_layers} layers, "
          f"d_model={cfg.d_model}, mesh={dict(mesh.shape)}")

    key = jax.random.PRNGKey(0)
    if args.resume:
        tree, meta = checkpoint.restore(args.resume)
        params, opt_state = tree["params"], tree["opt"]
        start = meta.get("step", 0)
        print(f"[train] resumed from {args.resume} at step {start}")
    else:
        params = T.init_params(key, cfg)
        opt_state = adamw.init_state(params)
        start = 0

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] params: {n_params/1e6:.1f}M")

    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, schedule=adamw.cosine_schedule(10, args.steps * 10))
    step_fn = programs.make_train_step(cfg, opt_cfg, remat=False,
                                       moe_strategy=args.moe_strategy)
    p_specs = sharding.param_specs(mesh, jax.eval_shape(lambda: params), cfg)
    p_shard = sharding.to_named(mesh, p_specs)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))

    stream = TokenStream(cfg.vocab_size, args.seq, args.batch,
                         num_codebooks=cfg.num_codebooks)
    extra = {}
    if cfg.num_prefix_embeds:
        extra["prefix_embeds"] = vit_patch_embeds(
            jax.random.PRNGKey(5), args.batch, cfg.num_prefix_embeds,
            cfg.d_model)
    if cfg.cond_dim:
        extra["memory"] = text_memory(jax.random.PRNGKey(6), args.batch, 16,
                                      cfg.cond_dim)

    with shardctx.use(mesh):
        for i in range(start, start + args.steps):
            toks, tgts = stream.batch_at(i)
            t0 = time.time()
            params, opt_state, loss, metrics = jstep(
                params, opt_state, toks, tgts, **extra)
            loss = float(loss)
            dt = time.time() - t0
            if i < start + 3 or (i + 1) % 10 == 0:
                print(f"[train] step {i+1}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({dt*1e3:.0f} ms)")

    if args.ckpt:
        checkpoint.save(args.ckpt, {"params": params, "opt": opt_state},
                        {"step": start + args.steps, "arch": args.arch})
        print(f"[train] saved {args.ckpt}")


if __name__ == "__main__":
    main()
