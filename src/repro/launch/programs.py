"""The three lowered programs per (architecture × input shape):

  train_step   — LM loss (+MoE aux, +MTP for deepseek) + AdamW update
  prefill_step — full forward that builds the decode caches
  serve_step   — ONE new token against a fixed KV/state cache

plus ``input_specs``: ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for every program input, and the long_500k
sub-quadratic config adaptation (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import shardctx
from repro.config import AttentionSpec, ModelConfig, ShapePreset, SHAPES, Stage
from repro.models import transformer as T
from repro.optim import adamw

CACHE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.bfloat16
# optimizer moments: mu bf16 / nu fp32 (memory/stability trade recorded in
# DESIGN.md — required to approach fitting the 671B MoE on 512 chips)
MU_DTYPE = jnp.bfloat16
NU_DTYPE = jnp.float32


# ---------------------------------------------------------------------------
# long-context adaptation
# ---------------------------------------------------------------------------

def adapt_for_shape(cfg: ModelConfig, shape: ShapePreset) -> ModelConfig:
    """For long_500k, full-attention archs switch to the sliding-window
    variant (window = cfg.swa_window); SSM/hybrid archs are native."""
    if shape.name != "long_500k" or cfg.long_context != "swa":
        return cfg
    def swa(m):
        if isinstance(m, AttentionSpec) and not m.cross and m.window is None:
            return dataclasses.replace(m, window=cfg.swa_window)
        if isinstance(m, AttentionSpec) and m.window is not None:
            return dataclasses.replace(m, window=min(m.window, cfg.swa_window))
        return m
    stages = tuple(
        Stage(unit=tuple(dataclasses.replace(b, mixer=swa(b.mixer))
                         for b in st.unit), repeat=st.repeat)
        for st in cfg.stages)
    return cfg.replace(stages=stages, name=cfg.name + "+swa")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------

def token_struct(cfg: ModelConfig, batch: int, seq: int):
    shape = (batch, seq, cfg.num_codebooks) if cfg.num_codebooks > 1 \
        else (batch, seq)
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapePreset,
                moe_group_size: int = 2048) -> Dict[str, Any]:
    """Returns {name: ShapeDtypeStruct} for the program of this shape."""
    b = shape.global_batch
    out: Dict[str, Any] = {}
    if shape.program == "train":
        seq = shape.seq_len
        out["tokens"] = token_struct(cfg, b, seq)
        out["targets"] = token_struct(cfg, b, seq)
    elif shape.program == "prefill":
        out["tokens"] = token_struct(cfg, b, shape.seq_len)
    else:  # decode
        out["token"] = token_struct(cfg, b, 1)
        caches = jax.eval_shape(
            lambda: T.init_caches(cfg, b, shape.seq_len, CACHE_DTYPE))
        out["caches"] = caches
    if cfg.num_prefix_embeds and shape.program in ("train", "prefill"):
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_prefix_embeds, cfg.d_model), PARAM_DTYPE)
    if cfg.cond_dim:
        out["memory"] = jax.ShapeDtypeStruct((b, 64, cfg.cond_dim), PARAM_DTYPE)
    return out


def params_struct(cfg: ModelConfig, dtype=PARAM_DTYPE):
    return jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg, dtype=dtype),
        jax.random.PRNGKey(0))


def opt_struct(params_shape):
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "mu": jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, MU_DTYPE),
                           params_shape),
        "nu": jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, NU_DTYPE),
                           params_shape),
    }


# ---------------------------------------------------------------------------
# Programs
# ---------------------------------------------------------------------------

def _xent(logits, targets):
    """Cross entropy that stays vocab-sharded under GSPMD: the target
    logit is picked with a fused where(iota == target) reduction instead of
    a gather along the (model-sharded) vocab axis — a take_along_axis here
    forced a full replicated-logits all-reduce in the baseline HLO."""
    z = logits.astype(jnp.float32)
    z = shardctx.constrain(z, *(["batch"] + [None] * (z.ndim - 2) + ["model"]))
    lse = jax.nn.logsumexp(z, axis=-1)
    v = z.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, z.shape, z.ndim - 1)
    tgt = jnp.sum(jnp.where(iota == targets[..., None], z, 0.0), axis=-1)
    return jnp.mean(lse - tgt)


def lm_loss(cfg: ModelConfig, params, tokens, targets, *, prefix_embeds=None,
            memory=None, use_flash=False, moe_group_size=2048,
            moe_strategy="gshard", remat=True, mtp_weight: float = 0.3):
    logits, aux = T.forward(cfg, params, tokens, prefix_embeds=prefix_embeds,
                            memory=memory, use_flash=use_flash,
                            moe_group_size=moe_group_size,
                            moe_strategy=moe_strategy, remat=remat)
    if cfg.num_prefix_embeds and prefix_embeds is not None:
        logits = logits[:, prefix_embeds.shape[1]:]
    loss = _xent(logits, targets)
    if cfg.mtp_depth > 0 and cfg.num_codebooks == 1:
        hidden = aux["hidden"]
        if cfg.num_prefix_embeds and prefix_embeds is not None:
            hidden = hidden[:, prefix_embeds.shape[1]:]
        mlogits = T.mtp_logits(cfg, params, hidden, tokens,
                               moe_group_size=moe_group_size,
                               moe_strategy=moe_strategy)
        mtgt = jnp.concatenate([targets[:, 1:], targets[:, -1:]], axis=1)
        loss = loss + mtp_weight * _xent(mlogits, mtgt)
    # MoE load-balance aux
    aux_w = _moe_aux_weight(cfg)
    if aux_w:
        loss = loss + aux_w * aux["aux"]
    return loss


def _moe_aux_weight(cfg: ModelConfig) -> float:
    for st in cfg.stages:
        for b in st.unit:
            w = getattr(b.ffn, "aux_loss_weight", 0.0) if b.ffn else 0.0
            if w:
                return w
    return 0.0


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[adamw.AdamWConfig] = None,
                    *, use_flash=False, moe_group_size=2048,
                    moe_strategy="gshard", remat=True, grad_shardings=None):
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    def train_step(params, opt_state, tokens, targets, prefix_embeds=None,
                   memory=None):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, tokens, targets,
                              prefix_embeds=prefix_embeds, memory=memory,
                              use_flash=use_flash,
                              moe_group_size=moe_group_size,
                              moe_strategy=moe_strategy, remat=remat))(params)
        # cast + pin grads to the FSDP param sharding BEFORE the optimizer:
        # without the constraint GSPMD emitted full-weight f32 all-reduces
        # instead of bf16 reduce-scatters (gemma2 §Perf-2 iter 2)
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        params, opt_state, metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, loss, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: Optional[int] = None, *,
                      use_flash=False, moe_group_size=2048,
                      moe_strategy="gshard"):
    def prefill_step(params, tokens, prefix_embeds=None, memory=None):
        logits, caches = T.prefill(
            cfg, params, tokens,
            cache_len=cache_len or tokens.shape[1],
            prefix_embeds=prefix_embeds, memory=memory,
            cache_dtype=CACHE_DTYPE, use_flash=use_flash,
            moe_group_size=moe_group_size, moe_strategy=moe_strategy)
        return logits[:, -1:], caches

    return prefill_step


def make_serve_step(cfg: ModelConfig, pos: int, *, moe_group_size=2048,
                    moe_strategy="gshard"):
    """One decode step at static position ``pos`` (dry-run lowers the
    steady-state step; the serve driver re-lowers per bucket or uses a
    traced position)."""
    def serve_step(params, token, caches, memory=None):
        logits, new_caches = T.decode_step(cfg, params, token, pos, caches,
                                           memory=memory)
        return logits, new_caches

    return serve_step
