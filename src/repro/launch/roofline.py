"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_global   / (chips × 197 TFLOP/s)
    memory term     = HLO_bytes_global   / (chips × 819 GB/s)
    collective term = collective_bytes_per_chip / 50 GB/s   (ICI)

``compiled.cost_analysis()`` reports the per-device (post-SPMD) module, so
global = per-device × chips.  Collective bytes are parsed from the
partitioned HLO text; per-op ICI traffic model (ring algorithms):

    all-gather        → result bytes × (n−1)/n
    reduce-scatter    → operand bytes × (n−1)/n
    all-reduce        → 2 × operand bytes × (n−1)/n
    all-to-all        → operand bytes × (n−1)/n
    collective-permute→ operand bytes
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\S+?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device ICI bytes by collective kind, from partitioned HLO."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2)
        kind = m.group(3)
        nbytes = _shape_bytes(shape_str)
        # replica-group size for the (n-1)/n factor
        rg = re.search(r"replica_groups=\{([^}]*)\}", line)
        n = 2
        if rg:
            first = rg.group(1).split("}")[0].lstrip("{")
            n = max(2, len([x for x in first.split(",") if x.strip() != ""]))
        else:
            rg2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            if rg2:
                n = max(2, int(rg2.group(2)))
        frac = (n - 1) / n
        if kind == "all-reduce":
            traffic = 2.0 * nbytes * frac
        elif kind == "all-gather":
            traffic = nbytes * frac            # result bytes already in line
        elif kind == "reduce-scatter":
            traffic = nbytes * frac
        elif kind == "all-to-all":
            traffic = nbytes * frac
        else:                                   # collective-permute
            traffic = nbytes
        out[kind] = out.get(kind, 0.0) + traffic
        out["total"] = out.get("total", 0.0) + traffic
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, float]
    memory_per_chip: Optional[dict] = None
    model_flops: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if not self.model_flops:
            return None
        return self.model_flops / max(self.flops_per_chip * self.chips, 1.0)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "memory_per_chip": self.memory_per_chip,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops_estimate(n_params_active: float, tokens: float,
                         train: bool) -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward."""
    return (6.0 if train else 2.0) * n_params_active * tokens


def fmt_seconds(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.2f}ms"
    return f"{s*1e6:.1f}us"
