"""GSPMD sharding rules for every parameter / activation / cache in the
framework.

Policy (DESIGN.md §8):
  * tensor parallel over ``model``: attention heads, FFN hidden, expert dim,
    vocab;
  * FSDP over the batch axes (``pod``+``data``): the largest non-model dim
    of every big 2D+ weight (ZeRO-style — optimizer moments inherit it);
  * activations: batch over (pod, data);
  * decode caches: batch over (pod, data) when divisible, KV heads over
    ``model`` when divisible, else sequence over the free axes (context
    sharding — the long_500k path).

Every rule degrades to replication when a dim is not divisible by the mesh
axis: ``_fit`` checks divisibility so one rule set serves all 13 archs.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh, dim_size: int, axes):
    """Return axes if dim divisible by their product, else None."""
    if axes is None:
        return None
    n = _axis_size(mesh, axes)
    return axes if (n > 1 and dim_size % n == 0) else None


def fsdp_axes(mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


# ---------------------------------------------------------------------------
# Parameter rules (path-name dispatch)
# ---------------------------------------------------------------------------

def _attn_shardable(mesh, aspec) -> bool:
    """Head-TP is only coherent when head counts divide the model axis —
    otherwise GSPMD splits heads mid-vector on the (H·dh) reshape and
    falls back to huge reshards (observed in the internvl2 baseline)."""
    m = mesh.shape["model"] if "model" in mesh.axis_names else 1
    if m <= 1:
        return False
    if aspec.kind == "mla":
        return aspec.num_heads % m == 0
    return aspec.num_heads % m == 0 and aspec.num_kv_heads % m == 0


def _param_rule(name: str, shape, mesh, fsdp, block=None, role=None) -> P:
    nd = len(shape)

    def f(i, axes):          # fit axes to dim i
        return _fit(mesh, shape[i], axes)

    # attention projections: head-TP only when divisible (see above)
    if role in ("mixer", "cross") and block is not None:
        from repro.config import AttentionSpec, RGLRUSpec
        spec = block.mixer if role == "mixer" else block.cross
        if isinstance(spec, AttentionSpec) and not _attn_shardable(mesh, spec):
            if name in ("wq", "wk", "wv", "wo", "wq_b", "wkv_b"):
                return P(f(0, fsdp), None)
            if name in ("bq", "bk", "bv"):
                return P(None)
        if isinstance(spec, RGLRUSpec):
            # block-diagonal gates don't split over the model axis cleanly;
            # keep the RG-LRU mixer replicated (FSDP only), TP on the FFN
            if name in ("in_x", "in_gate", "out", "wa", "wx", "a_param"):
                return P(f(0, fsdp), *([None] * (nd - 1)))

    if name == "embed":
        if nd == 2:   # (V, D)
            return P(f(0, "model"), f(1, fsdp))
        return P(None, f(1, "model"), f(2, fsdp))          # (K, V, D)
    if name == "lm_head":
        return P(f(0, fsdp), f(1, "model"))
    if name == "heads":                                     # (K, D, V)
        return P(None, f(1, fsdp), f(2, "model"))
    if name in ("wq", "wk", "wv", "in_x", "in_gate"):       # (D, H·dh)
        return P(f(0, fsdp), f(1, "model"))
    if name in ("wo", "out", "out_proj"):                   # (H·dh, D)
        return P(f(0, "model"), f(1, fsdp))
    if name in ("wq_a", "wkv_a", "in_proj"):                # (D, r)
        return P(f(0, fsdp), None)
    if name in ("wq_b", "wkv_b"):                           # (r, H·x)
        return P(None, f(1, "model"))
    if name in ("bq", "bk", "bv"):
        return P(f(0, "model"))
    if name in ("w_up", "w_gate"):
        if nd == 2:                                         # (D, F)
            return P(f(0, fsdp), f(1, "model"))
        return P(f(0, "model"), f(1, fsdp), None)           # (E, D, F)
    if name == "w_down":
        if nd == 2:                                         # (F, D)
            return P(f(0, "model"), f(1, fsdp))
        return P(f(0, "model"), None, f(2, fsdp))           # (E, F, D)
    if name in ("wa", "wx"):                                # (nh, hd, hd)
        return P(f(0, "model"), None, None)
    if name == "a_param":
        return P(f(0, "model"))
    if name == "w" and nd == 2:                             # adaLN mod etc.
        return P(None, f(1, "model"))
    return P()                                              # replicate


def param_specs(mesh, params_shape_tree, cfg: Optional[ModelConfig] = None,
                *, fsdp: bool = True):
    """PartitionSpec tree for a params (or optimizer-moment) shape tree.
    With ``cfg``, attention rules become head-divisibility aware.

    ``fsdp=False`` → pure tensor-parallel (weights replicated over the
    batch axes).  Used for decode serving: FSDP would all-gather every
    weight every step (§Perf-3 — 60× memory-term inflation on qwen3
    decode); TP-only weights fit HBM for every assigned arch except the
    two giant MoEs, which keep expert-dim sharding across data anyway."""
    fsdp = fsdp_axes(mesh) if fsdp else None

    def walk(path, leaf):
        name = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                name = str(p.key)
                break
        shape = leaf.shape
        # locate the owning block for stage params: .../stages[si][bi][role]...
        block = role = None
        stacked = False
        keys = list(path)
        for i, p in enumerate(keys):
            if isinstance(p, jax.tree_util.DictKey) and p.key == "stages":
                stacked = True
                if cfg is not None and i + 2 < len(keys):
                    si = keys[i + 1].idx
                    bi = keys[i + 2].idx
                    block = cfg.stages[si].unit[bi]
                    for q in keys[i + 3:]:
                        if isinstance(q, jax.tree_util.DictKey) and \
                                q.key in ("mixer", "cross", "ffn"):
                            role = q.key
                            break
                break
            if isinstance(p, jax.tree_util.DictKey) and p.key == "mtp" \
                    and cfg is not None:
                block = cfg.stages[-1].unit[-1]
                role = "mixer"
        if stacked:
            inner = _param_rule(name, shape[1:], mesh, fsdp, block, role)
            return P(None, *inner)
        return _param_rule(name, shape, mesh, fsdp, block, role)

    return jax.tree_util.tree_map_with_path(walk, params_shape_tree)


# ---------------------------------------------------------------------------
# Activation / cache rules
# ---------------------------------------------------------------------------

def batch_spec(mesh, batch: int, extra_dims: int = 1) -> P:
    """(B, ...) with B over (pod, data) when divisible."""
    axes = _fit(mesh, batch, fsdp_axes(mesh))
    return P(axes, *([None] * extra_dims))


def cache_specs(mesh, cfg: ModelConfig, caches_shape, batch: int):
    """Specs for the stacked decode caches from transformer.init_caches."""
    b_axes = _fit(mesh, batch, fsdp_axes(mesh))

    def leaf_spec(path, leaf):
        name = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                name = str(p.key)
                break
        shape = leaf.shape                 # leading dim = repeat
        if name == "slots":                # (repeat, clen) int32
            return P()
        if name in ("k", "v"):
            # decode layouts: k (repeat,B,KV,dh,S), v (repeat,B,KV,S,dh)
            kv_dim, s_dim = (2, 4) if name == "k" else (2, 3)
            kv_ax = _fit(mesh, shape[kv_dim], "model")
            s_ax = (_fit(mesh, shape[s_dim], "model")
                    if (kv_ax is None and b_axes) else None)
            if b_axes is None:             # long_500k B=1: context-shard S
                s_ax = _fit(mesh, shape[s_dim], ("data", "model")
                            if kv_ax is None else "data")
                if s_ax is None:
                    s_ax = _fit(mesh, shape[s_dim], "data")
            spec_l = [None, b_axes, None, None, None]
            spec_l[kv_dim] = kv_ax
            spec_l[s_dim] = s_ax
            return P(*spec_l)
        if name in ("ckv", "krope"):       # (repeat, B, S, c)
            s_ax = _fit(mesh, shape[2], "model")
            if b_axes is None:
                s_ax = _fit(mesh, shape[2], ("data", "model")) or s_ax
            return P(None, b_axes, s_ax, None)
        if name == "ssm":                  # (repeat, B, H, P, N)
            return P(None, b_axes, _fit(mesh, shape[2], "model"), None, None)
        if name == "conv":                 # (repeat, B, K-1, C)
            return P(None, b_axes, None, _fit(mesh, shape[3], "model"))
        if name == "h":                    # (repeat, B, W)
            return P(None, b_axes, _fit(mesh, shape[2], "model"))
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, caches_shape)


def to_named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
