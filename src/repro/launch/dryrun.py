"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape) and both production meshes
(16×16 single pod, 2×16×16 two pods), lower + compile the corresponding
program with ShapeDtypeStruct inputs (no allocation), then record
``memory_analysis()`` / ``cost_analysis()`` / parsed collective bytes into
a JSON result the roofline tables read.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import: jax locks the device count on first init.

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                    # noqa: E402
from repro.config import SHAPES              # noqa: E402
from repro.launch import programs, sharding  # noqa: E402
from repro.launch.mesh import make_production_mesh, num_chips  # noqa: E402
from repro.launch.roofline import Roofline, collective_bytes, fmt_seconds  # noqa: E402


def meta_params_bytes(shape_tree) -> float:
    import numpy as np
    return float(sum(np.prod(a.shape) * 2 for a in jax.tree.leaves(shape_tree)))


def count_params(cfg, shape_tree) -> float:
    import numpy as np
    return float(sum(np.prod(a.shape) for a in jax.tree.leaves(shape_tree)))


def active_params(cfg) -> float:
    """Parameters touched per token (MoE: top-k + shared experts only)."""
    import numpy as np
    full = count_params(cfg, programs.params_struct(cfg))
    inactive = 0.0
    for st in cfg.stages:
        for b in st.unit:
            f = b.ffn
            if f is not None and hasattr(f, "num_experts"):
                per_e = cfg.d_model * f.d_ff * (3 if f.gated else 2)
                inactive += st.repeat * per_e * (f.num_experts - f.top_k)
    return full - inactive


def build(arch: str, shape_name: str, multi_pod: bool,
          moe_group_size: int = 2048):
    """Returns (jitted_fn, args_structs, meta)."""
    shape = SHAPES[shape_name]
    cfg = configs.get(arch).replace(dtype="bfloat16")
    cfg = programs.adapt_for_shape(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    p_struct = programs.params_struct(cfg)
    # decode serving: TP-only weights (no per-step FSDP gathers) unless the
    # model cannot fit HBM without sharding over the batch axes (giant MoEs)
    serve_fsdp = meta_params_bytes(p_struct) / (mesh.devices.size / (
        mesh.shape["model"] if "model" in mesh.axis_names else 1)) > 12e9
    use_fsdp = not (shape.program == "decode" and not serve_fsdp)
    p_specs = sharding.param_specs(mesh, p_struct, cfg, fsdp=use_fsdp)
    p_shard = sharding.to_named(mesh, p_specs)
    ins = programs.input_specs(cfg, shape, moe_group_size)
    b = shape.global_batch

    def bshard(extra):
        return sharding.to_named(mesh, sharding.batch_spec(mesh, b, extra))

    have_prefix = "prefix_embeds" in ins
    have_mem = "memory" in ins

    def with_optionals(base, n_lead):
        """Map trailing positional args onto the present optional kwargs
        (prefix_embeds before memory) — archs differ in which they take."""
        def fn(*a):
            lead, rest = a[:n_lead], list(a[n_lead:])
            kw = {}
            if have_prefix:
                kw["prefix_embeds"] = rest.pop(0)
            if have_mem:
                kw["memory"] = rest.pop(0)
            return base(*lead, **kw)
        return fn

    if shape.program == "train":
        o_struct = programs.opt_struct(p_struct)
        o_specs = {
            "step": sharding.to_named(mesh, jax.sharding.PartitionSpec()),
            "mu": sharding.to_named(mesh, sharding.param_specs(mesh, o_struct["mu"], cfg)),
            "nu": sharding.to_named(mesh, sharding.param_specs(mesh, o_struct["nu"], cfg)),
        }
        fn = with_optionals(
            programs.make_train_step(cfg, moe_group_size=moe_group_size,
                                     grad_shardings=p_shard), 4)
        args = [p_struct, o_struct, ins["tokens"], ins["targets"]]
        in_sh = [p_shard, o_specs, bshard(ins["tokens"].ndim - 1),
                 bshard(ins["targets"].ndim - 1)]
        if "prefix_embeds" in ins:
            args.append(ins["prefix_embeds"]); in_sh.append(bshard(2))
        if "memory" in ins:
            args.append(ins["memory"]); in_sh.append(bshard(2))
        out_sh = (p_shard, o_specs, None, None)
        jfn = jax.jit(fn, in_shardings=tuple(in_sh), out_shardings=out_sh,
                      donate_argnums=(0, 1))
    elif shape.program == "prefill":
        fn = with_optionals(programs.make_prefill_step(cfg), 2)
        args = [p_struct, ins["tokens"]]
        in_sh = [p_shard, bshard(ins["tokens"].ndim - 1)]
        if "prefix_embeds" in ins:
            args.append(ins["prefix_embeds"]); in_sh.append(bshard(2))
        if "memory" in ins:
            args.append(ins["memory"]); in_sh.append(bshard(2))
        jfn = jax.jit(fn, in_shardings=tuple(in_sh))
    else:  # decode
        cache_struct = ins["caches"]
        c_specs = sharding.cache_specs(mesh, cfg, cache_struct, b)
        c_shard = sharding.to_named(mesh, c_specs)
        base_serve = programs.make_serve_step(cfg, pos=shape.seq_len - 1)

        def fn(params, token, caches, *rest):
            return base_serve(params, token, caches,
                              memory=(rest[0] if rest else None))
        args = [p_struct, ins["token"], cache_struct]
        in_sh = [p_shard, bshard(ins["token"].ndim - 1), c_shard]
        if "memory" in ins:
            args.append(ins["memory"]); in_sh.append(bshard(2))
        jfn = jax.jit(fn, in_shardings=tuple(in_sh),
                      out_shardings=(None, c_shard), donate_argnums=(2,))
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": num_chips(mesh), "program": shape.program,
            "params": count_params(cfg, p_struct),
            "active_params": active_params(cfg)}
    return jfn, args, meta, cfg, mesh


def run_combo(arch: str, shape_name: str, multi_pod: bool,
              moe_group_size: int = 2048, want_text: bool = False) -> dict:
    t0 = time.time()
    jfn, args, meta, cfg, mesh = build(arch, shape_name, multi_pod,
                                       moe_group_size)
    from repro import shardctx
    with shardctx.use(mesh):
        lowered = jfn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # trip-count-aware accounting: cost_analysis counts while-loop bodies
    # ONCE (verified on a scanned matmul), so scanned layer stacks would be
    # undercounted ~num_layers x.  hlo_analysis walks the call graph and
    # multiplies loop bodies by their trip counts.
    from repro.launch import hlo_analysis
    totals = hlo_analysis.analyze(hlo)
    coll = dict(totals.coll)
    chips = meta["chips"]
    shape = SHAPES[shape_name]
    tokens = (shape.global_batch * shape.seq_len
              if shape.program in ("train", "prefill")
              else shape.global_batch * 1)
    from repro.launch.roofline import model_flops_estimate
    mf = model_flops_estimate(meta["active_params"], tokens,
                              train=(shape.program == "train"))
    rec = dict(meta)
    rec.update({
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_chip": totals.flops,
        "bytes_per_chip": totals.bytes,
        "xla_cost_flops_loop_uncounted": float(cost.get("flops", -1.0)),
        "collectives": coll,
        "coll_bytes_per_chip": coll.get("total", 0.0),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "model_flops": mf,
        "tokens": tokens,
    })
    r = Roofline(arch, shape_name, rec["mesh"], chips,
                 rec["flops_per_chip"], rec["bytes_per_chip"],
                 rec["coll_bytes_per_chip"], coll, rec["memory"], mf)
    rec["roofline"] = r.to_dict()
    if want_text:
        rec["hlo"] = hlo
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--moe-group-size", type=int, default=2048)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    archs = configs.ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            combos.append((a, s))

    for a, s in combos:
        tag = f"{a}__{s}__{'2x16x16' if args.multi_pod else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip] {tag} (cached)")
            continue
        print(f"[run ] {tag}", flush=True)
        try:
            rec = run_combo(a, s, args.multi_pod, args.moe_group_size)
        except Exception as e:
            rec = {"arch": a, "shape": s, "ok": False,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if rec.get("ok"):
            rf = rec["roofline"]
            print(f"  ok  lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"flops/chip={rec['flops_per_chip']:.3g} "
                  f"coll/chip={rec['coll_bytes_per_chip']:.3g}B "
                  f"bottleneck={rf['bottleneck']}", flush=True)
        else:
            print(f"  FAIL {rec['error']}", flush=True)


if __name__ == "__main__":
    main()
