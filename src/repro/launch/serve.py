"""AR serving driver: prefill + decode loop with a static request batch.

    python -m repro.launch.serve --arch qwen3-14b --variant smoke \
        --batch 4 --prompt-len 32 --gen 16

(The diffusion serving driver — the paper's inference kind, with
SmoothCache — is ``examples/serve_diffusion.py``.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import TokenStream, text_memory
from repro.models import transformer as T


def generate(cfg, params, prompts, gen_len: int, *, memory=None,
             cache_len=None, temperature: float = 0.0, key=None):
    """Greedy/temperature batched generation. prompts: (B, L[, K])."""
    b, plen = prompts.shape[:2]
    cache_len = cache_len or (plen + gen_len)
    logits, caches = T.prefill(cfg, params, prompts, cache_len=cache_len,
                               memory=memory, cache_dtype=jnp.float32,
                               moe_strategy="dense")

    @jax.jit
    def step(tok, pos, caches):
        lg, caches = T.decode_step(cfg, params, tok, pos, caches,
                                   memory=memory)
        return lg, caches

    def pick(lg, k):
        if temperature <= 0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(k, lg / temperature, axis=-1)

    tok = pick(logits, key)[:, -1:]                        # (B,1) or (B,1,K)
    if cfg.num_codebooks > 1:
        tok = tok.reshape(b, 1, cfg.num_codebooks)
    out = [tok]
    for i in range(gen_len - 1):
        lg, caches = step(tok, plen + i, caches)
        k = jax.random.fold_in(key, i) if key is not None else None
        tok = pick(lg, k)
        if cfg.num_codebooks > 1:
            tok = tok.reshape(b, 1, cfg.num_codebooks)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get(args.arch, args.variant)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    stream = TokenStream(cfg.vocab_size, args.prompt_len, args.batch,
                         num_codebooks=cfg.num_codebooks)
    prompts, _ = stream.batch_at(0)
    memory = (text_memory(jax.random.PRNGKey(3), args.batch, 16, cfg.cond_dim)
              if cfg.cond_dim else None)

    t0 = time.time()
    toks = generate(cfg, params, prompts, args.gen, memory=memory,
                    temperature=args.temperature, key=jax.random.PRNGKey(1))
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"[serve] {cfg.name}: generated {toks.shape} "
          f"({n_new} tokens in {dt:.2f}s → {n_new/dt:.1f} tok/s incl. "
          f"prefill+compile)")
    print("[serve] first sequence:", jax.device_get(toks[0]).tolist()[:16])


if __name__ == "__main__":
    main()
