"""SmoothCache schedule generation (Eq. 4 of the paper) + baselines.

A *schedule* maps each SmoothCache layer type to a boolean vector over
sampling steps: ``True`` = reuse the cache (skip computing every layer of
that type), ``False`` = compute (and refill the cache).  Step 0 is always
computed.  Schedules are static — decided offline from calibration error
curves — which keeps every sampler step graph-compilable.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Mapping, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Schedule:
    """skip[t][s] == True → at step s reuse the cache for all layers of
    type t (filled at the most recent computed step)."""
    skip: Mapping[str, np.ndarray]
    num_steps: int
    alpha: Optional[float] = None
    name: str = "smoothcache"

    def compute_fraction(self, t: str) -> float:
        return 1.0 - float(np.mean(self.skip[t]))

    def mask_at(self, s: int) -> Dict[str, bool]:
        return {t: bool(v[s]) for t, v in self.skip.items()}

    def mask_key_at(self, s: int):
        """Canonical hashable form of the step-``s`` mask: sorted
        ``(type, skip)`` pairs — the compile-cache / plan-signature key."""
        return tuple(sorted(self.mask_at(s).items()))

    def distinct_masks(self):
        return sorted({self.mask_key_at(s) for s in range(self.num_steps)})

    def summary(self) -> str:
        rows = [f"{self.name} (alpha={self.alpha})"]
        for t, v in sorted(self.skip.items()):
            frac = 100.0 * np.mean(v)
            rows.append(f"  {t:10s} skip {int(v.sum()):3d}/{len(v)} steps ({frac:.0f}%)")
        return "\n".join(rows)

    def to_json(self) -> str:
        return json.dumps({
            "name": self.name, "alpha": self.alpha, "num_steps": self.num_steps,
            "skip": {t: v.astype(int).tolist() for t, v in self.skip.items()}},
            sort_keys=True)

    def content_key(self) -> str:
        """Canonical string identifying the schedule *content* (sorted keys,
        deterministic float formatting) — safe to use as a compile-cache key,
        unlike ``hash()`` which is salted per process for strings."""
        return self.to_json()

    def fingerprint(self) -> str:
        """Short stable digest of :meth:`content_key`, memoized on the
        (frozen, content-immutable) instance — plan-provenance checks on
        the sampling hot path must not re-serialize the skip arrays."""
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            fp = hashlib.sha256(
                self.content_key().encode("utf-8")).hexdigest()[:16]
            object.__setattr__(self, "_fingerprint", fp)
        return fp

    @staticmethod
    def from_json(s: str) -> "Schedule":
        d = json.loads(s)
        return Schedule(
            skip={t: np.asarray(v, bool) for t, v in d["skip"].items()},
            num_steps=d["num_steps"], alpha=d.get("alpha"),
            name=d.get("name", "schedule"))


def no_cache(types: Sequence[str], num_steps: int) -> Schedule:
    return Schedule({t: np.zeros(num_steps, bool) for t in types},
                    num_steps, name="no_cache")


def fora(types: Sequence[str], num_steps: int, n: int) -> Schedule:
    """FORA [arXiv:2407.01425] / 'Static Caching': compute every n-th step,
    reuse in between — uniform across all layer types."""
    s = np.arange(num_steps)
    skip = (s % n) != 0
    skip[0] = False
    return Schedule({t: skip.copy() for t in types}, num_steps,
                    name=f"fora_n{n}")


def smoothcache(error_curves: Mapping[str, np.ndarray], alpha: float,
                k_max: int = 3) -> Schedule:
    """Paper Eq. 4 — greedy thresholding of the calibration error curve.

    ``error_curves[t]`` has shape (S, K+1): entry [s, k] is the type-mean
    L1 relative error between layer outputs at step s and step s−k
    (NaN/inf where k > s).  A step is skipped iff the error vs. the step
    that currently fills the cache is below ``alpha`` and its lag ≤ k_max.
    """
    if not error_curves:
        raise ValueError(
            "smoothcache() needs at least one layer-type error curve; got an "
            "empty mapping (did calibration run on a model with no "
            "SmoothCache-eligible layers?)")
    skip = {}
    s_total = 0
    for t, err in error_curves.items():
        s_total = err.shape[0]
        k_lim = min(k_max, err.shape[1] - 1)
        v = np.zeros(s_total, bool)
        last_computed = 0
        for s in range(1, s_total):
            k = s - last_computed
            if k <= k_lim and np.isfinite(err[s, k]) and err[s, k] < alpha:
                v[s] = True
            else:
                last_computed = s
        skip[t] = v
    return Schedule(skip, s_total, alpha=alpha)


def alpha_for_budget(error_curves: Mapping[str, np.ndarray],
                     target_compute_fraction: float, k_max: int = 3,
                     tol: float = 1e-3) -> float:
    """Linear/bisection search for the α whose schedule computes ~the given
    fraction of layer evaluations (paper §2.2: 'a brief linear search')."""
    lo, hi = 0.0, float(max(np.nanmax(e) for e in error_curves.values())) + 1e-6
    for _ in range(64):
        mid = 0.5 * (lo + hi)
        sch = smoothcache(error_curves, mid, k_max)
        frac = np.mean([sch.compute_fraction(t) for t in error_curves])
        if frac > target_compute_fraction:
            lo = mid          # computing too much → raise α
        else:
            hi = mid
        if hi - lo < tol:
            break
    return 0.5 * (lo + hi)
